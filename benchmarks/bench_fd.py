"""Planted-FD workload bench (ISSUE 10): precision/recall of two-phase FD
discovery on the shared index, plus count-prune accounting.

The planted lake makes every verdict decidable by construction:

  * the QUERY carries 24 determinant keys; the first 4 appear twice with
    two different dependent values (violating groups), the rest map to a
    single dependent value;
  * ``clean`` tables hold only non-violating keys — the FD holds on the
    join (``holds=True``);
  * ``violator`` tables include the violating keys — refuted exactly
    (``holds=False``);
  * ``near-miss`` tables match exactly ONE determinant key plus filler —
    their phase-A count sits below ``min_support=2``, so the counts-as-
    refutation prune drops them before any re-gather;
  * ``noise`` tables hold a single determinant-column value each —
    posting-list candidates that can never host the composite key, pruned
    the same way.  They exist to make the prune rate mean something: the
    ≥0.9 gate proves phase B touches a sliver of the candidate set.

Recall is over planted clean tables (no FD may be missed — the §6.3
zero-false-negative lemma extends to FD support), precision is over
``holds=False`` verdicts (every refutation must be a planted violator).
A second pass with the signal ensemble on gates that signals reorder but
NEVER change support/holds facts (``signals_identical``).
"""

from __future__ import annotations

import argparse
import time

from benchmarks import common
from repro.core import fd as fd_lib
from repro.core import xash
from repro.core.corpus import Corpus, Table
from repro.core.index import MateIndex

N_KEYS = 24
N_VIOL_KEYS = 4
N_CLEAN = 6
N_VIOL = 6
N_NEAR = 6
N_NOISE = 200
MIN_SUPPORT = 2
BITS = 128


def planted_fd_lake():
    """Returns (corpus, query, det_cols, dep_col, clean_ids, violator_ids)."""
    keys = [(f"fkA{r:02d}", f"fkB{r:02d}") for r in range(N_KEYS)]
    rows = [[a, b, f"dv{r:02d}"] for r, (a, b) in enumerate(keys)]
    for r in range(N_VIOL_KEYS):  # second dependent value → violating group
        a, b = keys[r]
        rows.append([a, b, f"dv{r:02d}x"])
    query = Table(-1, rows, name="fd bench query")
    clean_keys = keys[N_VIOL_KEYS:]

    tables: list[Table] = []
    clean_ids: set[int] = set()
    violator_ids: set[int] = set()
    for _ in range(N_CLEAN):
        tid = len(tables)
        cells = [[a, b, f"t{tid}p{r}"] for r, (a, b) in enumerate(clean_keys)]
        tables.append(Table(tid, cells))
        clean_ids.add(tid)
    for _ in range(N_VIOL):
        tid = len(tables)
        picked = keys[:N_VIOL_KEYS] + clean_keys[:4]
        cells = [[a, b, f"t{tid}p{r}"] for r, (a, b) in enumerate(picked)]
        tables.append(Table(tid, cells))
        violator_ids.add(tid)
    for i in range(N_NEAR):
        tid = len(tables)
        a, b = clean_keys[i % len(clean_keys)]
        cells = [[a, b, f"t{tid}solo"]] + [
            [f"nm{tid}r{r}", f"nm{tid}s{r}", "pad"] for r in range(6)
        ]
        tables.append(Table(tid, cells))
    for i in range(N_NOISE):
        tid = len(tables)
        a, _b = keys[i % N_KEYS]  # init-column value → posting candidate
        tables.append(Table(tid, [[a, f"zz{tid}"]]))
    return Corpus(tables), query, [0, 1], 2, clean_ids, violator_ids


def fd_bench():
    print("# two-phase FD discovery on the planted-FD lake")
    corpus, query, det_cols, dep_col, clean_ids, violator_ids = planted_fd_lake()
    idx = MateIndex(corpus, cfg=xash.XashConfig(bits=BITS))

    t0 = time.perf_counter()
    fds, stats = fd_lib.discover_fds(
        idx, query, det_cols, dep_col, min_support=MIN_SUPPORT
    )
    dt_us = (time.perf_counter() - t0) * 1e6

    reported_holds = {c.table_id for c in fds if c.holds}
    reported_viol = {c.table_id for c in fds if not c.holds}
    recall = len(reported_holds & clean_ids) / max(len(clean_ids), 1)
    viol_precision = (
        len(reported_viol & violator_ids) / max(len(reported_viol), 1)
    )
    common.emit(
        f"fd/planted({BITS})", dt_us,
        f"recall={recall:.3f};viol_precision={viol_precision:.3f};"
        f"n_clean={len(clean_ids)};n_viol={len(violator_ids)};"
        f"reported={len(fds)};min_support={MIN_SUPPORT}",
    )

    prune_rate = 1 - stats.fd_validated / max(stats.fd_candidates, 1)
    common.emit(
        f"fd/prune({BITS})", 0.0,
        f"candidates={stats.fd_candidates};validated={stats.fd_validated};"
        f"prune_rate={prune_rate:.3f};"
        f"bytes_verified={stats.fd_bytes_verified}",
    )

    # signal ensemble: pure reordering/annotation — identical facts
    scored, _ = fd_lib.discover_fds(
        idx, query, det_cols, dep_col, min_support=MIN_SUPPORT,
        signals=fd_lib.DEFAULT_SIGNALS,
    )
    facts = lambda out: sorted(  # noqa: E731
        (c.table_id, c.support, c.holds, c.violations) for c in out
    )
    identical = facts(scored) == facts(fds)
    all_scored = all(c.score is not None for c in scored)
    common.emit(
        f"fd/signals({BITS})", 0.0,
        f"signals_identical={identical};all_scored={all_scored};"
        f"n_signals={len(fd_lib.DEFAULT_SIGNALS)}",
    )
    return {
        "recall": recall, "viol_precision": viol_precision,
        "prune_rate": prune_rate, "signals_identical": identical,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.parse_args(argv)
    out = fd_bench()
    common.save_trajectory("fd")
    return out


if __name__ == "__main__":
    main()
