"""FP-rate vs filter-bandwidth tradeoff across superkey widths.

Paper Tables 1–2 show that widening XASH from 128 to 512 bits cuts
false-positive rows by an order of magnitude at 4x the filter bandwidth
(16 uint32 lanes instead of 4).  This harness reproduces that tradeoff on
the synthetic lake: per width it builds the index, probes every eligible
(candidate row, query key) pair through the super-key filter WITHOUT top-k
pruning, verifies every survivor exactly, and reports

  * ``fp_rate``       — false positives per eligible probe (lower = better)
  * ``fp`` / ``tp``   — raw survivor split
  * ``fn``            — filter rejections of exact matches (must be 0:
                        the §6.3 no-false-negative lemma holds at ANY width)
  * ``filter_bytes_per_row`` — superkey bytes streamed per candidate row
                        (the bandwidth side of the tradeoff)

Rows persist to ``benchmarks/results/BENCH_fp_rate.json`` so the per-width
trend accumulates a trajectory across runs (docs/BENCHMARKS.md).

``python -m benchmarks.bench_fp_rate [--quick]`` (--quick: 128/512 only,
small query group).
"""

from __future__ import annotations

import argparse

from benchmarks import common

WIDTHS = (128, 256, 512)


def fp_rate(widths=WIDTHS, groups=None):
    print("# FP rate vs filter bandwidth per superkey width (Tables 1-2)")
    out = {}
    for gname, n_rows in (groups or common.ROWS).items():
        queries = common.query_group(n_rows)
        for bits in widths:
            idx = common.index("xash", bits)
            agg = common.fp_outcomes(idx, queries, check_false_negatives=True)
            out[(gname, bits)] = agg
            common.emit(
                f"fp/{gname}/xash({bits})", 0.0,
                f"fp_rate={agg['fp_rate']:.5f};fp={agg['fp']};tp={agg['tp']};"
                f"fn={agg['fn']};checks={agg['checks']};"
                f"filter_bytes_per_row={idx.cfg.lanes * 4}",
            )
        lo, hi = min(widths), max(widths)
        a, b = out[(gname, lo)], out[(gname, hi)]
        ratio = a["fp"] / max(b["fp"], 1)
        fn_any = max(out[(gname, bits)]["fn"] for bits in widths)
        common.emit(
            f"fp/{gname}/trend", 0.0,
            f"fp_{lo}_over_{hi}={ratio:.1f}x;"
            f"ordering_ok={b['fp'] < a['fp'] or a['fp'] == 0};"
            f"fn_any={fn_any}",
        )
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="128/512 only on the small query group")
    args = ap.parse_args(argv)
    widths = (128, 512) if args.quick else WIDTHS
    groups = {"webtable(10)": common.ROWS["webtable(10)"]} if args.quick else None
    fp_rate(widths, groups)
    common.save_trajectory("fp_rate")


if __name__ == "__main__":
    main()
