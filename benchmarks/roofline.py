"""Roofline builder: reads results/dryrun/*.json → §Roofline table.

Per (arch × shape × mesh):
  compute    = corrected HLO flops/device ÷ 197 TFLOP/s (bf16, v5e)
  memory     = HLO bytes-accessed/device ÷ 819 GB/s
  collective = corrected collective bytes/device ÷ 50 GB/s/link
(bytes-accessed falls back to param+arg traffic when XLA omits it on CPU)
plus MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) and the useful-flops
ratio.  Emits markdown (EXPERIMENTS.md §Roofline) and a CSV for run.py.
"""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

from repro.launch.mesh import V5E

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def model_flops_per_device(rec: dict) -> float:
    """Analytic useful flops per device per step."""
    n_active = rec["params_active"]
    chips = rec["n_chips"]
    if rec["kind"] == "filter":  # mate-filter: 8 int-ops per (row × key) probe
        return rec.get("probe_ops", 0.0) / chips
    if rec["kind"] == "train":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 6.0 * n_active * tokens / chips
    if rec["kind"] == "prefill":
        tokens = rec["global_batch"] * rec["seq_len"]
        return 2.0 * n_active * tokens / chips
    # decode: one token per sequence
    return 2.0 * n_active * rec["global_batch"] / chips


def load_cells(variant: str | None = None, out_dir: str = RESULTS) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        stem = os.path.basename(path)[: -len(".json")]
        parts = stem.split("__")
        v = parts[3] if len(parts) > 3 else "baseline"
        if variant is not None and v != variant:
            continue
        with open(path) as f:
            rec = json.load(f)
        rec["_file"] = stem
        rec["_variant"] = v
        cells.append(rec)
    return cells


def terms(rec: dict) -> dict | None:
    if rec.get("skipped") or "error" in rec:
        return None
    hc = rec.get("hlo_cost") or {}
    flops = hc.get("flops") or 0.0
    # bytes accessed: XLA cost analysis key (per device); CPU backend reports
    # it under 'bytes accessed'; fall back to args+outputs+temp traffic.
    ca = rec.get("cost_analysis") or {}
    bytes_acc = ca.get("bytes accessed")
    if bytes_acc is None:
        ma = rec.get("memory_analysis") or {}
        bytes_acc = sum(
            ma.get(k, 0)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes")
        )
    coll = hc.get("collective_bytes_total") or 0.0
    t_compute = flops / V5E["peak_flops_bf16"]
    t_memory = bytes_acc / V5E["hbm_bw"]
    t_coll = coll / V5E["ici_bw"]
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]
    mf = model_flops_per_device(rec)
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "variant": rec["_variant"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops": flops,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_frac": (
            mf / V5E["peak_flops_bf16"] / max(t_compute, t_memory, t_coll)
            if max(t_compute, t_memory, t_coll) > 0
            else 0.0
        ),
        "mem_temp_gb": (rec.get("memory_analysis") or {}).get(
            "temp_size_in_bytes", 0
        ) / 1e9,
        "compile_s": rec.get("compile_seconds"),
    }


def markdown_table(rows: list[dict]) -> str:
    hdr = (
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | useful flops | roofline frac | temp GB |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} "
            f"| {r['t_collective_s']:.4f} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.3f} "
            f"| {r['mem_temp_gb']:.1f} |"
        )
    return hdr + "\n".join(lines)


def main():
    cells = load_cells(variant="baseline")
    rows = [t for t in (terms(c) for c in cells) if t]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print(markdown_table(rows))
    skipped = [c for c in cells if c.get("skipped")]
    errored = [c for c in cells if "error" in c]
    print(f"\n{len(rows)} cells, {len(skipped)} documented skips, "
          f"{len(errored)} errors")
    for c in skipped:
        print(f"  SKIP {c['_file']}: {c['reason'][:70]}")
    for c in errored:
        print(f"  ERR  {c['_file']}")


if __name__ == "__main__":
    main()
