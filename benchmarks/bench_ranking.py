"""Ranked discovery bench (ISSUE 9): precision@k of the join-quality
scoring head vs raw count rank, plus profile-gate prune accounting.

The planted-quality lake makes count rank provably uninformative:

  * ``good`` tables hold each of the query's composite keys exactly once
    and nothing else duplicated — joinability 20, uniqueness ~1.0;
  * ``bad`` tables hold the SAME keys once each plus a block of repeated
    filler rows — joinability is identical (20) but uniqueness ~0.2;
  * good/bad ids interleave, so count rank (sorted ``(-J, table_id)``)
    alternates them and precision@10 sits at 0.5, while the quality score's
    uniqueness term separates the two classes completely;
  * ``narrow`` tables are 1-column tables holding the init-column values —
    posting-list candidates that can never host a width-2 key, so the
    profile gate prunes them deterministically (``n_cols < width``).

Retrieval runs at k = all planted tables: rank='quality' must keep the
verified SET bit-identical to count rank (pure reordering), which is
exactly what the ``identical`` flag gates in CI.
"""

from __future__ import annotations

import argparse
import time

from benchmarks import common
from repro.core import xash
from repro.core.batched import discover_batched
from repro.core.index import MateIndex

N_KEYS = 20
N_GOOD = 10
N_BAD = 10
N_NARROW = 10
N_NOISE = 30
PREC_AT = 10
BITS = 128


def planted_lake():
    """Returns (corpus, query, q_cols, good_ids) — the shared factory at
    this module's historical parameters (byte-identical lake)."""
    return common.planted_quality_lake(
        n_keys=N_KEYS, n_good=N_GOOD, n_bad=N_BAD,
        n_narrow=N_NARROW, n_noise=N_NOISE, noise_seed=11,
    )


def _precision_at(entries, good_ids, n=PREC_AT):
    top = [e.table_id for e in entries[:n]]
    return sum(1 for tid in top if tid in good_ids) / max(len(top), 1)


def ranking_bench():
    print("# quality rank vs count rank on the planted-quality lake")
    corpus, query, q_cols, good_ids = planted_lake()
    idx = MateIndex(corpus, cfg=xash.XashConfig(bits=BITS))
    k = N_GOOD + N_BAD  # retrieve every planted table; rank decides order

    count_rank, count_stats = discover_batched(idx, query, q_cols, k=k)
    t0 = time.perf_counter()
    quality, qstats = discover_batched(
        idx, query, q_cols, k=k, rank="quality", profile_gate=True
    )
    dt_us = (time.perf_counter() - t0) * 1e6

    def key(entries):
        return sorted((e.table_id, e.joinability) for e in entries)

    identical = key(quality) == key(count_rank)
    prec_q = _precision_at(quality, good_ids)
    prec_c = _precision_at(count_rank, good_ids)
    common.emit(
        f"rank/planted({BITS})", dt_us,
        f"prec_quality={prec_q:.3f};prec_count={prec_c:.3f};"
        f"quality_beats_count={prec_q > prec_c};"
        f"n_good={N_GOOD};n_bad={N_BAD};k={k};"
        f"ranking_launches={qstats.ranking_launches}",
    )

    fetched = count_stats.tables_fetched
    gated = qstats.tables_gated
    prune_rate = gated / max(fetched, 1)
    common.emit(
        f"rank/gate({BITS})", 0.0,
        f"gated={gated};fetched={fetched};prune_rate={prune_rate:.3f};"
        f"identical={identical};gate_bytes_saved={qstats.gate_bytes_saved}",
    )
    return {
        "prec_quality": prec_q, "prec_count": prec_c,
        "gated": gated, "identical": identical,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.parse_args(argv)
    out = ranking_bench()
    common.save_trajectory("ranking")
    return out


if __name__ == "__main__":
    main()
