"""Kernel microbenchmarks (interpret-mode wall clock on CPU is NOT a TPU
number — the derived column carries the structural throughput metrics that
transfer: bytes/row touched, probes per byte; see EXPERIMENTS.md §Roofline
for the device-level analysis) + batched-vs-sequential engine comparison."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common
from repro.core import discovery, xash
from repro.core.batched import discover_batched
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _time(fn, *args, n=3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / n


def kernels():
    print("# kernel microbench (interpret mode)")
    cfg = xash.DEFAULT_CONFIG
    enc = RNG.integers(0, 38, size=(4096, 6, 48)).astype(np.uint8)
    dt = _time(ops.superkey, enc, cfg)
    common.emit(
        "kern/xash_superkey_4096x6", dt * 1e6,
        f"rows_per_s={4096/dt:,.0f};bytes_per_row={6*48+16}"
    )
    row_sk = np.asarray(ref.xash_superkey_ref(enc, cfg))
    q_sk = row_sk[:256]
    dt = _time(ops.filter_count, row_sk, q_sk)
    probes = row_sk.shape[0] * q_sk.shape[0]
    common.emit(
        "kern/filter_count_4096x256", dt * 1e6,
        f"probes_per_s={probes/dt:,.0f};bytes_per_probe={2*16/256:.3f}"
    )
    dt_ref = _time(
        lambda: np.asarray(ref.filter_count_ref(row_sk, q_sk))
    )
    common.emit(
        "kern/filter_count_jnp_ref", dt_ref * 1e6,
        f"kernel_vs_ref={dt_ref/dt:.2f}x"
    )
    # backend-dispatched filter the online engine actually calls (Pallas on
    # TPU, vectorised XLA on CPU) — the per-launch cost the batched engine
    # amortises over whole table batches
    dt_auto = _time(ops.filter_match_auto, row_sk, q_sk)
    backend = jax.default_backend()
    dispatch = "pallas" if backend == "tpu" else "xla"
    common.emit(
        "kern/filter_match_auto_4096x256", dt_auto * 1e6,
        f"probes_per_s={probes/dt_auto:,.0f};backend_dispatch={backend}_{dispatch}"
    )
    # fused filter+segment-count vs the composed path (match matrix + XLA
    # segment-sum): identical probes and counts, but the fused launch's only
    # outputs are the two counts vectors — the n×q int8 match matrix (the
    # dominant write of the composed path) never exists, which is the
    # structural bytes-moved metric that transfers to TPU (see
    # docs/BENCHMARKS.md §Roofline).
    n, q = row_sk.shape[0], q_sk.shape[0]
    n_tables = 64
    seg = np.sort(RNG.integers(0, n_tables, n)).astype(np.int32)
    elig = np.ones((n, q), dtype=bool)
    dt_fused = _time(ops.filter_table_counts, row_sk, q_sk, elig, seg, n_tables)
    dt_comp = _time(
        lambda: ops.filter_hits_table_counts(
            row_sk, q_sk, elig, seg, n_tables, backend="xla"
        )[1]
    )
    out_fused = 4 * n_tables + 4 * q  # counts + key-counts vectors
    out_comp = n * q + 4 * n_tables  # int8 match matrix + counts
    common.emit(
        "kern/filter_table_counts_fused_4096x256", dt_fused * 1e6,
        f"out_bytes={out_fused};matrix_bytes_avoided={n*q};"
        f"bytes_out_vs_composed={out_fused/out_comp:.4f}",
        backend="fused",  # this row pins the fused kernel regardless of env
    )
    common.emit(
        "kern/filter_table_counts_composed_4096x256", dt_comp * 1e6,
        f"out_bytes={out_comp};fused_vs_composed_wallclock={dt_comp/dt_fused:.2f}x",
        backend="xla",  # composed reference is pinned to the XLA path
    )
    # gather-fused: same launch, but candidate INPUT rows are DMA-gathered
    # from the device-resident superkey store inside the kernel — the host
    # ships n int32 offsets instead of n×lanes uint32 superkeys.  The
    # structural metric is input bytes shipped per launch; wall clock in
    # interpret mode only shows the path isn't pathological.
    import jax.numpy as jnp

    store = jnp.asarray(
        np.concatenate([row_sk, RNG.integers(0, 2**32, row_sk.shape, np.uint32)])
    )
    rows_idx = RNG.permutation(store.shape[0])[:n].astype(np.int64)
    dt_gather = _time(
        ops.gather_filter_table_counts, store, rows_idx, q_sk, elig, seg, n_tables
    )
    lanes = row_sk.shape[1]
    in_gather = n * 4  # int32 offsets
    in_comp = n * lanes * 4  # host-gathered uint32 superkeys
    common.emit(
        "kern/gather_filter_table_counts_4096x256", dt_gather * 1e6,
        f"in_bytes={in_gather};gather_bytes_saved={in_comp - in_gather};"
        f"in_bytes_vs_composed={in_gather/in_comp:.4f};"
        f"gather_vs_fused_wallclock={dt_gather/dt_fused:.2f}x",
        backend="fused-gather",  # this row pins the gather-fused kernel
    )


def engines():
    print("# engine comparison: SCI vs MATE(seq) vs MATE(batched/fused)")
    queries = common.query_group(common.ROWS["webtable(100)"])
    idx = common.index("xash", 128)
    # warm jit/dispatch caches so the timed runs (and the CI regression gate
    # ratios derived from them) measure steady state, not compiles
    for engine in ("seq", "batched", "batched_fused", "batched_gather"):
        common.run_discovery(idx, queries, engine=engine)
    t_sci, _ = common.run_discovery(idx, queries, row_filter=False)
    t_seq, _ = common.run_discovery(idx, queries)
    t_bat, stb = common.run_discovery(idx, queries, engine="batched")
    t_fus, stf = common.run_discovery(idx, queries, engine="batched_fused")
    n = len(queries)
    common.emit("engine/sci", t_sci / n * 1e6, "row_filter=off")
    common.emit("engine/mate_seq", t_seq / n * 1e6, f"vs_sci={t_sci/t_seq:.2f}x")
    common.emit(
        "engine/mate_batched", t_bat / n * 1e6,
        f"vs_sci={t_sci/t_bat:.2f}x;vs_seq={t_seq/t_bat:.2f}x"
    )
    # fused filter+segment-count engine path: the structural claim the gate
    # checks is matrix_bytes == 0 (counts-only readback); wall-clock vs the
    # composed engine only transfers on TPU backends.
    common.emit(
        "engine/mate_batched_fused", t_fus / n * 1e6,
        f"vs_seq={t_seq/t_fus:.2f}x;matrix_bytes={stf['matrix_bytes']};"
        f"fused_launches={stf['fused_launches']};"
        f"readback_bytes={stf['readback_bytes']}",
        backend="fused",  # run_discovery pins backend='fused' for this row
    )
    # gather-fused engine path: same counts-only contract PLUS no host
    # superkey gather — gather_saved counts the launch input bytes that
    # stayed in the device store (n_candidates × (lanes·4 − 4) per launch).
    t_gat, stg = common.run_discovery(idx, queries, engine="batched_gather")
    common.emit(
        "engine/mate_batched_gather", t_gat / n * 1e6,
        f"vs_fused={t_fus/t_gat:.2f}x;matrix_bytes={stg['matrix_bytes']};"
        f"fused_launches={stg['fused_launches']};"
        f"gather_bytes_saved={stg['gather_saved']}",
        backend="fused-gather",  # run_discovery pins backend='fused-gather'
    )
    # routed lake (4 shards): shard-local launches + count-only merge.  The
    # structural claims the gate checks: bit-identical top-k to the
    # single-host engine, and the ONLY cross-shard traffic is the int32
    # count vectors — route_bytes ≪ the superkey bytes a host-gather ships.
    ridx = common.routed_index(4, 128)
    common.run_discovery(ridx, queries, engine="batched")  # warm
    identical = int(
        all(
            [(e.table_id, e.joinability) for e in discover_batched(
                ridx, q, c, k=common.K)[0]]
            == [(e.table_id, e.joinability) for e in discover_batched(
                idx, q, c, k=common.K)[0]]
            for q, c in queries
        )
    )
    t_rt, strt = common.run_discovery(ridx, queries, engine="batched")
    host_gather_bytes = strt["items_checked"] * ridx.cfg.lanes * 4
    common.emit(
        "engine/mate_batched_routed", t_rt / n * 1e6,
        f"vs_batched={t_bat/t_rt:.2f}x;identical={identical};"
        f"shard_launches={strt['shard_launches']};"
        f"route_bytes_merged={strt['route_bytes']};"
        f"route_frac={strt['route_bytes']/max(host_gather_bytes,1):.4f}",
    )


def main():
    kernels()
    engines()
    common.save_trajectory("kernels")


if __name__ == "__main__":
    main()
