"""Kernel microbenchmarks (interpret-mode wall clock on CPU is NOT a TPU
number — the derived column carries the structural throughput metrics that
transfer: bytes/row touched, probes per byte; see EXPERIMENTS.md §Roofline
for the device-level analysis) + batched-vs-sequential engine comparison."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common
from repro.core import discovery, xash
from repro.core.batched import discover_batched
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _time(fn, *args, n=3, **kw):
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / n


def kernels():
    print("# kernel microbench (interpret mode)")
    cfg = xash.DEFAULT_CONFIG
    enc = RNG.integers(0, 38, size=(4096, 6, 48)).astype(np.uint8)
    dt = _time(ops.superkey, enc, cfg)
    common.emit(
        "kern/xash_superkey_4096x6", dt * 1e6,
        f"rows_per_s={4096/dt:,.0f};bytes_per_row={6*48+16}"
    )
    row_sk = np.asarray(ref.xash_superkey_ref(enc, cfg))
    q_sk = row_sk[:256]
    dt = _time(ops.filter_count, row_sk, q_sk)
    probes = row_sk.shape[0] * q_sk.shape[0]
    common.emit(
        "kern/filter_count_4096x256", dt * 1e6,
        f"probes_per_s={probes/dt:,.0f};bytes_per_probe={2*16/256:.3f}"
    )
    dt_ref = _time(
        lambda: np.asarray(ref.filter_count_ref(row_sk, q_sk))
    )
    common.emit(
        "kern/filter_count_jnp_ref", dt_ref * 1e6,
        f"kernel_vs_ref={dt_ref/dt:.2f}x"
    )
    # backend-dispatched filter the online engine actually calls (Pallas on
    # TPU, vectorised XLA on CPU) — the per-launch cost the batched engine
    # amortises over whole table batches
    dt_auto = _time(ops.filter_match_auto, row_sk, q_sk)
    backend = jax.default_backend()
    dispatch = "pallas" if backend == "tpu" else "xla"
    common.emit(
        "kern/filter_match_auto_4096x256", dt_auto * 1e6,
        f"probes_per_s={probes/dt_auto:,.0f};backend_dispatch={backend}_{dispatch}"
    )


def engines():
    print("# engine comparison: SCI vs MATE(seq) vs MATE(batched)")
    queries = common.query_group(common.ROWS["webtable(100)"])
    idx = common.index("xash", 128)
    t_sci, _ = common.run_discovery(idx, queries, row_filter=False)
    t_seq, _ = common.run_discovery(idx, queries)
    t_bat, stb = common.run_discovery(idx, queries, engine="batched")
    n = len(queries)
    common.emit("engine/sci", t_sci / n * 1e6, "row_filter=off")
    common.emit("engine/mate_seq", t_seq / n * 1e6, f"vs_sci={t_sci/t_seq:.2f}x")
    common.emit(
        "engine/mate_batched", t_bat / n * 1e6,
        f"vs_sci={t_sci/t_bat:.2f}x;vs_seq={t_seq/t_bat:.2f}x"
    )


def main():
    kernels()
    engines()
    common.save_trajectory("kernels")


if __name__ == "__main__":
    main()
