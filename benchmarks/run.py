"""Benchmark harness: one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick]``
prints ``name,us_per_call,derived`` CSV rows (plus section comments), then a
roofline summary if dry-run results exist.
"""

from __future__ import annotations

import argparse
import sys

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="skip 512-bit builds")
    args = ap.parse_args()

    from benchmarks import (
        bench_figures, bench_fp_rate, bench_kernels, bench_tables, common,
    )

    if args.quick:
        bench_tables.HASHES_512 = []
        bench_tables.HASHES_128 = ["murmur", "ht", "bf", "xash"]
        bench_tables.ENGINE_512 = False

    print("name,us_per_call,derived")
    bench_tables.main()
    bench_figures.main()
    bench_kernels.main()
    # the width sweep exists to build 512-bit indexes — skipped entirely in
    # quick mode (run `benchmarks.bench_fp_rate --quick` directly for a
    # small-group 128/512 trend, as CI's bench job does)
    if not args.quick:
        bench_fp_rate.main([])

    # roofline summary (requires results/dryrun/*.json from the dry-run)
    try:
        from benchmarks import roofline

        cells = roofline.load_cells(variant="baseline")
        rows = [t for t in (roofline.terms(c) for c in cells) if t]
        if rows:
            by_dom = {}
            for r in rows:
                by_dom.setdefault(r["dominant"], []).append(r)
            for dom, rs in sorted(by_dom.items()):
                common.emit(
                    f"roofline/{dom}-bound-cells", 0.0,
                    f"count={len(rs)};median_frac="
                    f"{sorted(x['roofline_frac'] for x in rs)[len(rs)//2]:.3f}"
                )
    except Exception as e:  # dry-run not yet executed
        print(f"# roofline summary unavailable: {e}")


if __name__ == "__main__":
    main()
