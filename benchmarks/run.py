"""Benchmark harness: one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick]``
prints ``name,us_per_call,derived`` CSV rows (plus section comments), then a
roofline summary if dry-run results exist.

A section that raises is reported (traceback to stderr) and the remaining
sections still run, but the process exits NON-ZERO — CI's bench-regression
gate (tools/check_bench.py) must be able to trust that every row it compares
was actually produced, so a silently skipped section is a gate failure.
"""

from __future__ import annotations

import argparse
import sys
import traceback

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="skip 512-bit builds")
    args = ap.parse_args()

    from benchmarks import (
        bench_fd, bench_figures, bench_fp_rate, bench_kernels,
        bench_ranking, bench_tables, common,
    )

    if args.quick:
        bench_tables.HASHES_512 = []
        bench_tables.HASHES_128 = ["murmur", "ht", "bf", "xash"]
        bench_tables.ENGINE_512 = False

    failures: list[str] = []

    def section(name: str, fn) -> None:
        try:
            fn()
        except Exception:
            failures.append(name)
            # drop rows the failed section emitted but never saved, so they
            # can't leak into the NEXT section's BENCH_*.json trajectory
            common.ROWS_CSV = []
            print(f"# SECTION FAILED: {name}", flush=True)
            traceback.print_exc()

    # every row this process emits is stamped with ONE resolved backend —
    # announce it up front so a pasted CSV is self-describing too
    print(f"# filter_backend={common.resolved_backend()} (registry-resolved)")
    print("name,us_per_call,derived")
    section("tables", lambda: bench_tables.main([]))
    section("figures", bench_figures.main)
    section("kernels", bench_kernels.main)
    section("ranking", lambda: bench_ranking.main([]))
    section("fd", lambda: bench_fd.main([]))
    # the width sweep exists to build 512-bit indexes — skipped entirely in
    # quick mode (run `benchmarks.bench_fp_rate --quick` directly for a
    # small-group 128/512 trend, as CI's bench job does)
    if not args.quick:
        section("fp_rate", lambda: bench_fp_rate.main([]))

    # roofline summary (requires results/dryrun/*.json from the dry-run;
    # their absence is expected on hosts that never ran it — not a failure)
    try:
        from benchmarks import roofline

        cells = roofline.load_cells(variant="baseline")
        rows = [t for t in (roofline.terms(c) for c in cells) if t]
        if rows:
            by_dom = {}
            for r in rows:
                by_dom.setdefault(r["dominant"], []).append(r)
            for dom, rs in sorted(by_dom.items()):
                common.emit(
                    f"roofline/{dom}-bound-cells", 0.0,
                    f"count={len(rs)};median_frac="
                    f"{sorted(x['roofline_frac'] for x in rs)[len(rs)//2]:.3f}"
                )
    except Exception as e:  # dry-run not yet executed
        print(f"# roofline summary unavailable: {e}")

    if failures:
        print(f"# FAILED sections: {', '.join(failures)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
