"""Paper Table 1 (runtime) + Table 2 (precision) analogs, plus the
offline-phase build-time section (``index_build`` trajectory).

Runtime of top-k n-ary discovery per hash function / hash size, and
macro-averaged precision (mean ± std over queries), on the synthetic lake
calibrated to webtable statistics (power-law widths, ~12 PL items/value).

``--only index_build`` runs just the build section (what CI's bench job
gates through ``tools/check_bench.py``): single-host build time with
structural metrics (values/bytes hashed are seed-deterministic, gated
exactly) and a host-sharded build asserting byte-identity to the
single-host artifacts, with the merge-cost fraction gated so the shard
merge can never quietly grow superlinear.
"""

from __future__ import annotations

import argparse
import time

from benchmarks import common


HASHES_128 = ["md5", "murmur", "city", "simhash", "ht", "bf", "xash"]
HASHES_512 = ["simhash", "ht", "bf", "xash"]
# gates the 512-bit engine row in table_engines (run.py --quick clears it
# together with HASHES_512 to skip all 512-bit index builds)
ENGINE_512 = True


def table1_runtime():
    print("# Table 1 analog: discovery runtime (SCI baseline + hash variants)")
    out = {}
    for gname, n_rows in common.ROWS.items():
        queries = common.query_group(n_rows)
        idx_x = common.index("xash", 128)
        dt, st = common.run_discovery(idx_x, queries, row_filter=False)
        out[(gname, "sci", 128)] = (dt, st)
        common.emit(
            f"t1/{gname}/sci", dt / len(queries) * 1e6,
            f"precision={st['precision_mean']:.3f}"
        )
        for bits, hashes in ((128, HASHES_128), (512, HASHES_512)):
            for h in hashes:
                idx = common.index(h, bits)
                dt, st = common.run_discovery(idx, queries)
                out[(gname, h, bits)] = (dt, st)
                common.emit(
                    f"t1/{gname}/{h}({bits})", dt / len(queries) * 1e6,
                    f"precision={st['precision_mean']:.3f};fp={st['fp']}"
                )
        # headline ratios (paper: MATE up to 20x over SCI; XASH ≤2.2x over BF)
        sci_t = out[(gname, "sci", 128)][0]
        x_t = out[(gname, "xash", 128)][0]
        bf_t = out[(gname, "bf", 128)][0]
        common.emit(
            f"t1/{gname}/speedups", 0.0,
            f"mate_vs_sci={sci_t/x_t:.2f}x;xash_vs_bf={bf_t/x_t:.2f}x"
        )
    return out


def table_engines():
    """Beyond-paper §6.3 fast path: scalar Alg. 1 vs batched kernel-backed
    engine vs multi-query shared-launch batching (docs/ARCHITECTURE.md ADR)."""
    print("# Engine comparison: scalar vs batched (kernel) vs multi-query")
    out = {}
    for gname, n_rows in common.ROWS.items():
        queries = common.query_group(n_rows)
        idx = common.index("xash", 128)
        # warm up jit caches (full group: the multi-query launch shape
        # depends on the whole group) so we measure steady-state serving
        for engine in ("seq", "batched", "many"):
            common.run_discovery(idx, queries, engine=engine)
        times = {}
        for engine in ("seq", "batched", "batched_np", "many"):
            dt, st = common.run_discovery(idx, queries, engine=engine)
            times[engine] = dt
            out[(gname, engine)] = (dt, st)
            # per-batch transfer behaviour (device-side rule 1/2): fraction
            # of the match matrix materialised on the host — counts vector +
            # verification slices on the device path.  Undefined for the
            # scalar engine (no match matrix), so only batched/many rows
            # carry the field.
            rb = ""
            if st["matrix_bytes"]:
                rb = (
                    f";match_readback_frac="
                    f"{st['readback_bytes'] / st['matrix_bytes']:.3f}"
                )
            common.emit(
                f"engines/{gname}/{engine}", dt / len(queries) * 1e6,
                f"precision={st['precision_mean']:.3f};passed={st['passed']}{rb}",
                # batched_np pins the numpy oracle in code; the others follow
                # the process-level registry resolution
                backend="numpy" if engine == "batched_np" else None,
            )
        common.emit(
            f"engines/{gname}/speedups", 0.0,
            f"batched_vs_seq={times['seq']/times['batched']:.2f}x;"
            f"many_vs_seq={times['seq']/times['many']:.2f}x"
        )
        # 512-bit end-to-end engine path (16 lanes through the same kernels)
        if ENGINE_512:
            idx512 = common.index("xash", 512)
            common.run_discovery(idx512, queries, engine="batched")  # warm jit
            dt, st = common.run_discovery(idx512, queries, engine="batched")
            rb = st["readback_bytes"] / max(st["matrix_bytes"], 1)
            common.emit(
                f"engines/{gname}/batched(512)", dt / len(queries) * 1e6,
                f"precision={st['precision_mean']:.3f};passed={st['passed']};"
                f"match_readback_frac={rb:.3f};vs_128={times['batched']/dt:.2f}x"
            )
    return out


def index_build():
    """Offline phase (§4/§5) build-time rows — the ``index_build`` section.

    The sharded row uses the host-sharded path (4 shards, no device mesh):
    the same hash work plus the shard-merge bookkeeping, so
    ``sharded_vs_single`` isolates the merge/bookkeeping overhead and
    ``identical`` pins the byte-identity contract on every bench run.
    """
    from repro.core import xash
    from repro.core.index import build_index, index_artifacts_equal

    print("# index_build: offline-phase build time (single-host vs sharded merge)")
    c = common.corpus()
    cfg = xash.XashConfig(
        bits=128, char_freq=tuple(c.char_frequencies().tolist())
    )
    # warm the jit caches of both paths so the rows measure steady-state
    # hashing, not compile time (shard shapes differ from the single pass)
    build_index(c, cfg=cfg)
    build_index(c, cfg=cfg, n_shards=4)

    t0 = time.perf_counter()
    ref, st1 = build_index(c, cfg=cfg)
    dt_single = time.perf_counter() - t0
    common.emit(
        "build/xash(128)", dt_single * 1e6,
        f"values={st1.values_total};bytes_hashed={st1.bytes_hashed};"
        f"rows={st1.rows_total};"
        f"hash_frac={st1.hash_seconds / max(st1.total_seconds, 1e-9):.3f}",
    )
    t0 = time.perf_counter()
    idx4, st4 = build_index(c, cfg=cfg, n_shards=4)
    dt_sharded = time.perf_counter() - t0
    identical = index_artifacts_equal(idx4, ref)
    common.emit(
        "build/sharded_host(4)", dt_sharded * 1e6,
        f"identical={identical};"
        f"sharded_vs_single={dt_sharded / max(dt_single, 1e-9):.2f}x;"
        f"merge_frac={st4.merge_seconds / max(st4.total_seconds, 1e-9):.4f};"
        f"shards={st4.n_shards}",
    )
    if ENGINE_512:
        cfg512 = xash.XashConfig(
            bits=512, char_freq=tuple(c.char_frequencies().tolist())
        )
        build_index(c, cfg=cfg512)  # warm
        t0 = time.perf_counter()
        _idx, st = build_index(c, cfg=cfg512)
        dt = time.perf_counter() - t0
        common.emit(
            "build/xash(512)", dt * 1e6,
            f"values={st.values_total};bytes_hashed={st.bytes_hashed};"
            f"vs_128={dt / max(dt_single, 1e-9):.2f}x",
        )


N_DISTINCT = 24  # distinct query tables behind the Zipf traffic
N_REQUESTS = 160
ZIPF_S = 1.1  # skew exponent: rank-r query drawn with p ∝ 1/r^s


def serving():
    """Online serving tier under skewed traffic — the ``serving`` section.

    Zipf-distributed requests (a few hot query tables dominate, FREYJA-style)
    flow through ``serve.engine.DiscoveryEngine`` with both serving caches
    enabled.  Everything gated is seed-deterministic: the traffic, hence the
    result-cache hit count, hence the bound-cache replay count; latency
    percentiles are emitted for the trajectory but NOT gated (machine noise).
    ``bit_identical`` pins the serving tier's core contract on every bench
    run: cached answers are indistinguishable from a cold ``discover``.
    """
    import numpy as np

    from repro.core.batched import discover_batched
    from repro.core.session import DiscoveryConfig, MateSession
    from repro.data import synthetic
    from repro.serve.engine import DiscoveryEngine

    print("# serving: Zipf traffic through the cached DiscoveryEngine")
    idx = common.index("xash", 128)
    distinct = synthetic.make_mixed_queries(
        common.corpus(), N_DISTINCT, 10, 2, seed=common.SEED + 9
    )
    ranks = np.arange(1, N_DISTINCT + 1, dtype=np.float64)
    probs = ranks**-ZIPF_S
    probs /= probs.sum()
    rng = np.random.default_rng(common.SEED + 11)
    traffic = rng.choice(N_DISTINCT, size=N_REQUESTS, p=probs)

    # steady state: warm the filter path's compile caches outside the engine
    common.run_discovery(idx, distinct, engine="many")
    # cold ground truth per distinct query (computed outside the timed loop)
    def key(entries):
        return [(e.table_id, e.joinability, e.mapping) for e in entries]

    # the session serves at its default rank='quality' + profile gate, so
    # the cold reference must run the raw engine with the same knobs
    cold = {
        qi: key(
            discover_batched(
                idx, *distinct[qi], k=common.K,
                rank="quality", profile_gate=True,
            )[0]
        )
        for qi in sorted(set(traffic.tolist()))
    }

    session = MateSession(
        idx,
        DiscoveryConfig(
            k=common.K, window=4, flush_after=None, result_cache=64, bound_cache=64
        ),
    )
    eng = DiscoveryEngine(session=session)
    lat = []
    identical = True
    for qi in traffic:
        q, q_cols = distinct[qi]
        t0 = time.perf_counter()
        req = eng.discover(q, q_cols)
        lat.append(time.perf_counter() - t0)
        identical &= key(req.results) == cold[qi]
    lat_us = np.asarray(lat) * 1e6
    hits = session.stats.cache_hits
    common.emit(
        "serving/zipf(128)", float(lat_us.mean()),
        f"hits={hits};hit_rate={hits / N_REQUESTS:.4f};"
        f"bit_identical={int(identical)};requests={N_REQUESTS};"
        f"p50_us={np.percentile(lat_us, 50):.1f};"
        f"p99_us={np.percentile(lat_us, 99):.1f}",
    )

    # second wave: the SAME queries at a different k — the result cache
    # cannot answer (k is part of its key) but the bound cache replays
    # phase A, skipping gather_candidates + the filter launch per request
    seen = sorted(set(traffic.tolist()))
    lat2 = []
    identical2 = True
    for qi in seen:
        q, q_cols = distinct[qi]
        t0 = time.perf_counter()
        req = eng.discover(q, q_cols, k=5)
        lat2.append(time.perf_counter() - t0)
        identical2 &= key(req.results) == key(
            discover_batched(
                idx, q, q_cols, k=5, rank="quality", profile_gate=True
            )[0]
        )
    lat2_us = np.asarray(lat2) * 1e6
    common.emit(
        "serving/zipf_rek(128)", float(lat2_us.mean()),
        f"bound_hits={session.stats.bound_hits};distinct={len(seen)};"
        f"bound_identical={int(identical2)};"
        f"p50_us={np.percentile(lat2_us, 50):.1f}",
    )


def table2_precision():
    print("# Table 2 analog: precision mean±std")
    for gname, n_rows in common.ROWS.items():
        queries = common.query_group(n_rows)
        for bits, hashes in ((128, HASHES_128), (512, HASHES_512)):
            for h in hashes:
                idx = common.index(h, bits)
                _, st = common.run_discovery(idx, queries)
                common.emit(
                    f"t2/{gname}/{h}({bits})", 0.0,
                    f"precision={st['precision_mean']:.3f}±{st['precision_std']:.3f}"
                )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only", default=None, choices=["index_build", "serving"],
        help="run a single section (CI's bench job gates index_build and "
             "serving without paying for the full table sweep)",
    )
    args = ap.parse_args(argv)
    if args.only == "serving":
        serving()
        common.save_trajectory("serving")
        return
    index_build()
    common.save_trajectory("index_build")
    if args.only == "index_build":
        return
    table1_runtime()
    table_engines()
    table2_precision()
    common.save_trajectory("tables")
    serving()
    common.save_trajectory("serving")


if __name__ == "__main__":
    main()
