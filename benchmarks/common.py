"""Shared benchmark fixtures: corpus, query groups, index cache, timing."""

from __future__ import annotations

import sys
import time
from functools import lru_cache

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

import numpy as np

from repro.core import discovery, xash
from repro.core.batched import discover_batched
from repro.core.index import MateIndex
from repro.data import synthetic

SEED = 3
N_TABLES = 500
ROWS = {"webtable(10)": 10, "webtable(100)": 100}
N_QUERIES = 4
K = 10


@lru_cache(maxsize=1)
def corpus():
    return synthetic.make_corpus(
        synthetic.SyntheticSpec(n_tables=N_TABLES, seed=SEED)
    )


@lru_cache(maxsize=None)
def index(hash_name: str = "xash", bits: int = 128, **xash_kw):
    c = corpus()
    if hash_name == "xash":
        kw = dict(xash_kw)
        cfg = xash.XashConfig(
            bits=bits, char_freq=tuple(c.char_frequencies().tolist()), **kw
        )
        return MateIndex(c, cfg=cfg)
    return MateIndex(c, cfg=xash.XashConfig(bits=bits), hash_name=hash_name)


@lru_cache(maxsize=None)
def query_group(n_rows: int, key_width: int = 2):
    return tuple(
        synthetic.make_mixed_queries(
            corpus(), N_QUERIES, n_rows, key_width, seed=SEED + 2
        )
    )


def run_discovery(idx, queries, k=K, row_filter=True, engine="seq"):
    """Returns (seconds_total, aggregate stats)."""
    tp = fp = checks = passed = 0
    precs = []
    t0 = time.perf_counter()
    for q, q_cols in queries:
        if engine == "batched":
            # use_kernel=False: on CPU the Pallas interpret path adds per-call
            # overhead; the numpy filter is the fair wall-clock proxy here
            _, st = discover_batched(idx, q, q_cols, k=k, use_kernel=False)
        else:
            _, st = discovery.discover(idx, q, q_cols, k=k, row_filter=row_filter)
        tp += st.verified_tp
        fp += st.verified_fp
        checks += st.filter_checks
        passed += st.filter_passed
        precs.append(st.precision)
    dt = time.perf_counter() - t0
    return dt, {
        "tp": tp,
        "fp": fp,
        "checks": checks,
        "passed": passed,
        "precision_mean": float(np.mean(precs)),
        "precision_std": float(np.std(precs)),
    }


ROWS_CSV = []


def emit(name: str, us_per_call: float, derived: str):
    ROWS_CSV.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")
