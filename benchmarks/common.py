"""Shared benchmark fixtures: corpus, query groups, index cache, timing,
and ``BENCH_*.json`` trajectory persistence (see docs/BENCHMARKS.md)."""

from __future__ import annotations

import json
import os
import sys
import time
from functools import lru_cache

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

import numpy as np

from repro.core import discovery, xash
from repro.core.batched import discover_batched, discover_many, filter_outcomes
from repro.core.corpus import Corpus, Table
from repro.core.index import MateIndex
from repro.data import synthetic
from repro.kernels import registry

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def resolved_backend() -> str:
    """The registry-resolved filter backend this bench process runs under.

    Stamped into every trajectory row so ``tools/check_bench.py`` can refuse
    to compare runs recorded under different backends (a baseline recorded
    on the fused path must not be "regressed" by a composed-path run).
    """
    return registry.resolve_backend().name

SEED = 3
N_TABLES = 500
ROWS = {"webtable(10)": 10, "webtable(100)": 100}
N_QUERIES = 4
K = 10


@lru_cache(maxsize=1)
def corpus():
    return synthetic.make_corpus(
        synthetic.SyntheticSpec(n_tables=N_TABLES, seed=SEED)
    )


@lru_cache(maxsize=None)
def index(hash_name: str = "xash", bits: int = 128, **xash_kw):
    c = corpus()
    if hash_name == "xash":
        kw = dict(xash_kw)
        cfg = xash.XashConfig(
            bits=bits, char_freq=tuple(c.char_frequencies().tolist()), **kw
        )
        return MateIndex(c, cfg=cfg)
    return MateIndex(c, cfg=xash.XashConfig(bits=bits), hash_name=hash_name)


@lru_cache(maxsize=None)
def routed_index(n_shards: int = 4, bits: int = 128):
    """Routed lake over the bench corpus: per-shard ownership, shard-local
    launches, count-only merge (``core.routing.ShardedMateIndex``)."""
    from repro.core.routing import ShardedMateIndex

    c = corpus()
    cfg = xash.XashConfig(
        bits=bits, char_freq=tuple(c.char_frequencies().tolist())
    )
    return ShardedMateIndex(c, cfg=cfg, n_shards=n_shards)


@lru_cache(maxsize=None)
def query_group(n_rows: int, key_width: int = 2):
    return tuple(
        synthetic.make_mixed_queries(
            corpus(), N_QUERIES, n_rows, key_width, seed=SEED + 2
        )
    )


def planted_quality_lake(
    n_keys: int = 20,
    n_good: int = 10,
    n_bad: int = 10,
    n_narrow: int = 10,
    n_noise: int = 30,
    noise_seed: int = 11,
):
    """Deterministic lake separating count rank from quality rank
    (``bench_ranking``'s planted lake, shared so other sections can reuse
    the shape).  Returns (corpus, query, q_cols, good_ids):

      * ``good`` tables hold each composite key exactly once — joinability
        ``n_keys``, uniqueness ~1.0;
      * ``bad`` tables hold the same keys plus repeated filler rows — the
        SAME joinability, uniqueness ~0.2; good/bad ids interleave so count
        rank alternates the classes;
      * ``narrow`` 1-column tables hold the init-column values — posting
        candidates that can never host a width-2 key (profile-gate fodder);
      * ``noise`` tables come from the seeded synthetic generator.
    """
    keys = [(f"pkA{r:02d}", f"pkB{r:02d}") for r in range(n_keys)]
    query = Table(
        -1, [[a, b, f"qx{r:02d}"] for r, (a, b) in enumerate(keys)]
    )
    tables: list[Table] = []
    good_ids: set[int] = set()
    # good/bad interleaved: even ids good, odd ids bad
    for i in range(n_good + n_bad):
        tid = len(tables)
        cells = [[a, b, f"t{tid}v{r}"] for r, (a, b) in enumerate(keys)]
        if i % 2:  # bad: dilute every column with repeated filler rows
            cells += [[f"pad{tid}", f"pad{tid}", f"pad{tid}"]] * (4 * n_keys)
        else:
            good_ids.add(tid)
        tables.append(Table(tid, cells))
    for _ in range(n_narrow):  # candidates the gate must prune
        tid = len(tables)
        tables.append(Table(tid, [[a] for a, _b in keys]))
    noise = synthetic.make_corpus(
        synthetic.SyntheticSpec(n_tables=n_noise, seed=noise_seed)
    )
    for t in noise.tables:
        tables.append(Table(len(tables), t.cells))
    return Corpus(tables), query, [0, 1], good_ids


def fp_outcomes(idx, queries, check_false_negatives: bool = False) -> dict:
    """Aggregate unpruned §6.3 filter outcomes over a query group.

    Sums ``core.batched.filter_outcomes`` per query and derives ``fp_rate``
    (false positives per eligible probe) — the Table 1/2 quantity the
    hash-width sweep in ``bench_fp_rate.py`` tracks.
    """
    agg = {"checks": 0, "passed": 0, "tp": 0, "fp": 0, "fn": 0}
    for q, q_cols in queries:
        out = filter_outcomes(
            idx, q, q_cols, check_false_negatives=check_false_negatives
        )
        for key in agg:
            agg[key] += out[key]
    agg["fp_rate"] = agg["fp"] / max(agg["checks"], 1)
    return agg


def run_discovery(idx, queries, k=K, row_filter=True, engine="seq"):
    """Returns (seconds_total, aggregate stats).

    Engines: ``seq`` (faithful Alg. 1), ``batched`` (kernel-backed blocks,
    registry-resolved backend: Pallas on TPU / XLA fallback on CPU),
    ``batched_np`` (same engine, backend='numpy'), ``many`` (all queries
    share one filter launch — the DiscoveryEngine path), plus
    ``batched_fused`` / ``many_fused`` (backend='fused': the fused
    filter+segment-count kernel — counts-only readback, zero match-matrix
    bytes), and ``batched_gather`` / ``many_gather`` (backend='fused-gather':
    the gather-fused launch — candidate superkeys are DMA-gathered from the
    device-resident store inside the kernel, so the host ships only int32
    row offsets; ``gather_saved`` below counts the bytes that never moved).
    """
    tp = fp = checks = passed = 0
    mat_bytes = rb_bytes = 0
    precs = []
    t0 = time.perf_counter()
    if engine in ("many", "many_fused", "many_gather"):
        many_backend = {"many_fused": "fused", "many_gather": "fused-gather"}
        stats = [
            st
            for _, st in discover_many(
                idx,
                [(q, c) for q, c in queries],
                k=k,
                backend=many_backend.get(engine),
            )
        ]
    else:
        stats = []
        for q, q_cols in queries:
            if engine == "batched":
                _, st = discover_batched(idx, q, q_cols, k=k)
            elif engine == "batched_fused":
                _, st = discover_batched(idx, q, q_cols, k=k, backend="fused")
            elif engine == "batched_gather":
                _, st = discover_batched(
                    idx, q, q_cols, k=k, backend="fused-gather"
                )
            elif engine == "batched_np":
                _, st = discover_batched(idx, q, q_cols, k=k, backend="numpy")
            else:
                _, st = discovery.discover(idx, q, q_cols, k=k, row_filter=row_filter)
            stats.append(st)
    dt = time.perf_counter() - t0
    fused_launches = 0
    gather_saved = 0
    shard_launches = 0
    route_bytes = 0
    items_checked = 0
    for st in stats:
        tp += st.verified_tp
        fp += st.verified_fp
        checks += st.filter_checks
        passed += st.filter_passed
        mat_bytes += st.filter_matrix_bytes
        rb_bytes += st.filter_readback_bytes
        fused_launches += st.filter_fused_launches
        gather_saved += st.gather_bytes_saved
        shard_launches += st.shard_launches
        route_bytes += st.route_bytes_merged
        items_checked += st.pl_items_checked
        precs.append(st.precision)
    return dt, {
        "tp": tp,
        "fp": fp,
        "checks": checks,
        "passed": passed,
        "matrix_bytes": mat_bytes,
        "readback_bytes": rb_bytes,
        "fused_launches": fused_launches,
        "gather_saved": gather_saved,
        "shard_launches": shard_launches,
        "route_bytes": route_bytes,
        "items_checked": items_checked,
        "precision_mean": float(np.mean(precs)),
        "precision_std": float(np.std(precs)),
    }


ROWS_CSV = []


def emit(name: str, us_per_call: float, derived: str, backend: str | None = None):
    """Record one bench row.  ``backend`` overrides the row's backend stamp
    for rows that PIN a backend in code (``engine='batched_fused'`` and
    friends) rather than following the process-level registry resolution —
    the stamp must describe what the row actually ran."""
    ROWS_CSV.append((name, us_per_call, derived, backend))
    print(f"{name},{us_per_call:.1f},{derived}")


def save_trajectory(section: str) -> str:
    """Append this run's rows to ``benchmarks/results/BENCH_<section>.json``.

    Each file is a JSON list of run records ({"ts", "backend", "rows"}) so
    successive runs accumulate a perf trajectory; rows emitted since the
    last save are consumed.  Every row (and the record itself) carries the
    registry-resolved filter backend, so downstream comparisons
    (``tools/check_bench.py``, ``tools/plot_bench.py``) can tell apart runs
    recorded under different dispatch paths.  Returns the file path.
    """
    global ROWS_CSV
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"BENCH_{section}.json")
    history = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            history = []
    backend = resolved_backend()
    history.append({
        "ts": time.time(),
        "backend": backend,
        "rows": [
            {"name": n, "us_per_call": us, "derived": d, "backend": bk or backend}
            for n, us, d, bk in ROWS_CSV
        ],
    })
    with open(path, "w") as f:
        json.dump(history, f, indent=1)
    ROWS_CSV = []
    return path
