"""Paper Figures 5-8 analogs: top-k sweep, XASH component ablation,
key-size scaling, initial-column selection."""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import discovery


def fig5_topk():
    print("# Fig 5 analog: precision vs k")
    queries = common.query_group(common.ROWS["webtable(100)"])
    for h in ("xash", "bf", "ht"):
        idx = common.index(h, 128)
        for k in (2, 5, 10, 20):
            _, st = common.run_discovery(idx, queries, k=k)
            common.emit(
                f"f5/{h}/k={k}", 0.0, f"precision={st['precision_mean']:.3f}"
            )


def fig6_ablation():
    print("# Fig 6 analog: XASH component ablation")
    queries = common.query_group(common.ROWS["webtable(100)"])
    variants = [
        ("char", dict(use_location=False, use_length=False, use_rotation=False)),
        ("char+len", dict(use_location=False, use_length=True, use_rotation=False)),
        ("char+len+loc", dict(use_location=True, use_length=True, use_rotation=False)),
        ("xash(full)", dict()),
    ]
    for name, kw in variants:
        idx = common.index("xash", 128, **kw)
        _, st = common.run_discovery(idx, queries)
        common.emit(
            f"f6/{name}", 0.0,
            f"precision={st['precision_mean']:.3f};fp={st['fp']}"
        )


def fig7_keysize():
    print("# Fig 7 analog: composite-key width 2..5")
    for width in (2, 3, 4, 5):
        queries = common.query_group(40, key_width=width)
        idx = common.index("xash", 128)
        dt, st = common.run_discovery(idx, queries)
        common.emit(
            f"f7/xash/|Q|={width}", dt / max(len(queries), 1) * 1e6,
            f"precision={st['precision_mean']:.3f};fp={st['fp']}"
        )


def fig8_initcol():
    print("# Fig 8 analog: initial-column strategy → PL items fetched")
    queries = common.query_group(common.ROWS["webtable(100)"])
    idx = common.index("xash", 128)
    for mode in ("cardinality", "order", "tls", "best", "worst"):
        fetched = []
        for q, q_cols in queries:
            col = discovery.init_column_selection(q, q_cols, mode, idx)
            fetched.append(
                sum(len(idx.fetch_postings(v)) for v in set(q.column(col)))
            )
        common.emit(f"f8/{mode}", 0.0, f"avg_pl_items={np.mean(fetched):.1f}")


def main():
    fig5_topk()
    fig6_ablation()
    fig7_keysize()
    fig8_initcol()
    common.save_trajectory("figures")


if __name__ == "__main__":
    main()
