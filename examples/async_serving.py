"""Asyncio serving-tier example: bounded queue, admission control, caches.

    PYTHONPATH=src python examples/async_serving.py [--requests 60]

Zipf-skewed discovery traffic (a few hot query tables dominate) flows
through ``AsyncDiscoveryEngine`` — a background pump task groups requests
into shared filter launches, while the serving tier in front of it does the
work of a production deployment:

  * a BOUNDED submit queue with admission control: under pressure requests
    are shed (``AdmissionError``) or degraded to 128-bit filtering — a pure
    relaxation, so degraded answers stay bit-identical;
  * a query-result cache answering repeated queries at submit time and a
    hot-table bound cache that skips gather+filter for warm queries at any
    ``k`` — both invalidated the moment a §5.4 index mutation lands.
"""

import argparse
import asyncio
import sys
import time

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

import numpy as np

from repro.core.session import DiscoveryConfig, MateSession
from repro.data import synthetic
from repro.serve.engine import AdmissionError, AsyncDiscoveryEngine


async def run(args) -> None:
    corpus = synthetic.make_corpus(
        synthetic.SyntheticSpec(n_tables=args.n_tables, seed=3)
    )
    session = MateSession.build(
        corpus,
        DiscoveryConfig(
            k=5,
            window=args.window,
            flush_after=args.flush_after,
            max_queue=args.max_queue,
            pressure_policy=args.pressure_policy,
            result_cache=64,
            bound_cache=64,
        ),
    )
    print(f"lake: {corpus.total_rows} rows; {session}")

    distinct = synthetic.make_mixed_queries(corpus, 12, 10, 2, seed=10)
    rng = np.random.default_rng(7)
    probs = np.arange(1, len(distinct) + 1, dtype=np.float64) ** -1.1
    probs /= probs.sum()
    traffic = rng.choice(len(distinct), size=args.requests, p=probs)

    lat: list[float] = []
    shed = 0

    async def one(qi: int, eng: AsyncDiscoveryEngine) -> None:
        nonlocal shed
        q, q_cols = distinct[qi]
        t0 = time.perf_counter()
        try:
            await eng.discover_async(q, q_cols)
        except AdmissionError:
            shed += 1  # bounded queue at capacity: rejected, not hung
            return
        lat.append(time.perf_counter() - t0)

    async with AsyncDiscoveryEngine(session=session) as eng:
        # waves, not one burst: the first wave primes the caches (and shows
        # admission control under the burst), later waves repeat the hot
        # queries and resolve straight from the result cache at submit
        wave = max(args.window * 3, 12)
        for i in range(0, len(traffic), wave):
            await asyncio.gather(
                *(one(int(qi), eng) for qi in traffic[i : i + wave])
            )

        st = session.stats
        lat_us = np.asarray(lat) * 1e6
        print(
            f"served {len(lat)}/{args.requests} "
            f"(cache_hits={st.cache_hits}, bound_hits={st.bound_hits}, "
            f"shed={st.shed}, degraded={st.degraded}, "
            f"pump_errors={eng.pump_errors})"
        )
        if len(lat):
            print(
                f"latency: p50={np.percentile(lat_us, 50):.0f}us "
                f"p99={np.percentile(lat_us, 99):.0f}us"
            )

        # §5.4 invalidation: a mutation bumps the index epoch, so the next
        # request for a hot query re-discovers instead of replaying a stale
        # top-k — correctness over hit rate, always.
        hot_q, hot_cols = distinct[0]
        hits_before = st.cache_hits
        session.insert_table([[r[c] for c in hot_cols] for r in hot_q.cells])
        req = await eng.discover_async(hot_q, hot_cols)
        print(
            f"after insert_table: from_cache={req.from_cache} "
            f"(hits {hits_before} -> {st.cache_hits}) — the mutation "
            f"invalidated every cached entry"
        )
        assert not req.from_cache


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--n-tables", type=int, default=120)
    ap.add_argument("--window", type=int, default=4)
    ap.add_argument("--flush-after", type=float, default=0.02)
    ap.add_argument("--max-queue", type=int, default=8)
    ap.add_argument("--pressure-policy", default="degrade",
                    choices=["shed", "degrade"])
    args = ap.parse_args()
    asyncio.run(run(args))


if __name__ == "__main__":
    main()
