"""Distributed MATE: the paper's filter as a mesh-sharded workload.

Opens a ``MateSession`` on a synthetic lake, shards its super keys over a
device mesh, replicates the query keys, and runs the subsumption filter +
per-table candidate counting with psum — the layout that scales the online
phase to pod-sized corpora (EXPERIMENTS.md §Roofline rows 'mate-filter').
The per-shard filter impl resolves from the SAME backend registry the
session uses (a 'fused' backend runs the fused per-shard Pallas launch).
On CPU this runs on a 1x1 mesh; the same code lowers for 16x16 / 2x16x16
in the dry-run.

    PYTHONPATH=src python examples/distributed_discovery.py
"""

import sys
import time

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

import numpy as np

from repro.core import discovery, distributed
from repro.core.session import DiscoveryConfig, MateSession
from repro.data import synthetic
from repro.launch import mesh as meshlib


def main():
    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=600, seed=11))
    session = MateSession.build(corpus, DiscoveryConfig(k=10))
    queries = synthetic.make_mixed_queries(corpus, 3, 30, 2, seed=12)
    print(f"lake: {corpus.total_rows} rows / {len(corpus.tables)} tables; {session}")

    # host engine for reference
    q, q_cols = queries[0]
    topk, stats = session.discover(q, q_cols)
    print(f"batched engine top-3: {[(e.table_id, e.joinability) for e in topk[:3]]} "
          f"(precision {stats.precision:.3f})")

    # mesh-sharded filter, impl resolved from the session's backend
    index = session.index
    mesh = meshlib.make_mesh((1, 1), ("data", "model"))
    row_tables = np.asarray(
        corpus.table_of_row(np.arange(corpus.total_rows)), dtype=np.int32
    )
    sk, rt = distributed.shard_corpus_rows(
        index.superkeys, row_tables, mesh, ("data",)
    )
    _keys, sk_of_key = discovery.build_query_superkeys(index, q, q_cols)
    qsk = np.stack(list(sk_of_key.values()))
    filt = distributed.make_distributed_filter(
        mesh, len(corpus.tables), ("data",), backend=session.backend
    )
    t0 = time.time()
    table_counts, key_counts = filt(sk, rt, qsk)
    table_counts.block_until_ready()
    tc = np.asarray(table_counts)
    print(f"distributed filter (impl="
          f"{distributed.shard_impl_for(session.backend)}): {tc.sum()} candidate "
          f"rows in {(tc > 0).sum()} tables ({time.time()-t0:.3f}s on mesh "
          f"{dict(zip(mesh.axis_names, mesh.devices.shape))})")
    top_tables = np.argsort(-tc)[:5]
    print(f"most candidate-dense tables: {[(int(t), int(tc[t])) for t in top_tables]}")


if __name__ == "__main__":
    main()
