"""End-to-end driver: MATE discovery → dataset enrichment → LM training.

The paper's own motivation (§1): enrich a base dataset with joinable tables
from a lake, then use it for downstream ML.  This driver runs the full loop:

  1. build a synthetic lake + index it (offline phase);
  2. enrich a base table via top-k n-ary join discovery (online phase);
  3. tokenise the enriched records and train a decoder LM on them, with
     checkpointing/auto-resume.

CPU-sized by default (~2M params, 120 steps — a few minutes).  On a real pod
the same code trains the full configs: ``--arch qwen1.5-0.5b --full``.

    PYTHONPATH=src python examples/enrich_and_train.py [--steps 120]
"""

import argparse
import sys
import time

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.ckpt.manager import CheckpointManager
from repro.core.corpus import Corpus, Table
from repro.core.session import DiscoveryConfig, MateSession
from repro.data import synthetic
from repro.data.enrichment import enrich, tokenize_records
from repro.models import params as params_lib, transformer
from repro.train import optimizer as opt, step as step_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--full", action="store_true", help="full-size config (TPU)")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    # ---- 1. lake + index ----
    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=150, seed=7))
    base_cells = [[f"entity{i}", f"city{i % 23}", "payload"] for i in range(64)]
    feat = [[f"entity{i}", f"city{i % 23}", f"income {i*13%997}", f"region {i%7}"]
            for i in range(64)]
    corpus.tables.append(Table(len(corpus.tables), feat))
    corpus = Corpus(corpus.tables)
    session = MateSession.build(corpus, DiscoveryConfig(k=5))
    print(f"[1] lake indexed: {corpus.total_rows} rows "
          f"(backend={session.backend.name})")

    # ---- 2. enrichment via MATE ----
    base = Table(-1, base_cells)
    enriched, prov = enrich(session, base, key_cols=[0, 1], k=5)
    print(f"[2] enriched {base.n_cols} -> {enriched.n_cols} cols; provenance:")
    for p in prov:
        print(f"    table {p['table_id']}: j={p['joinability']} "
              f"+{p['new_cols']} cols, {p['hit_rows']} rows hit")

    # ---- 3. train an LM on the enriched records ----
    cfg = configs.get_config(args.arch)
    if not args.full:
        cfg = configs.reduce_config(cfg)
    tokens_all = tokenize_records(enriched, cfg.vocab_size, args.seq_len)
    print(f"[3] training {cfg.name}: {cfg.params_count()['total']/1e6:.1f}M params "
          f"on {tokens_all.shape[0]} records")

    specs = transformer.model_specs(cfg)
    params = params_lib.materialize(specs, jax.random.PRNGKey(0))
    tcfg = step_lib.TrainConfig(
        adamw=opt.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps),
        ce_chunk=args.seq_len,
    )
    state = opt.init_state(params, tcfg.adamw)
    tstep = jax.jit(step_lib.make_train_step(cfg, tcfg), donate_argnums=(0, 1))

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    rng = np.random.default_rng(0)
    t0, losses = time.time(), []
    for step in range(args.steps):
        idx = rng.integers(0, tokens_all.shape[0], size=args.batch)
        toks = jnp.asarray(tokens_all[idx])
        batch = {
            "tokens": toks,
            "labels": jnp.concatenate(
                [toks[:, 1:], -jnp.ones((args.batch, 1), jnp.int32)], axis=1
            ),
        }
        params, state, m = tstep(params, state, batch)
        losses.append(float(m["loss"]))
        if step % 20 == 0 or step == args.steps - 1:
            print(f"    step {step:4d} loss {losses[-1]:.4f}")
        if mgr and step % 50 == 49:
            mgr.save(step + 1, {"params": params, "opt": state})
    dt = time.time() - t0
    print(f"[3] done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({args.steps/dt:.1f} steps/s)")
    assert losses[-1] < losses[0], "training should reduce loss"


if __name__ == "__main__":
    main()
