"""Quickstart: MATE in five minutes.

Builds a small synthetic data lake, opens a ``MateSession`` on it (one
frozen ``DiscoveryConfig``, one resolved filter backend), runs top-k
multi-attribute join discovery, and shows the filtering statistics the
paper is about.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

from repro.core.session import DiscoveryConfig, MateSession
from repro.data import synthetic


def main():
    # 1. a synthetic "data lake" with webtable-like statistics
    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=200, seed=0))
    print(f"lake: {len(corpus.tables)} tables, {corpus.total_rows} rows, "
          f"{len(corpus.unique_values)} unique values")

    # 2. a query table with a 2-column composite key, with known joins
    query, q_cols, expected, corpus = synthetic.make_query_with_ground_truth(
        corpus, n_rows=20, key_width=2, n_joinable_tables=6
    )

    # 3. offline phase: ONE config object, ONE session — the session builds
    #    the inverted index + XASH super keys and resolves the filter
    #    backend (config > MATE_FILTER_BACKEND env var > platform default)
    config = DiscoveryConfig(bits=128, k=5)
    session = MateSession.build(corpus, config)
    print(f"indexed with {session.bits}-bit XASH "
          f"(c={session.index.cfg.c}, ones={session.index.cfg.ones}); "
          f"filter backend: {session.backend.name} "
          f"[resolved from {session.backend.source}]")

    # 4. online phase: top-k n-ary join discovery (batched Algorithm 1 —
    #    bit-identical to the faithful scalar engine in core/discovery.py)
    topk, stats = session.discover(query, q_cols)
    print("\ntop-5 joinable tables (table_id, joinability, column mapping):")
    for e in topk:
        print(f"  table {e.table_id:4d}  j={e.joinability:3d}  mapping={e.mapping}")
    print(f"\nexpected ≥: {dict(sorted(expected.items(), key=lambda kv: -kv[1])[:5])}")
    print(
        f"stats: {stats.pl_items_total} PL items fetched, "
        f"{stats.filter_checks} super-key probes, "
        f"{stats.filter_passed} passed, precision={stats.precision:.3f}, "
        f"rule1-pruned={stats.tables_pruned_rule1} tables"
    )
    print(f"session: {session}")


if __name__ == "__main__":
    main()
