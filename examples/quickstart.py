"""Quickstart: MATE in five minutes.

Builds a small synthetic data lake, indexes it with XASH super keys, runs
top-k multi-attribute join discovery, and shows the filtering statistics the
paper is about.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

from repro.core import discovery
from repro.core.index import MateIndex
from repro.data import synthetic


def main():
    # 1. a synthetic "data lake" with webtable-like statistics
    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=200, seed=0))
    print(f"lake: {len(corpus.tables)} tables, {corpus.total_rows} rows, "
          f"{len(corpus.unique_values)} unique values")

    # 2. offline phase: inverted index + XASH super keys
    index = MateIndex(corpus, use_corpus_char_freq=True)
    print(f"indexed with {index.cfg.bits}-bit XASH "
          f"(c={index.cfg.c}, ones={index.cfg.ones})")

    # 3. a query table with a 2-column composite key, with known joins
    query, q_cols, expected, corpus2 = synthetic.make_query_with_ground_truth(
        corpus, n_rows=20, key_width=2, n_joinable_tables=6
    )
    index = MateIndex(corpus2, use_corpus_char_freq=True)  # rebuilt post-injection

    # 4. online phase: top-k n-ary join discovery (Algorithm 1)
    topk, stats = discovery.discover(index, query, q_cols, k=5)
    print("\ntop-5 joinable tables (table_id, joinability, column mapping):")
    for e in topk:
        print(f"  table {e.table_id:4d}  j={e.joinability:3d}  mapping={e.mapping}")
    print(f"\nexpected ≥: {dict(sorted(expected.items(), key=lambda kv: -kv[1])[:5])}")
    print(
        f"stats: {stats.pl_items_total} PL items fetched, "
        f"{stats.filter_checks} super-key probes, "
        f"{stats.filter_passed} passed, precision={stats.precision:.3f}, "
        f"rule1-pruned={stats.tables_pruned_rule1} tables"
    )


if __name__ == "__main__":
    main()
