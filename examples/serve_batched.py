"""Batched serving example: slot-batched prefill+decode with the engine.

    PYTHONPATH=src python examples/serve_batched.py [--arch mamba2-1.3b]

Runs the reduced config of any assigned architecture (attention KV caches,
MLA latent caches and SSM states all flow through the same cache pytree).
"""

import argparse
import sys
import time

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

import jax
import numpy as np

from repro import configs
from repro.data.pipeline import stub_inputs
from repro.models import params as params_lib, transformer
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = configs.reduce_config(configs.get_config(args.arch))
    params = params_lib.materialize(
        transformer.model_specs(cfg), jax.random.PRNGKey(0)
    )
    engine = ServeEngine(
        params, cfg, batch=args.batch, max_seq=64,
        temperature=args.temperature, extra_inputs=stub_inputs(cfg, args.batch),
    )
    rng = np.random.default_rng(1)
    reqs = [
        Request(prompt=list(rng.integers(2, cfg.vocab_size, rng.integers(3, 12))),
                max_new=args.max_new)
        for _ in range(args.requests)
    ]
    t0 = time.time()
    done = engine.generate(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in done)
    print(f"{cfg.name}: {len(done)} requests, {n_tok} new tokens, "
          f"{n_tok/dt:.1f} tok/s (CPU, reduced config)")
    for i, r in enumerate(done[:4]):
        print(f"  req{i}: prompt={r.prompt[:5]}... -> {r.out}")


if __name__ == "__main__":
    main()
