"""Batched serving example: LLM decode ticks interleaved with MATE discovery.

    PYTHONPATH=src python examples/serve_batched.py [--arch mamba2-1.3b]

Two request classes share one host loop, the shape the async-serve roadmap
item targets:

  * token generation — slot-batched prefill+decode (``ServeEngine``) for the
    reduced config of any assigned architecture (attention KV caches, MLA
    latent caches and SSM states all flow through the same cache pytree);
  * join discovery — a ``DiscoveryEngine`` over a ``MateSession``: requests
    queue with an arrival-window policy (group size ``--disc-batch``,
    deadline ``--flush-after``) and the loop calls ``pump()`` between decode
    ticks, so a discovery group launches the moment its window fills or its
    deadline expires — without stalling decode while the window is open.
"""

import argparse
import sys
import time

sys.path.insert(0, __file__.rsplit("/", 2)[0] + "/src")

import jax
import numpy as np

from repro import configs
from repro.core.session import DiscoveryConfig, MateSession
from repro.data import synthetic
from repro.data.pipeline import stub_inputs
from repro.models import params as params_lib, transformer
from repro.serve.engine import DiscoveryEngine, Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--disc-requests", type=int, default=6)
    ap.add_argument("--disc-batch", type=int, default=4)
    ap.add_argument("--flush-after", type=float, default=0.05)
    args = ap.parse_args()

    # ---- discovery side: one session over a synthetic lake ----
    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=120, seed=9))
    session = MateSession.build(
        corpus,
        DiscoveryConfig(k=5, window=args.disc_batch, flush_after=args.flush_after),
    )
    disc = DiscoveryEngine(session=session)
    disc_queries = synthetic.make_mixed_queries(
        corpus, args.disc_requests, 12, 2, seed=10
    )
    print(f"lake: {corpus.total_rows} rows; {session}")

    # ---- LLM side: slot-batched decode ----
    cfg = configs.reduce_config(configs.get_config(args.arch))
    params = params_lib.materialize(
        transformer.model_specs(cfg), jax.random.PRNGKey(0)
    )
    engine = ServeEngine(
        params, cfg, batch=args.batch, max_seq=64,
        temperature=args.temperature, extra_inputs=stub_inputs(cfg, args.batch),
    )
    rng = np.random.default_rng(1)
    reqs = [
        Request(prompt=list(rng.integers(2, cfg.vocab_size, rng.integers(3, 12))),
                max_new=args.max_new)
        for _ in range(args.requests)
    ]

    # interleave: submit a discovery request every other decode tick and
    # pump the discovery engine after every tick — groups launch when the
    # window fills or the oldest request's deadline expires, decode never
    # waits on an open window.
    disc_iter = iter(disc_queries)
    disc_served = 0

    def tick(step: int) -> None:
        nonlocal disc_served
        if step % 2 == 0:
            nxt = next(disc_iter, None)
            if nxt is not None:
                disc.submit(nxt[0], nxt[1])
        disc_served += len(disc.pump())

    engine.on_tick = tick  # ServeEngine calls this between decode steps
    t0 = time.time()
    done = engine.generate(reqs)
    disc_served += len(disc.flush())  # drain any open window at shutdown
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in done)
    print(f"{cfg.name}: {len(done)} requests, {n_tok} new tokens, "
          f"{n_tok/dt:.1f} tok/s (CPU, reduced config)")
    for i, r in enumerate(done[:4]):
        print(f"  req{i}: prompt={r.prompt[:5]}... -> {r.out}")
    print(f"discovery: {disc_served}/{len(disc_queries)} requests served "
          f"between decode ticks (window={disc.batch}, "
          f"flush_after={disc.flush_after}s, backend={session.backend.name}); "
          f"precision={session.stats.precision:.3f}")


if __name__ == "__main__":
    main()
