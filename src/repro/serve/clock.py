"""Clocks for the asyncio serving tier — real and deterministic.

``AsyncDiscoveryEngine``'s pump task does exactly two time-dependent
things: read "now" (deadline checks) and sleep until "a submit arrives OR
the next group deadline".  Both are factored behind a clock object so the
entire serving tier runs under a fake clock in tests:

  * ``SystemClock`` — ``time.monotonic`` + ``asyncio.wait_for``; production.
  * ``ManualClock`` — VIRTUAL time that only moves when the test calls
    ``advance``/``advance_to``.  Waiters register a (deadline, event) pair;
    advancing past a deadline releases its waiter.  No real sleeping, no
    wall-clock flake: a test drives arrival order, deadline expiry and
    pump wake-ups cycle-by-cycle (``tests/test_serving.py``).

Both expose ``now() -> float`` and ``async wait(event, timeout) -> bool``
(True iff the event fired before the timeout).  The plain synchronous
``DiscoveryEngine`` needs only ``now`` — pass ``ManualClock().now`` as its
``clock=``.
"""

from __future__ import annotations

import asyncio
import heapq
import itertools
import time


class SystemClock:
    """Wall clock: ``time.monotonic`` now, real asyncio sleeps."""

    def now(self) -> float:
        return time.monotonic()

    async def wait(self, event: asyncio.Event, timeout: float | None = None) -> bool:
        if timeout is None:
            await event.wait()
            return True
        if timeout <= 0:
            await asyncio.sleep(0)
            return event.is_set()
        try:
            await asyncio.wait_for(event.wait(), timeout)
            return True
        except asyncio.TimeoutError:
            return False


class ManualClock:
    """Deterministic virtual clock for serving-tier tests.

    ``now`` returns virtual time; ``wait`` parks the caller until the event
    fires or virtual time passes ``now + timeout`` — which only happens when
    the test calls ``advance``/``advance_to``.  Advancing releases every
    waiter whose virtual deadline passed (in deadline order), then returns;
    the released coroutines run on the next event-loop cycle, so tests
    interleave clock advances with ``asyncio.sleep(0)`` yields to step the
    pump deterministically.
    """

    def __init__(self, start: float = 0.0):
        self._t = float(start)
        self._seq = itertools.count()  # tie-break so heap never compares Events
        self._sleepers: list[tuple[float, int, asyncio.Event]] = []

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        self.advance_to(self._t + dt)

    def advance_to(self, t: float) -> None:
        if t < self._t:
            raise ValueError(f"virtual time cannot go backwards: {t} < {self._t}")
        self._t = float(t)
        while self._sleepers and self._sleepers[0][0] <= self._t:
            _, _, release = heapq.heappop(self._sleepers)
            release.set()

    async def wait(self, event: asyncio.Event, timeout: float | None = None) -> bool:
        if event.is_set():
            return True
        if timeout is None:
            await event.wait()
            return True
        if timeout <= 0:
            await asyncio.sleep(0)
            return event.is_set()
        release = asyncio.Event()
        heapq.heappush(self._sleepers, (self._t + timeout, next(self._seq), release))
        ev_task = asyncio.ensure_future(event.wait())
        rel_task = asyncio.ensure_future(release.wait())
        try:
            await asyncio.wait(
                {ev_task, rel_task}, return_when=asyncio.FIRST_COMPLETED
            )
        finally:
            for task in (ev_task, rel_task):
                if not task.done():
                    task.cancel()
                    try:
                        await task
                    except asyncio.CancelledError:
                        pass
        return event.is_set()
