"""Batched serving engines: LLM decode slots + MATE discovery batching.

Two request classes share the slot-batching philosophy (fixed-size groups,
one device launch per group):

  * ``ServeEngine`` — prefill + decode with slot-based batching for the model
    zoo.  A fixed pool of ``batch`` slots; requests occupy slots, decode
    steps run for the whole pool every tick (tokens for finished/empty slots
    are masked).  Continuous-batching-lite: static shapes (TPU-friendly),
    per-slot position counters, greedy or temperature sampling.
    serve_step (one decode tick) is the unit the dry-run lowers for
    decode_32k / long_500k shapes.

  * ``DiscoveryEngine`` — multi-query online join discovery.  Queued
    requests drain in groups of ``batch``; each group's candidate rows and
    query keys concatenate into ONE super-key filter launch
    (``core.batched.discover_many``), so concurrent requests amortise the
    kernel dispatch instead of filtering one query at a time.  Results are
    bit-identical to per-request ``discover``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batched as batched_lib
from repro.core.corpus import Table
from repro.core.discovery import DiscoveryStats, TopKEntry
from repro.core.index import MateIndex
from repro.models import transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass
class DiscoveryRequest:
    """One top-k join-discovery request flowing through ``DiscoveryEngine``."""

    query: Table
    q_cols: list[int]
    k: int = 10
    results: list[TopKEntry] | None = None
    stats: DiscoveryStats | None = None

    @property
    def done(self) -> bool:
        return self.results is not None


class DiscoveryEngine:
    """Host-side loop batching concurrent discovery requests.

    ``submit`` queues; ``flush`` drains the queue in groups of ``batch``,
    each group sharing one filter launch via ``discover_many``.  The engine
    serves whatever hash width its index was built at (``bits``): group
    launches, device-side rule-1/2 counts and verification slices are all
    ``lanes``-wide, so a 512-bit lake and a 128-bit lake run the same code.

    ``fused`` selects the fused filter+segment-count kernel for the group
    launches (counts-only readback, zero match-matrix bytes — see
    ``core.batched.discover_many``); None follows the backend dispatch
    (fused on TPU / ``MATE_FILTER_BACKEND=fused``).
    """

    def __init__(
        self,
        index: MateIndex,
        batch: int = 8,
        use_kernel: bool = True,
        fused: bool | None = None,
    ):
        self.index = index
        self.batch = batch
        self.use_kernel = use_kernel
        self.fused = fused
        self.queue: list[DiscoveryRequest] = []

    @property
    def bits(self) -> int:
        """Superkey hash width of the underlying index."""
        return self.index.cfg.bits

    def submit(self, query: Table, q_cols: list[int], k: int = 10) -> DiscoveryRequest:
        req = DiscoveryRequest(query=query, q_cols=q_cols, k=k)
        self.queue.append(req)
        return req

    def flush(self) -> list[DiscoveryRequest]:
        """Serve every queued request; returns them in submission order."""
        served, self.queue = self.queue, []
        for start in range(0, len(served), self.batch):
            group = served[start : start + self.batch]
            out = batched_lib.discover_many(
                self.index,
                [(r.query, r.q_cols) for r in group],
                k=[r.k for r in group],
                use_kernel=self.use_kernel,
                fused=self.fused,
            )
            for req, (entries, stats) in zip(group, out):
                req.results, req.stats = entries, stats
        return served

    def discover(self, query: Table, q_cols: list[int], k: int = 10) -> DiscoveryRequest:
        """One-shot convenience: submit + flush a single request."""
        req = self.submit(query, q_cols, k)
        self.flush()
        return req


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new: int = 32
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


def make_serve_step(cfg: ModelConfig, temperature: float = 0.0):
    """Returns serve_step(params, cache, token[B], rng) -> (next_token[B], cache)."""

    def serve_step(params, cache, token, rng):
        logits, cache = transformer.decode_step(params, cfg, token, cache)
        if temperature > 0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), cache

    return serve_step


class ServeEngine:
    """Host-side loop around prefill/serve_step for real (small) models."""

    def __init__(self, params, cfg: ModelConfig, batch: int, max_seq: int,
                 temperature: float = 0.0, extra_inputs: dict | None = None):
        self.params, self.cfg = params, cfg
        self.batch, self.max_seq = batch, max_seq
        self.extra = extra_inputs or {}
        self.step_fn = jax.jit(make_serve_step(cfg, temperature), donate_argnums=(1,))
        self.prefill_fn = jax.jit(
            lambda p, t, **kw: transformer.prefill(p, cfg, t, max_seq, **kw)
        )

    def generate(self, requests: list[Request]) -> list[Request]:
        """Serve requests in slot batches of ``self.batch``."""
        rng = jax.random.PRNGKey(0)
        for start in range(0, len(requests), self.batch):
            group = requests[start : start + self.batch]
            b = len(group)
            plen = max(len(r.prompt) for r in group)
            toks = np.zeros((self.batch, plen), np.int32)
            for i, r in enumerate(group):
                toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
            logits, cache = self.prefill_fn(self.params, jnp.asarray(toks), **self.extra)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            max_new = max(r.max_new for r in group)
            for step in range(max_new):
                for i, r in enumerate(group):
                    if not r.done and step < r.max_new:
                        r.out.append(int(token[i]))
                rng, sub = jax.random.split(rng)
                token, cache = self.step_fn(self.params, cache, token, sub)
            for r in group:
                r.done = True
        return requests
