"""Batched serving engines: LLM decode slots + MATE discovery batching.

Two request classes share the slot-batching philosophy (fixed-size groups,
one device launch per group):

  * ``ServeEngine`` — prefill + decode with slot-based batching for the model
    zoo.  A fixed pool of ``batch`` slots; requests occupy slots, decode
    steps run for the whole pool every tick (tokens for finished/empty slots
    are masked).  Continuous-batching-lite: static shapes (TPU-friendly),
    per-slot position counters, greedy or temperature sampling.
    serve_step (one decode tick) is the unit the dry-run lowers for
    decode_32k / long_500k shapes.

  * ``DiscoveryEngine`` — multi-query online join discovery, rebuilt on top
    of ``core.session.MateSession`` as an ASYNC-CAPABLE loop.  ``submit``
    returns a request carrying a ``concurrent.futures.Future``; ``pump``
    (the per-tick scheduling step) serves arrival-window groups — a group
    launches when it fills to ``batch`` requests OR when its oldest request
    has waited ``flush_after`` seconds (minus a ``deadline_margin`` so the
    group is SERVED by its deadline, not merely started at it) — so
    discovery groups and LLM decode ticks can interleave on one device.
    Each group's candidate rows and query keys concatenate into ONE
    super-key filter launch (``MateSession.plan_and_count``), so concurrent
    requests amortise the kernel dispatch instead of filtering one query at
    a time.  Results are bit-identical to per-request ``discover``.

    The serving tier on top (all knobs in ``DiscoveryConfig``):

      - bounded submit queue + admission control: at ``max_queue`` waiting
        requests, ``submit`` either SHEDS (the future is rejected with
        ``AdmissionError`` — never silently hung) or DEGRADES (the request
        is admitted flagged for ``degrade_bits`` lane-prefix filtering —
        a pure relaxation, so results stay bit-identical while filter
        bandwidth drops; a hard shed still applies at 2×``max_queue``);
      - ``serve.cache`` in front of the filter: a query-result cache
        answers repeated queries at ``submit`` time and a hot-table bound
        cache lets repeated queries skip ``gather_candidates`` + the
        filter launch, both invalidated by §5.4 index mutations via
        ``MateIndex.mutation_epoch``;
      - cancellation: a request whose future is cancelled never launches
        and stops holding a window slot.

  * ``AsyncDiscoveryEngine`` — the asyncio serving tier proper: a
    background pump task that wakes on submit or the next group deadline
    and SURVIVES group failures (each failed group rejects its own futures;
    the loop keeps serving).  Time is injected via ``serve.clock`` so the
    whole tier runs deterministically under a fake clock in tests.
"""

from __future__ import annotations

import asyncio
import dataclasses
import time
from concurrent.futures import Future
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batched import PlanCounts
from repro.core.corpus import Table
from repro.core.discovery import DiscoveryStats, TopKEntry
from repro.core.index import MateIndex
from repro.core.session import DiscoveryConfig, MateSession
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serve import cache as cache_lib
from repro.serve.clock import SystemClock


class AdmissionError(RuntimeError):
    """Request rejected by admission control (bounded queue at capacity,
    or the engine stopped with a non-draining shutdown).  Carried by the
    request's future — awaiters observe the shed instead of hanging."""


@dataclasses.dataclass
class DiscoveryRequest:
    """One top-k join-discovery request flowing through ``DiscoveryEngine``.

    ``future`` resolves to ``(results, stats)`` when the request's group is
    served — the async handle a caller can await (``asyncio.wrap_future``)
    or block on (``future.result()``) while the engine keeps ticking;
    ``results``/``stats`` mirror it for synchronous callers.
    """

    query: Table
    q_cols: list[int]
    k: int = 10
    arrival: float = 0.0
    results: list[TopKEntry] | None = None
    stats: DiscoveryStats | None = None
    future: Future = dataclasses.field(default_factory=Future, repr=False)
    # serving-tier bookkeeping:
    degraded: bool = False  # admitted under pressure → degrade_bits filtering
    from_cache: bool = False  # answered from the query-result cache at submit
    fingerprint: bytes | None = dataclasses.field(default=None, repr=False)
    bounds: PlanCounts | None = dataclasses.field(default=None, repr=False)
    # bound-cache hit: phase A (gather + filter) is already paid for

    @property
    def done(self) -> bool:
        return self.results is not None

    def cancel(self) -> bool:
        """Cancel the future; a cancelled request never launches (the
        engine purges it before grouping) and frees its window slot."""
        return self.future.cancel()

    @property
    def cancelled(self) -> bool:
        return self.future.cancelled()


class DiscoveryEngine:
    """Arrival-window batching loop over a ``MateSession``.

    Construction: pass a ``MateSession`` (preferred — the engine adopts its
    config's ``window``/``flush_after``), or a bare ``MateIndex`` plus an
    optional ``DiscoveryConfig``.  The engine serves whatever hash width and
    backend the session resolved; the pre-registry ``use_kernel=``/``fused=``
    flags were removed after their one-release deprecation window (PR 4) —
    pin the backend via ``DiscoveryConfig(backend=...)``.

    Scheduling: ``submit`` queues a request (its ``k`` may differ per
    request; None takes the config default).  ``pump(now)`` — the unit a
    serving tick calls between decode steps — launches every DUE group:
    a group is due when ``batch`` requests are waiting (window full) or the
    oldest waiting request is ``flush_after`` seconds old (deadline).  With
    ``flush_after=None`` only full windows launch; ``flush()`` always
    drains everything (the synchronous path, unchanged from earlier PRs).
    """

    def __init__(
        self,
        index: MateIndex | MateSession | None = None,
        batch: int | None = None,
        *,
        session: MateSession | None = None,
        config: DiscoveryConfig | None = None,
        flush_after: float | None = None,
        clock=time.monotonic,
    ):
        if isinstance(index, MateSession):
            session, index = index, None
        if session is None:
            if index is None:
                raise TypeError("DiscoveryEngine needs a MateSession or a MateIndex")
            session = MateSession(index, config)
        elif index is not None or config is not None:
            raise TypeError("pass either session= or index/config, not both")
        self.session = session
        self.batch = batch if batch is not None else session.config.window
        self.flush_after = (
            flush_after if flush_after is not None else session.config.flush_after
        )
        self.clock = clock
        self.queue: list[DiscoveryRequest] = []
        cfg = session.config
        self.max_queue = cfg.max_queue
        self.pressure_policy = cfg.pressure_policy
        # degrade width in uint32 lanes, clamped to the index width (a
        # 128-bit index cannot degrade below itself — degrade is a no-op)
        self.degrade_lanes = min(cfg.degrade_bits // 32, session.index.cfg.lanes)
        self.deadline_margin = cfg.deadline_margin  # None: auto (EWMA below)
        self._service_ewma: float | None = None  # observed group service time
        self.result_cache = (
            cache_lib.QueryResultCache(cfg.result_cache) if cfg.result_cache else None
        )
        self.bound_cache = (
            cache_lib.BoundCache(cfg.bound_cache) if cfg.bound_cache else None
        )

    @property
    def index(self) -> MateIndex:
        return self.session.index

    @property
    def bits(self) -> int:
        """Superkey hash width of the underlying index."""
        return self.session.bits

    @property
    def backend(self):
        """The session's resolved filter backend."""
        return self.session.backend

    def submit(
        self,
        query: Table,
        q_cols: list[int],
        k: int | None = None,
        now: float | None = None,
    ) -> DiscoveryRequest:
        """Queue a request (or answer/reject it immediately).

        In order: a query-result cache hit resolves the future RIGHT HERE
        (bit-identical replay, no queue slot, no index work); then admission
        control applies at ``max_queue`` waiting requests — 'shed' rejects
        the future with ``AdmissionError``, 'degrade' admits the request
        flagged for ``degrade_bits`` filtering (hard shed at 2×); finally a
        bound-cache hit rides along on the queued request so its group
        launch skips gather+filter for it.  The returned request's future
        is thus always eventually resolved: result, error, or shed."""
        req = DiscoveryRequest(
            query=query,
            q_cols=q_cols,
            k=self.session.config.k if k is None else k,
            arrival=self.clock() if now is None else now,
        )
        st = self.session.stats
        if self.result_cache is not None or self.bound_cache is not None:
            req.fingerprint = cache_lib.query_fingerprint(
                query, q_cols, self.session.config.init_mode,
                rank=self.session.config.rank,
                profile_gate=self.session.config.profile_gate,
            )
            epoch = self.index.mutation_epoch
            if self.result_cache is not None:
                hit = self.result_cache.get(req.fingerprint, req.k, epoch)
                if hit is not None:
                    entries, stats = hit
                    req.results, req.stats, req.from_cache = entries, stats, True
                    req.future.set_result((entries, stats))
                    st.requests += 1
                    st.cache_hits += 1
                    return req
            if self.bound_cache is not None:
                req.bounds = self.bound_cache.get(req.fingerprint, epoch)
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            # degraded filtering relieves filter bandwidth, not an unbounded
            # backlog — past 2×max_queue even 'degrade' sheds.
            if self.pressure_policy == "shed" or len(self.queue) >= 2 * self.max_queue:
                st.shed += 1
                req.future.set_exception(
                    AdmissionError(
                        f"queue full: {len(self.queue)} waiting >= "
                        f"max_queue={self.max_queue} (policy="
                        f"{self.pressure_policy!r})"
                    )
                )
                return req
            req.degraded = True
            st.degraded += 1
        self.queue.append(req)
        self._notify_submit()
        return req

    def _notify_submit(self) -> None:
        """Hook for the async engine: wake the pump task on new work."""

    def _purge_cancelled(self) -> None:
        self.queue = [r for r in self.queue if not r.future.cancelled()]

    def _serve_group(self, group: list[DiscoveryRequest]) -> None:
        group = [r for r in group if not r.future.cancelled()]
        if not group:
            return
        t0 = self.clock()
        epoch = self.index.mutation_epoch
        # warm requests replay cached phase-A bounds (skip gather+filter);
        # a stale-epoch bounds object is discarded — it was cached before a
        # §5.4 mutation that may have changed this query's candidates.
        warm: list[DiscoveryRequest] = []
        cold: list[DiscoveryRequest] = []
        for r in group:
            (warm if r.bounds is not None and r.bounds.epoch == epoch else cold).append(r)
        lanes = self.degrade_lanes if any(r.degraded for r in cold) else None
        try:
            pcs = (
                self.session.plan_and_count(
                    [(r.query, r.q_cols) for r in cold], filter_lanes=lanes
                )
                if cold
                else []
            )
            st = self.session.stats
            for req, pc in zip(cold, pcs):
                entries, stats = self.session.score_from_counts(pc, req.k)
                if req.fingerprint is not None:
                    if self.result_cache is not None:
                        self.result_cache.put(
                            req.fingerprint, req.k, pc.epoch, entries, stats
                        )
                    # degraded counts are valid (looser) bounds, but don't
                    # cache them: a hot entry would keep replaying the wide
                    # survivor set long after the pressure spike ended.
                    if self.bound_cache is not None and not req.degraded:
                        self.bound_cache.put(req.fingerprint, pc)
                self._resolve(req, entries, stats)
            for req in warm:
                entries, stats = self.session.score_from_counts(
                    req.bounds, req.k, from_cache=True
                )
                st.bound_hits += 1
                if self.result_cache is not None and req.fingerprint is not None:
                    self.result_cache.put(
                        req.fingerprint, req.k, req.bounds.epoch, entries, stats
                    )
                self._resolve(req, entries, stats)
        except BaseException as e:
            # the group is already dequeued: reject every future so sibling
            # awaiters see the failure instead of polling forever, then let
            # the pump caller observe the exception too.  (The background
            # pump task catches it and keeps serving later groups.)
            for req in group:
                if not req.future.done():
                    req.future.set_exception(e)
            raise
        dt = self.clock() - t0
        self._service_ewma = (
            dt if self._service_ewma is None else 0.7 * self._service_ewma + 0.3 * dt
        )

    def _resolve(self, req: DiscoveryRequest, entries, stats) -> None:
        req.results, req.stats = entries, stats
        if not req.future.done():  # done: cancelled between launch and here
            req.future.set_result((entries, stats))

    def _margin(self) -> float:
        """Seconds before a deadline to launch a partial group, so it is
        SERVED by the deadline: the configured ``deadline_margin``, or the
        observed group-service-time EWMA when configured as None (auto)."""
        if self.deadline_margin is not None:
            return self.deadline_margin
        return self._service_ewma or 0.0

    def _due(self, now: float) -> bool:
        if len(self.queue) >= self.batch:
            return True
        return bool(
            self.queue
            and self.flush_after is not None
            and now - self.queue[0].arrival >= self.flush_after - self._margin()
        )

    def next_deadline(self) -> float | None:
        """Absolute time the oldest queued request's group should LAUNCH by
        (its ``flush_after`` deadline minus the margin), or None when
        nothing is waiting / no deadline policy is set."""
        if not self.queue or self.flush_after is None:
            return None
        return self.queue[0].arrival + self.flush_after - self._margin()

    def pump(self, now: float | None = None) -> list[DiscoveryRequest]:
        """One scheduling step: launch every due group; returns requests
        served THIS call (submission order).  O(1) when nothing is due —
        cheap enough to call between every decode tick.  Cancelled requests
        are purged first: they never launch and never hold a window open."""
        now = self.clock() if now is None else now
        self._purge_cancelled()
        served: list[DiscoveryRequest] = []
        while self._due(now):
            group, self.queue = self.queue[: self.batch], self.queue[self.batch :]
            self._serve_group(group)
            served.extend(r for r in group if not r.future.cancelled())
        return served

    def flush(self) -> list[DiscoveryRequest]:
        """Serve every queued request NOW (deadline ignored); returns them
        in submission order.  Groups dequeue one at a time, so a failing
        group launch rejects only ITS requests' futures — later groups stay
        queued (futures pending) for a retry pump/flush."""
        self._purge_cancelled()
        served: list[DiscoveryRequest] = []
        while self.queue:
            group, self.queue = self.queue[: self.batch], self.queue[self.batch :]
            self._serve_group(group)
            served.extend(r for r in group if not r.future.cancelled())
        return served

    def discover(
        self, query: Table, q_cols: list[int], k: int | None = None
    ) -> DiscoveryRequest:
        """One-shot convenience: submit + flush a single request."""
        req = self.submit(query, q_cols, k)
        self.flush()
        return req

    async def discover_async(
        self, query: Table, q_cols: list[int], k: int | None = None
    ) -> DiscoveryRequest:
        """Submit and await: yields to the event loop until the request's
        group is served.  The engine itself has no background thread — some
        task must keep calling ``pump()`` (a serving tick, or a sibling
        ``discover_async`` waiter: each waiter pumps when its own deadline
        or window comes due, so a loop full of awaiting requests makes
        progress by itself).

        With NO deadline policy (``flush_after=None``) nothing would ever
        launch a partial group, so an async waiter must not wait on the
        window alone — it yields once (letting sibling submits land and the
        window fill) and then drains its group immediately.  Set
        ``flush_after`` to actually hold a window open for stragglers."""
        req = self.submit(query, q_cols, k)
        if self.flush_after is None:
            await asyncio.sleep(0)  # let concurrently-spawned waiters queue
            self.pump()
            if not req.future.done():
                self.flush()  # no deadline will ever fire: drain, don't spin
        else:
            while not req.future.done():
                self.pump()
                if req.future.done():
                    break
                deadline = self.next_deadline()
                now = self.clock()
                # sleep to the group deadline (or a short poll while our own
                # group is not yet the oldest), yielding to decode ticks
                delay = 0.001 if deadline is None else max(deadline - now, 0.0)
                await asyncio.sleep(min(delay, 0.05))
        req.future.result()  # propagate a group failure to THIS awaiter
        return req


class AsyncDiscoveryEngine(DiscoveryEngine):
    """The asyncio serving tier: a ``DiscoveryEngine`` driven by a
    BACKGROUND pump task instead of caller-side pumping.

    ``start()`` spawns the pump loop on the running event loop: it wakes
    whenever a request is submitted or the next group deadline arrives,
    launches every due group, and goes back to sleep until the next signal
    — callers just ``await discover_async(...)``.  The loop OUTLIVES group
    failures: a failing launch rejects that group's futures (see
    ``_serve_group``) and is counted in ``pump_errors``, then the loop
    keeps serving later groups — one poisoned query must not orphan every
    future queued behind it.

    Time comes from a ``serve.clock`` object (``SystemClock`` by default);
    pass ``ManualClock`` and the whole tier — deadlines, wake-ups, EWMA —
    runs under virtual time (``tests/test_serving.py``).

    Use as an async context manager::

        async with AsyncDiscoveryEngine(session=session) as eng:
            entries, stats = (await eng.discover_async(q, cols)).future.result()
    """

    def __init__(
        self,
        index: MateIndex | MateSession | None = None,
        batch: int | None = None,
        *,
        session: MateSession | None = None,
        config: DiscoveryConfig | None = None,
        flush_after: float | None = None,
        clock=None,
    ):
        self.aclock = clock if clock is not None else SystemClock()
        super().__init__(
            index, batch, session=session, config=config,
            flush_after=flush_after, clock=self.aclock.now,
        )
        self._wake: asyncio.Event | None = None
        self._task: asyncio.Task | None = None
        self._stopping = False
        self.pump_errors = 0  # failed group launches the pump survived

    def _notify_submit(self) -> None:
        if self._wake is not None:
            self._wake.set()

    async def start(self) -> None:
        if self._task is not None:
            raise RuntimeError("pump task already running")
        self._wake = asyncio.Event()
        self._stopping = False
        self._task = asyncio.get_running_loop().create_task(self._pump_loop())

    async def stop(self, drain: bool = True) -> None:
        """Stop the pump task.  ``drain=True`` serves the backlog first
        (synchronously, deadline ignored); ``drain=False`` rejects every
        still-pending queued future with ``AdmissionError`` — either way no
        future is left hanging."""
        if self._task is None:
            return
        self._stopping = True
        self._wake.set()
        try:
            await self._task
        finally:
            self._task = None
            self._wake = None
        if drain:
            self.flush()
        else:
            for req in self.queue:
                if not req.future.done():
                    req.future.set_exception(AdmissionError("engine stopped"))
            self.queue.clear()

    async def __aenter__(self) -> "AsyncDiscoveryEngine":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    async def _pump_loop(self) -> None:
        while not self._stopping:
            try:
                self.pump()
            except asyncio.CancelledError:
                raise
            except BaseException:
                # the failed group's futures are already rejected; the loop
                # must survive to serve everything queued behind it.
                self.pump_errors += 1
            timeout = None  # no queued deadline: sleep until a submit
            deadline = self.next_deadline()
            if deadline is not None:
                timeout = max(deadline - self.clock(), 0.0)
            await self.aclock.wait(self._wake, timeout)
            self._wake.clear()

    async def discover_async(
        self, query: Table, q_cols: list[int], k: int | None = None
    ) -> DiscoveryRequest:
        """Submit and await — the background pump serves the group, so this
        just parks on the future (no caller-side pumping).  Raises what the
        future carries: ``AdmissionError`` on shed, the group's exception
        on a failed launch."""
        if self._task is None:
            raise RuntimeError("pump task not running — use 'async with' or start()")
        req = self.submit(query, q_cols, k)
        await asyncio.wrap_future(req.future)
        return req


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new: int = 32
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


def make_serve_step(cfg: ModelConfig, temperature: float = 0.0):
    """Returns serve_step(params, cache, token[B], rng) -> (next_token[B], cache)."""

    def serve_step(params, cache, token, rng):
        logits, cache = transformer.decode_step(params, cfg, token, cache)
        if temperature > 0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), cache

    return serve_step


class ServeEngine:
    """Host-side loop around prefill/serve_step for real (small) models.

    ``on_tick`` (optional, ``callable(step)``) runs between decode steps —
    the interleave point where a co-located ``DiscoveryEngine.pump()`` (or
    any other host-side scheduler) gets the device while the freshly
    dispatched decode step is in flight.
    """

    def __init__(self, params, cfg: ModelConfig, batch: int, max_seq: int,
                 temperature: float = 0.0, extra_inputs: dict | None = None):
        self.params, self.cfg = params, cfg
        self.batch, self.max_seq = batch, max_seq
        self.extra = extra_inputs or {}
        self.on_tick = None
        self.step_fn = jax.jit(make_serve_step(cfg, temperature), donate_argnums=(1,))
        self.prefill_fn = jax.jit(
            lambda p, t, **kw: transformer.prefill(p, cfg, t, max_seq, **kw)
        )

    def generate(self, requests: list[Request]) -> list[Request]:
        """Serve requests in slot batches of ``self.batch``."""
        rng = jax.random.PRNGKey(0)
        for start in range(0, len(requests), self.batch):
            group = requests[start : start + self.batch]
            b = len(group)
            plen = max(len(r.prompt) for r in group)
            toks = np.zeros((self.batch, plen), np.int32)
            for i, r in enumerate(group):
                toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
            logits, cache = self.prefill_fn(self.params, jnp.asarray(toks), **self.extra)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            max_new = max(r.max_new for r in group)
            for step in range(max_new):
                for i, r in enumerate(group):
                    if not r.done and step < r.max_new:
                        r.out.append(int(token[i]))
                rng, sub = jax.random.split(rng)
                token, cache = self.step_fn(self.params, cache, token, sub)
                if self.on_tick is not None:
                    self.on_tick(step)
            for r in group:
                r.done = True
        return requests
