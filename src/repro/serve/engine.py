"""Batched serving engines: LLM decode slots + MATE discovery batching.

Two request classes share the slot-batching philosophy (fixed-size groups,
one device launch per group):

  * ``ServeEngine`` — prefill + decode with slot-based batching for the model
    zoo.  A fixed pool of ``batch`` slots; requests occupy slots, decode
    steps run for the whole pool every tick (tokens for finished/empty slots
    are masked).  Continuous-batching-lite: static shapes (TPU-friendly),
    per-slot position counters, greedy or temperature sampling.
    serve_step (one decode tick) is the unit the dry-run lowers for
    decode_32k / long_500k shapes.

  * ``DiscoveryEngine`` — multi-query online join discovery, rebuilt on top
    of ``core.session.MateSession`` as an ASYNC-CAPABLE loop.  ``submit``
    returns a request carrying a ``concurrent.futures.Future``; ``pump``
    (the per-tick scheduling step) serves arrival-window groups — a group
    launches when it fills to ``batch`` requests OR when its oldest request
    has waited ``flush_after`` seconds — so discovery groups and LLM decode
    ticks can interleave on one device.  Each group's candidate rows and
    query keys concatenate into ONE super-key filter launch
    (``MateSession.discover_many``), so concurrent requests amortise the
    kernel dispatch instead of filtering one query at a time.  Results are
    bit-identical to per-request ``discover``.
"""

from __future__ import annotations

import asyncio
import dataclasses
import time
from concurrent.futures import Future
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.corpus import Table
from repro.core.discovery import DiscoveryStats, TopKEntry
from repro.core.index import MateIndex
from repro.core.session import DiscoveryConfig, MateSession
from repro.models import transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass
class DiscoveryRequest:
    """One top-k join-discovery request flowing through ``DiscoveryEngine``.

    ``future`` resolves to ``(results, stats)`` when the request's group is
    served — the async handle a caller can await (``asyncio.wrap_future``)
    or block on (``future.result()``) while the engine keeps ticking;
    ``results``/``stats`` mirror it for synchronous callers.
    """

    query: Table
    q_cols: list[int]
    k: int = 10
    arrival: float = 0.0
    results: list[TopKEntry] | None = None
    stats: DiscoveryStats | None = None
    future: Future = dataclasses.field(default_factory=Future, repr=False)

    @property
    def done(self) -> bool:
        return self.results is not None


class DiscoveryEngine:
    """Arrival-window batching loop over a ``MateSession``.

    Construction: pass a ``MateSession`` (preferred — the engine adopts its
    config's ``window``/``flush_after``), or a bare ``MateIndex`` plus an
    optional ``DiscoveryConfig``.  The engine serves whatever hash width and
    backend the session resolved; the pre-registry ``use_kernel=``/``fused=``
    flags were removed after their one-release deprecation window (PR 4) —
    pin the backend via ``DiscoveryConfig(backend=...)``.

    Scheduling: ``submit`` queues a request (its ``k`` may differ per
    request; None takes the config default).  ``pump(now)`` — the unit a
    serving tick calls between decode steps — launches every DUE group:
    a group is due when ``batch`` requests are waiting (window full) or the
    oldest waiting request is ``flush_after`` seconds old (deadline).  With
    ``flush_after=None`` only full windows launch; ``flush()`` always
    drains everything (the synchronous path, unchanged from earlier PRs).
    """

    def __init__(
        self,
        index: MateIndex | MateSession | None = None,
        batch: int | None = None,
        *,
        session: MateSession | None = None,
        config: DiscoveryConfig | None = None,
        flush_after: float | None = None,
        clock=time.monotonic,
    ):
        if isinstance(index, MateSession):
            session, index = index, None
        if session is None:
            if index is None:
                raise TypeError("DiscoveryEngine needs a MateSession or a MateIndex")
            session = MateSession(index, config)
        elif index is not None or config is not None:
            raise TypeError("pass either session= or index/config, not both")
        self.session = session
        self.batch = batch if batch is not None else session.config.window
        self.flush_after = (
            flush_after if flush_after is not None else session.config.flush_after
        )
        self.clock = clock
        self.queue: list[DiscoveryRequest] = []

    @property
    def index(self) -> MateIndex:
        return self.session.index

    @property
    def bits(self) -> int:
        """Superkey hash width of the underlying index."""
        return self.session.bits

    @property
    def backend(self):
        """The session's resolved filter backend."""
        return self.session.backend

    def submit(
        self,
        query: Table,
        q_cols: list[int],
        k: int | None = None,
        now: float | None = None,
    ) -> DiscoveryRequest:
        req = DiscoveryRequest(
            query=query,
            q_cols=q_cols,
            k=self.session.config.k if k is None else k,
            arrival=self.clock() if now is None else now,
        )
        self.queue.append(req)
        return req

    def _serve_group(self, group: list[DiscoveryRequest]) -> None:
        try:
            out = self.session.discover_many(
                [(r.query, r.q_cols) for r in group], k=[r.k for r in group]
            )
        except BaseException as e:
            # the group is already dequeued: reject every future so sibling
            # awaiters see the failure instead of polling forever, then let
            # the pump caller observe the exception too.
            for req in group:
                if not req.future.done():
                    req.future.set_exception(e)
            raise
        for req, (entries, stats) in zip(group, out):
            req.results, req.stats = entries, stats
            req.future.set_result((entries, stats))

    def _due(self, now: float) -> bool:
        if len(self.queue) >= self.batch:
            return True
        return bool(
            self.queue
            and self.flush_after is not None
            and now - self.queue[0].arrival >= self.flush_after
        )

    def next_deadline(self) -> float | None:
        """Absolute time the oldest queued request must be served by, or
        None when nothing is waiting / no deadline policy is set."""
        if not self.queue or self.flush_after is None:
            return None
        return self.queue[0].arrival + self.flush_after

    def pump(self, now: float | None = None) -> list[DiscoveryRequest]:
        """One scheduling step: launch every due group; returns requests
        served THIS call (submission order).  O(1) when nothing is due —
        cheap enough to call between every decode tick."""
        now = self.clock() if now is None else now
        served: list[DiscoveryRequest] = []
        while self._due(now):
            group, self.queue = self.queue[: self.batch], self.queue[self.batch :]
            self._serve_group(group)
            served.extend(group)
        return served

    def flush(self) -> list[DiscoveryRequest]:
        """Serve every queued request NOW (deadline ignored); returns them
        in submission order.  Groups dequeue one at a time, so a failing
        group launch rejects only ITS requests' futures — later groups stay
        queued (futures pending) for a retry pump/flush."""
        served: list[DiscoveryRequest] = []
        while self.queue:
            group, self.queue = self.queue[: self.batch], self.queue[self.batch :]
            self._serve_group(group)
            served.extend(group)
        return served

    def discover(
        self, query: Table, q_cols: list[int], k: int | None = None
    ) -> DiscoveryRequest:
        """One-shot convenience: submit + flush a single request."""
        req = self.submit(query, q_cols, k)
        self.flush()
        return req

    async def discover_async(
        self, query: Table, q_cols: list[int], k: int | None = None
    ) -> DiscoveryRequest:
        """Submit and await: yields to the event loop until the request's
        group is served.  The engine itself has no background thread — some
        task must keep calling ``pump()`` (a serving tick, or a sibling
        ``discover_async`` waiter: each waiter pumps when its own deadline
        or window comes due, so a loop full of awaiting requests makes
        progress by itself).

        With NO deadline policy (``flush_after=None``) nothing would ever
        launch a partial group, so an async waiter must not wait on the
        window alone — it yields once (letting sibling submits land and the
        window fill) and then drains its group immediately.  Set
        ``flush_after`` to actually hold a window open for stragglers."""
        req = self.submit(query, q_cols, k)
        if self.flush_after is None:
            await asyncio.sleep(0)  # let concurrently-spawned waiters queue
            self.pump()
            if not req.future.done():
                self.flush()  # no deadline will ever fire: drain, don't spin
        else:
            while not req.future.done():
                self.pump()
                if req.future.done():
                    break
                deadline = self.next_deadline()
                now = self.clock()
                # sleep to the group deadline (or a short poll while our own
                # group is not yet the oldest), yielding to decode ticks
                delay = 0.001 if deadline is None else max(deadline - now, 0.0)
                await asyncio.sleep(min(delay, 0.05))
        req.future.result()  # propagate a group failure to THIS awaiter
        return req


@dataclasses.dataclass
class Request:
    prompt: list[int]
    max_new: int = 32
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


def make_serve_step(cfg: ModelConfig, temperature: float = 0.0):
    """Returns serve_step(params, cache, token[B], rng) -> (next_token[B], cache)."""

    def serve_step(params, cache, token, rng):
        logits, cache = transformer.decode_step(params, cfg, token, cache)
        if temperature > 0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), cache

    return serve_step


class ServeEngine:
    """Host-side loop around prefill/serve_step for real (small) models.

    ``on_tick`` (optional, ``callable(step)``) runs between decode steps —
    the interleave point where a co-located ``DiscoveryEngine.pump()`` (or
    any other host-side scheduler) gets the device while the freshly
    dispatched decode step is in flight.
    """

    def __init__(self, params, cfg: ModelConfig, batch: int, max_seq: int,
                 temperature: float = 0.0, extra_inputs: dict | None = None):
        self.params, self.cfg = params, cfg
        self.batch, self.max_seq = batch, max_seq
        self.extra = extra_inputs or {}
        self.on_tick = None
        self.step_fn = jax.jit(make_serve_step(cfg, temperature), donate_argnums=(1,))
        self.prefill_fn = jax.jit(
            lambda p, t, **kw: transformer.prefill(p, cfg, t, max_seq, **kw)
        )

    def generate(self, requests: list[Request]) -> list[Request]:
        """Serve requests in slot batches of ``self.batch``."""
        rng = jax.random.PRNGKey(0)
        for start in range(0, len(requests), self.batch):
            group = requests[start : start + self.batch]
            b = len(group)
            plen = max(len(r.prompt) for r in group)
            toks = np.zeros((self.batch, plen), np.int32)
            for i, r in enumerate(group):
                toks[i, plen - len(r.prompt):] = r.prompt  # left-pad
            logits, cache = self.prefill_fn(self.params, jnp.asarray(toks), **self.extra)
            token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            max_new = max(r.max_new for r in group)
            for step in range(max_new):
                for i, r in enumerate(group):
                    if not r.done and step < r.max_new:
                        r.out.append(int(token[i]))
                rng, sub = jax.random.split(rng)
                token, cache = self.step_fn(self.params, cache, token, sub)
                if self.on_tick is not None:
                    self.on_tick(step)
            for r in group:
                r.done = True
        return requests
