"""Serving-tier caches: query-result memoization + hot-table bound cache.

Skewed traffic is the serving tier's defining workload (FREYJA-style lakes:
a few popular query tables dominate), so two LRU caches sit in front of the
group filter launch, both keyed on ``query_fingerprint`` — a digest of the
HASHED KEY-COLUMN CONTENT of the query, not object identity:

  * ``QueryResultCache`` — (fingerprint, k) → the finished top-k + stats.
    A hit is resolved at ``submit`` time without touching the queue, the
    index or the device, and is BIT-IDENTICAL to a fresh ``discover`` by
    construction: for a fixed index epoch the fingerprint determines every
    downstream artifact (init column, candidate block, filter, top-k).

  * ``BoundCache`` — fingerprint → ``core.batched.PlanCounts`` (the phase-A
    artifact: candidate block + per-table filtered-candidate counts, matrix
    slice dropped).  A hit skips ``gather_candidates`` + the filter launch
    entirely and goes straight to phase-B scoring
    (``score_from_counts(from_cache=True)``), which recomputes surviving
    tables' hit slices from the cached row super keys — the same
    subsumption predicate, so verification inputs and the top-k stay
    bit-identical.  Unlike the result cache it serves ANY ``k``.

Invalidation: every §5.4 index mutation (insert/update/delete) bumps
``MateIndex.mutation_epoch``; entries pin the epoch they were filled at and
``get`` drops any entry whose epoch no longer matches.  One global counter
is deliberately conservative — it invalidates the affected entries (a
mutation can change any table's candidacy for any cached query: a new
table's rows enter posting lists, a tombstone removes them) by invalidating
everything stale, so a stale top-k can never be served.  Per-table
dependency tracking would save refills, not correctness, and is left out.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict

from repro.core.batched import PlanCounts
from repro.core.corpus import Table
from repro.core.discovery import DiscoveryStats, TopKEntry


def query_fingerprint(
    query: Table,
    q_cols: list[int],
    init_mode: str = "cardinality",
    rank: str = "count",
    profile_gate: bool = False,
    workload: str = "join",
) -> bytes:
    """Digest of everything about a QUERY that determines its discovery
    result for a fixed index: the init-column heuristic, the key width, and
    the ordered sequence of key tuples (row order matters for the
    deterministic tie-breaks in init-column selection and key dedup order).

    Two query tables with the same key-column content — regardless of
    table name, id, or non-key columns — share a fingerprint, which is the
    whole point: the cache recognises repeated traffic by content.

    ``rank``/``profile_gate`` join the digest because they shape the CACHED
    ARTIFACTS: rank changes entry order/annotation, the gate changes the
    candidate block a cached ``PlanCounts`` holds — a count-mode fill must
    never answer a quality-mode request (the sets match, the payloads
    don't).  Both default to the raw-engine defaults so pre-existing
    fingerprints are unchanged.

    ``workload`` discriminates WHAT is being asked of those key columns:
    'join' (top-k joinability, the default) vs FD workloads
    (``core.fd.discover_fds`` — callers encode the dependent column and
    min_support, e.g. ``f"fd:{dependent_col}:{min_support}"``).  An FD
    request over the same determinant columns must never hit a
    joinability fill: the cached payloads are different types entirely.
    """
    h = hashlib.blake2b(digest_size=16)
    h.update(
        f"{init_mode}|{len(q_cols)}|{rank}|{int(profile_gate)}|{workload}".encode()
    )
    for row in query.cells:
        for c in q_cols:
            v = row[c].encode()
            # length-prefix framing: ("ab","c") must not collide with ("a","bc")
            h.update(len(v).to_bytes(4, "little"))
            h.update(v)
        h.update(b"\xff")
    return h.digest()


@dataclasses.dataclass
class CacheStats:
    """Per-cache accounting (the engine also mirrors hits into
    ``SessionStats.cache_hits`` / ``bound_hits``)."""

    hits: int = 0
    misses: int = 0
    stale: int = 0  # entries dropped because the index epoch moved (§5.4)
    evictions: int = 0  # capacity-driven LRU evictions

    @property
    def hit_rate(self) -> float:
        denom = self.hits + self.misses
        return self.hits / denom if denom else 0.0


class _LruCache:
    """Bounded OrderedDict LRU with epoch-checked reads."""

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.stats = CacheStats()
        self._entries: OrderedDict = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    def _get(self, key, epoch: int):
        ent = self._entries.get(key)
        if ent is None:
            self.stats.misses += 1
            return None
        if ent[0] != epoch:  # a §5.4 mutation happened since the fill
            del self._entries[key]
            self.stats.stale += 1
            self.stats.misses += 1
            return None
        self._entries.move_to_end(key)
        self.stats.hits += 1
        return ent

    def _put(self, key, ent) -> None:
        self._entries[key] = ent
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.stats.evictions += 1

    def invalidate_all(self) -> None:
        self._entries.clear()


class QueryResultCache(_LruCache):
    """(fingerprint, k) → finished (top-k entries, stats) memoization."""

    def get(
        self, fp: bytes, k: int, epoch: int
    ) -> tuple[list[TopKEntry], DiscoveryStats] | None:
        ent = self._get((fp, k), epoch)
        if ent is None:
            return None
        _, entries, stats = ent
        # fresh copies: callers own their results and must not be able to
        # corrupt the cached ones (TopKEntry is a mutable dataclass).
        return (
            [dataclasses.replace(e) for e in entries],
            dataclasses.replace(stats),
        )

    def put(
        self,
        fp: bytes,
        k: int,
        epoch: int,
        entries: list[TopKEntry],
        stats: DiscoveryStats,
    ) -> None:
        self._put(
            (fp, k),
            (
                epoch,
                tuple(dataclasses.replace(e) for e in entries),
                dataclasses.replace(stats),
            ),
        )


class BoundCache(_LruCache):
    """fingerprint → cached phase-A ``PlanCounts`` (hot-table bounds)."""

    def get(self, fp: bytes, epoch: int) -> PlanCounts | None:
        ent = self._get(fp, epoch)
        return None if ent is None else ent[1]

    def put(self, fp: bytes, pc: PlanCounts) -> None:
        # the matrix slice (possibly device-resident) is dropped up front —
        # cached entries are host-only and replay via lazy recompute.
        self._put(fp, (pc.epoch, pc.cacheable()))
