"""MATE-powered dataset enrichment — the paper's technique as a first-class
data-pipeline operator (the use case §1 motivates: enrich a base table with
joinable tables from a lake before downstream ML).

``enrich``: given a base table with a composite key and a corpus index,
discover the top-k joinable tables, pick the best column mapping (Eq. 2
argmax, already computed by discovery), and append the joined columns to the
base records.  ``tokenize_records`` turns enriched rows into LM token
streams for the training pipeline.
"""

from __future__ import annotations

import numpy as np

from repro.core import discovery
from repro.core.corpus import Table
from repro.core.index import MateIndex
from repro.core.session import MateSession


def enrich(
    source: MateIndex | MateSession,
    base: Table,
    key_cols: list[int],
    k: int = 5,
    max_new_cols: int = 8,
) -> tuple[Table, list[dict]]:
    """Returns (enriched table, provenance records).

    ``source`` is a ``MateSession`` (preferred — discovery runs through its
    resolved backend and counts toward its stats) or a bare ``MateIndex``
    (wrapped in a default-config session on the fly).
    """
    session = source if isinstance(source, MateSession) else MateSession(source)
    topk, _stats = session.discover(base, key_cols, k=k)
    corpus = session.index.corpus
    enriched = [list(row) for row in base.cells]
    provenance = []
    new_cols = 0
    for entry in topk:
        if entry.mapping is None or new_cols >= max_new_cols:
            continue
        t = corpus.tables[entry.table_id]
        mapped = set(entry.mapping)
        extra_cols = [c for c in range(t.n_cols) if c not in mapped]
        if not extra_cols:
            continue
        extra_cols = extra_cols[: max_new_cols - new_cols]
        # build join map: key tuple -> first matching row's extra values
        joinmap: dict[tuple, list[str]] = {}
        for row in t.cells:
            key = tuple(row[c] for c in entry.mapping)
            joinmap.setdefault(key, [row[c] for c in extra_cols])
        hits = 0
        for i, row in enumerate(base.cells):
            key = tuple(row[c] for c in key_cols)
            vals = joinmap.get(key)
            if vals is not None:
                enriched[i].extend(vals)
                hits += 1
            else:
                enriched[i].extend([""] * len(extra_cols))
        provenance.append(
            {
                "table_id": entry.table_id,
                "joinability": entry.joinability,
                "mapping": entry.mapping,
                "new_cols": len(extra_cols),
                "hit_rows": hits,
            }
        )
        new_cols += len(extra_cols)
    return Table(table_id=base.table_id, cells=enriched, name=base.name), provenance


def tokenize_records(table: Table, vocab_size: int, seq_len: int) -> np.ndarray:
    """Hash-tokenise enriched records into fixed-length sequences."""
    out = np.zeros((table.n_rows, seq_len), np.int32)
    for i, row in enumerate(table.cells):
        toks: list[int] = []
        for cell in row:
            for word in str(cell).split():
                toks.append(hash(word) % (vocab_size - 2) + 2)
            toks.append(1)  # field separator
        toks = toks[:seq_len]
        out[i, : len(toks)] = toks
    return out
