"""Training data pipeline.

Deterministic, restart-safe synthetic LM token stream: batch ``i`` is a pure
function of (seed, step, host) so a restarted job resumes mid-epoch with no
state (fault tolerance without a data-service dependency).  The enrichment
hook (data/enrichment.py) runs MATE joins over record tables before
tokenisation — the paper's technique as a data-pipeline stage.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0


class TokenPipeline:
    """Zipfian token stream with injected n-gram structure (so tiny models
    have something learnable)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed bigram transition "grammar" for learnability
        self.next_tok = rng.integers(0, cfg.vocab_size, size=cfg.vocab_size)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len
        toks = np.empty((b, s), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=b)
        flip = rng.random((b, s)) < 0.3  # 70% deterministic bigram
        rand = rng.integers(0, cfg.vocab_size, size=(b, s))
        for t in range(1, s):
            det = self.next_tok[toks[:, t - 1]]
            toks[:, t] = np.where(flip[:, t], rand[:, t], det)
        labels = np.concatenate(
            [toks[:, 1:], np.full((b, 1), -1, np.int32)], axis=1
        )
        return {"tokens": toks, "labels": labels}


def stub_inputs(cfg: ModelConfig, batch: int, rng_seed: int = 0) -> dict:
    """Modality-frontend stubs: precomputed frame/patch embeddings."""
    out = {}
    rng = np.random.default_rng(rng_seed)
    if cfg.encoder is not None:
        out["frames"] = rng.standard_normal(
            (batch, cfg.encoder.n_frames, cfg.d_model), dtype=np.float32
        ).astype(np.float16)
    if cfg.vision is not None:
        out["patches"] = rng.standard_normal(
            (batch, cfg.vision.n_tokens, cfg.d_model), dtype=np.float32
        ).astype(np.float16)
    return {k: jax.numpy.asarray(v, jax.numpy.bfloat16) for k, v in out.items()}
