"""Synthetic data generators.

1. Table corpora mimicking webtable / open-data statistics (§7.1): many small
   tables, zipfian value reuse across tables, controllable injected
   n-ary-joinable rows so ground truth is known.
2. Token streams for the LM substrate (see data/pipeline.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.corpus import Corpus, Table

_SYLLABLES = [
    "ka", "ro", "mi", "ta", "shi", "lo", "ber", "lin", "mun", "ich", "to",
    "kyo", "am", "ster", "dam", "bo", "ston", "cam", "bridge", "ox", "ford",
    "han", "over", "sto", "ck", "holm", "war", "saw", "pra", "gue", "vien",
    "na", "del", "hi", "se", "oul", "qui", "to", "li", "ma", "ac", "cra",
]

# heavy-tailed letter sampler (approx. English unigram distribution) so rare
# characters (j, q, x, z …) actually occur — webtable text is heavy-tailed,
# and XASH's least-frequent-character feature needs that tail to exist.
_LETTERS = np.array(list("abcdefghijklmnopqrstuvwxyz"))
_LETTER_P = np.array(
    [8.17, 1.49, 2.78, 4.25, 12.7, 2.23, 2.02, 6.09, 6.97, 0.15, 0.77, 4.03,
     2.41, 6.75, 7.51, 1.93, 0.10, 5.99, 6.33, 9.06, 2.76, 0.98, 2.36, 0.15,
     1.97, 0.07]
)
_LETTER_P = _LETTER_P / _LETTER_P.sum()


def _random_word(rng: np.random.Generator, min_syl=1, max_syl=4) -> str:
    """Heterogeneous value: words, codes, numbers — webtable-like mix."""
    kind = rng.random()
    if kind < 0.45:  # syllable word(s)
        n = int(rng.integers(min_syl, max_syl + 1))
        w = "".join(rng.choice(_SYLLABLES) for _ in range(n))
        if rng.random() < 0.2:
            w += " " + rng.choice(_SYLLABLES)
    elif kind < 0.75:  # english-like letter string, varied length
        n = int(rng.integers(3, 20))
        w = "".join(rng.choice(_LETTERS, p=_LETTER_P, size=n))
        if rng.random() < 0.3:
            cut = int(rng.integers(1, n))
            w = w[:cut] + " " + w[cut:]
    elif kind < 0.9:  # numeric / code
        w = str(rng.integers(0, 10 ** int(rng.integers(2, 9))))
        if rng.random() < 0.3:
            w = "".join(rng.choice(_LETTERS, size=2)) + w
    else:  # long composite
        w = (
            "".join(rng.choice(_SYLLABLES) for _ in range(2))
            + " "
            + "".join(rng.choice(_LETTERS, p=_LETTER_P, size=int(rng.integers(4, 12))))
        )
    if rng.random() < 0.1:
        w += str(rng.integers(0, 10_000))
    return w


@dataclasses.dataclass
class SyntheticSpec:
    n_tables: int = 200
    rows_per_table: tuple[int, int] = (5, 60)
    cols_per_table: tuple[int, int] = (2, 24)  # power-law width: most tables
    width_alpha: float = 1.6  # narrow, heavy wide tail (webtable-like);
    # calibrated so hash-function precision ordering and magnitudes match
    # the paper's Table 2 (see EXPERIMENTS.md §Repro/precision)
    avg_pl_length: float = 12.0  # DWTC: ~12 posting-list items per value (§7.6.4)
    zipf_a: float = 1.8  # power-law head on top of the uniform body
    head_frac: float = 0.2  # fraction of cells drawn from the zipfian head
    seed: int = 0


def make_corpus(spec: SyntheticSpec) -> Corpus:
    rng = np.random.default_rng(spec.seed)
    # First pass: table shapes → total cells → pool size for target PL length.
    w_lo, w_hi = spec.cols_per_table
    widths = np.arange(w_lo, w_hi + 1)
    w_p = widths.astype(np.float64) ** -spec.width_alpha
    w_p /= w_p.sum()
    shapes = [
        (int(rng.integers(*spec.rows_per_table)), int(rng.choice(widths, p=w_p)))
        for _ in range(spec.n_tables)
    ]
    total_cells = sum(r * c for r, c in shapes)
    pool_size = max(int(total_cells / spec.avg_pl_length), 50)
    pool = list(dict.fromkeys(_random_word(rng) for _ in range(pool_size * 3)))[:pool_size]
    pool_size = len(pool)
    tables = []
    for tid, (n_rows, n_cols) in enumerate(shapes):
        # power-law head (frequent values everywhere) + uniform body:
        # reproduces the paper's observation that PL length is power-law
        # distributed with a long flat tail (§7.6.4).
        head = (rng.zipf(spec.zipf_a, size=(n_rows, n_cols)) - 1) % pool_size
        body = rng.integers(0, pool_size, size=(n_rows, n_cols))
        use_head = rng.random((n_rows, n_cols)) < spec.head_frac
        idx = np.where(use_head, head, body)
        cells = [[pool[j] for j in row] for row in idx]
        tables.append(Table(table_id=tid, cells=cells))
    return Corpus(tables)


def make_query_with_ground_truth(
    corpus: Corpus,
    n_rows: int = 30,
    key_width: int = 2,
    n_joinable_tables: int = 12,
    seed: int = 1,
) -> tuple[Table, list[int], dict[int, int]]:
    """Build a query table and inject its composite keys into corpus tables.

    Returns (query_table, q_cols, expected ≥joinability per injected table).
    Injection REPLACES the first ``key_width`` cells of random rows of chosen
    tables with the query's key values (in a random column order, to exercise
    the mapping argmax of Eq. 2).
    """
    rng = np.random.default_rng(seed)
    q_cols = list(range(key_width))
    q_cells = [
        [f"qv{r}c{c} " + _random_word(rng) for c in range(key_width + 1)]
        for r in range(n_rows)
    ]
    query = Table(table_id=-1, cells=q_cells)

    eligible = [t for t in corpus.tables if t.n_cols >= key_width and t.n_rows >= 3]
    chosen = rng.choice(len(eligible), size=min(n_joinable_tables, len(eligible)),
                        replace=False)
    expected: dict[int, int] = {}
    for rank, ei in enumerate(chosen):
        table = eligible[int(ei)]
        n_inject = min(2 + rank, table.n_rows, n_rows)
        rows = rng.choice(table.n_rows, size=n_inject, replace=False)
        col_perm = rng.permutation(table.n_cols)[:key_width]
        for i, r in enumerate(rows):
            key = q_cells[i][:key_width]
            for j, c in enumerate(col_perm):
                table.cells[int(r)][int(c)] = key[j]
        expected[table.table_id] = n_inject
    # corpus arenas must be rebuilt after cell surgery
    rebuilt = Corpus(corpus.tables, max_len=corpus.max_len)
    return query, q_cols, expected, rebuilt


def make_mixed_queries(
    corpus: Corpus,
    n_queries: int,
    n_rows: int,
    key_width: int = 2,
    seed: int = 5,
) -> list[tuple[Table, list[int]]]:
    """FP-heavy query workload (the paper's regime): each key column is drawn
    from a DIFFERENT corpus table, so single columns hit many posting lists
    while full composite keys rarely exist — exactly the sensor-data example
    of §1 (location matches many rows, location×timestamp few)."""
    rng = np.random.default_rng(seed)
    tables = [t for t in corpus.tables if t.n_cols >= 1]
    queries = []
    for _ in range(n_queries):
        cols = []
        for _c in range(key_width):
            t = tables[int(rng.integers(len(tables)))]
            col = int(rng.integers(t.n_cols))
            vals = [t.cells[int(rng.integers(t.n_rows))][col] for _ in range(n_rows)]
            cols.append(vals)
        cells = []
        for rowvals in zip(*cols):
            # real-world composite keys don't repeat a value across their own
            # columns; duplicate-value keys create a filter-independent FP
            # floor (multiplicity is invisible to ANY OR-aggregated filter)
            # that would mask the hash-function comparison.
            if len(set(rowvals)) == len(rowvals):
                cells.append(list(rowvals))
        if cells:
            queries.append((Table(table_id=-1, cells=cells), list(range(key_width))))
    return queries


def make_benchmark_queries(
    corpus: Corpus, cardinalities: list[int], per_group: int, seed: int = 7
) -> dict[int, list[tuple[Table, list[int]]]]:
    """Query groups as in §7.1: per cardinality bucket, sample corpus tables
    and use two of their columns as the composite key."""
    rng = np.random.default_rng(seed)
    groups: dict[int, list[tuple[Table, list[int]]]] = {c: [] for c in cardinalities}
    tables = [t for t in corpus.tables if t.n_cols >= 2]
    for card in cardinalities:
        for _ in range(per_group):
            t = tables[int(rng.integers(len(tables)))]
            n = min(t.n_rows, card)
            rows = [t.cells[i] for i in rng.choice(t.n_rows, size=n, replace=False)]
            cols = rng.permutation(t.n_cols)[:2]
            q = Table(table_id=-1, cells=[[r[c] for c in cols] for r in rows])
            groups[card].append((q, [0, 1]))
    return groups
