"""MATE inverted index with super keys (offline phase, paper §4/§5).

The index extends the classic single-attribute inverted index
``value -> [(table, col, row)]`` with one ``super key`` per row
(Eq. 4 → §5.1): the OR-aggregation of the row's per-cell hashes.

Hash functions are pluggable (``hash_name``): 'xash' uses the vectorised JAX
implementation; 'bf'/'ht'/'murmur'/'md5'/'city'/'simhash' are the paper's
baselines (computed per unique value, cached).  Per-unique-value hashing plus
an id-arena makes index build O(unique values) hash work instead of
O(total cells) — same trick the paper's artifact uses.

Index updates (§5.4): ``insert_table`` appends rows/postings/super keys;
``delete_table`` tombstones; ``update_cell`` re-hashes the affected row.

Columnar accessors for the batched online engine (``gather_candidates``,
``superkey_of_keys``, ``superkey_of_rows``) expose the index as contiguous
arrays — posting lists concatenated per candidate table in CSR layout and
query-key super keys hashed in one batched call — so the row filter can run
as a single kernel launch with no per-row dict lookups.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import encoding, hashes, xash
from repro.core.corpus import Corpus, Table

_XASH_CHUNK = 1 << 15


def _hash_unique_values(
    values: list[str],
    enc: np.ndarray,
    cfg: xash.XashConfig,
    hash_name: str,
    avg_row_width: float,
) -> np.ndarray:
    """uint32[n_unique, lanes] hash lanes per unique value."""
    n = len(values)
    out = np.zeros((n, cfg.lanes), dtype=np.uint32)
    if hash_name == "xash":
        for s in range(0, n, _XASH_CHUNK):
            out[s : s + _XASH_CHUNK] = np.asarray(
                xash.xash(enc[s : s + _XASH_CHUNK], cfg)
            )
        return out
    if hash_name == "bf":
        n_hash = hashes.optimal_bloom_hashes(cfg.bits, avg_row_width)
        fn = hashes.make_bloom(n_hash)
    else:
        fn = hashes.BASELINE_HASHES[hash_name]
    shift_mask = (1 << 32) - 1
    for i, v in enumerate(values):
        h = fn(v, cfg.bits)
        for lane in range(cfg.lanes):
            out[i, lane] = (h >> (32 * lane)) & shift_mask
    return out


def _aggregate_superkeys(
    cell_value_ids: np.ndarray, value_lanes: np.ndarray, lanes: int
) -> np.ndarray:
    """OR per-cell hash lanes into per-row super keys (vectorised)."""
    n_rows = cell_value_ids.shape[0]
    sk = np.zeros((n_rows, lanes), dtype=np.uint32)
    valid = cell_value_ids >= 0
    safe_ids = np.where(valid, cell_value_ids, 0)
    gathered = value_lanes[safe_ids]  # [rows, cols, lanes]
    gathered[~valid] = 0
    np.bitwise_or.reduce(gathered, axis=1, out=sk)
    return sk


@dataclasses.dataclass
class CandidateBlock:
    """All PL items for a set of query values, concatenated per candidate
    table (CSR layout) — the contiguous feed for one batched filter launch.

    Tables are ordered by descending item count (ties by ascending table id),
    the same order Algorithm 1 visits them, so rule-1 cutoffs apply to CSR
    prefixes.  Within a table, items keep fetch order (value-major, PL order).
    """

    rows: np.ndarray  # int64[N] global row ids, grouped by table
    value_idx: np.ndarray  # int32[N] index into the queried ``values`` list
    table_ids: np.ndarray  # int64[T] candidate table ids
    table_ptr: np.ndarray  # int64[T+1] CSR boundaries into rows/value_idx

    @property
    def n_items(self) -> int:
        return int(self.rows.shape[0])

    @property
    def n_tables(self) -> int:
        return int(self.table_ids.shape[0])

    def table_slice(self, t: int) -> slice:
        return slice(int(self.table_ptr[t]), int(self.table_ptr[t + 1]))


class MateIndex:
    """Inverted index + per-row super keys for one corpus."""

    def __init__(
        self,
        corpus: Corpus,
        cfg: xash.XashConfig = xash.DEFAULT_CONFIG,
        hash_name: str = "xash",
        use_corpus_char_freq: bool = False,
    ):
        if use_corpus_char_freq and hash_name == "xash":
            # replace() keeps every other knob (bits/width, ablation flags)
            # of the caller's config intact.
            cfg = dataclasses.replace(
                cfg, char_freq=tuple(corpus.char_frequencies().tolist())
            )
        self.corpus = corpus
        self.cfg = cfg
        self.hash_name = hash_name

        self.value_lanes = _hash_unique_values(
            corpus.unique_values,
            corpus.unique_enc,
            cfg,
            hash_name,
            corpus.avg_row_width(),
        )
        self.superkeys = _aggregate_superkeys(
            corpus.cell_value_ids, self.value_lanes, cfg.lanes
        )

        # posting lists: value id -> int64[n, 2] (global_row, col)
        self.postings: dict[int, np.ndarray] = {}
        rows_idx, cols_idx = np.nonzero(corpus.cell_value_ids >= 0)
        vids = corpus.cell_value_ids[rows_idx, cols_idx]
        order = np.argsort(vids, kind="stable")
        vids, rows_idx, cols_idx = vids[order], rows_idx[order], cols_idx[order]
        bounds = np.searchsorted(vids, np.arange(len(corpus.unique_values) + 1))
        payload = np.stack([rows_idx, cols_idx], axis=1).astype(np.int64)
        for vid in range(len(corpus.unique_values)):
            lo, hi = bounds[vid], bounds[vid + 1]
            if hi > lo:
                self.postings[vid] = payload[lo:hi]
        self._deleted_tables: set[int] = set()

    @property
    def bits(self) -> int:
        """Hash width this index was built at (128/256/512 → 4/8/16 lanes)."""
        return self.cfg.bits

    # -- online-side hashing --------------------------------------------------

    def hash_values(self, values: list[str]) -> np.ndarray:
        """Hash arbitrary (query-side) strings with this index's hash fn."""
        enc = encoding.encode_values(values, self.cfg.max_len)
        return _hash_unique_values(
            values, enc, self.cfg, self.hash_name, self.corpus.avg_row_width()
        )

    def superkey_of_keys(self, keys: list[tuple[str, ...]]) -> np.ndarray:
        """Batched query-side key hashing: uint32[len(keys), lanes].

        The super key of a query key is the OR of its value hashes (Alg. 1
        line 6).  For XASH the whole key set is encoded as one
        ``[n_keys, |Q|, max_len]`` block and hashed by a single
        ``xash.superkey`` call; baseline hashes fall back to per-unique-value
        hashing + OR.  Bit-identical to hashing each value separately.
        """
        lanes = self.cfg.lanes
        if not keys:
            return np.zeros((0, lanes), dtype=np.uint32)
        if self.hash_name == "xash":
            width = len(keys[0])
            flat = [v for key in keys for v in key]
            enc = encoding.encode_values(flat, self.cfg.max_len)
            enc = enc.reshape(len(keys), width, self.cfg.max_len)
            return np.asarray(xash.superkey(enc, self.cfg))
        flat_values = sorted({v for key in keys for v in key})
        value_lanes = self.hash_values(flat_values)
        lane_of = {v: value_lanes[i] for i, v in enumerate(flat_values)}
        out = np.zeros((len(keys), lanes), dtype=np.uint32)
        for i, key in enumerate(keys):
            for v in key:
                out[i] |= lane_of[v]
        return out

    # -- lookups --------------------------------------------------------------

    def fetch_postings(self, value: str) -> np.ndarray:
        """PL items for a value: int64[n, 2] of (global_row, col)."""
        vid = self.corpus.value_of.get(value)
        if vid is None or vid not in self.postings:
            return np.zeros((0, 2), dtype=np.int64)
        pl = self.postings[vid]
        if self._deleted_tables:
            tids = self.corpus.table_of_row(pl[:, 0])
            keep = ~np.isin(tids, list(self._deleted_tables))
            pl = pl[keep]
        return pl

    def superkey_of_rows(self, global_rows: np.ndarray) -> np.ndarray:
        """Block gather of per-row super keys: uint32[len(global_rows), lanes]."""
        return self.superkeys[np.asarray(global_rows, dtype=np.int64)]

    def gather_candidates(self, values: list[str]) -> CandidateBlock:
        """Concatenate the posting lists of ``values`` into one CSR block.

        One fetch per value, then a single vectorised group-by-table pass —
        the per-(row, value) dict bookkeeping of the scalar engine collapses
        into three contiguous arrays the filter kernel can consume directly.
        """
        parts_rows: list[np.ndarray] = []
        parts_vidx: list[np.ndarray] = []
        for i, value in enumerate(values):
            pl = self.fetch_postings(value)
            if len(pl):
                parts_rows.append(pl[:, 0])
                parts_vidx.append(np.full(len(pl), i, dtype=np.int32))
        if not parts_rows:
            return CandidateBlock(
                rows=np.zeros(0, dtype=np.int64),
                value_idx=np.zeros(0, dtype=np.int32),
                table_ids=np.zeros(0, dtype=np.int64),
                table_ptr=np.zeros(1, dtype=np.int64),
            )
        rows = np.concatenate(parts_rows)
        vidx = np.concatenate(parts_vidx)
        tids = np.asarray(self.corpus.table_of_row(rows), dtype=np.int64)
        uniq, inv, counts = np.unique(tids, return_inverse=True, return_counts=True)
        # Algorithm 1 visit order: descending item count, ties by table id.
        order = np.lexsort((uniq, -counts))
        rank = np.empty(len(uniq), dtype=np.int64)
        rank[order] = np.arange(len(uniq))
        perm = np.argsort(rank[inv], kind="stable")
        counts_sorted = counts[order]
        ptr = np.zeros(len(uniq) + 1, dtype=np.int64)
        np.cumsum(counts_sorted, out=ptr[1:])
        return CandidateBlock(
            rows=rows[perm],
            value_idx=vidx[perm],
            table_ids=uniq[order],
            table_ptr=ptr,
        )

    # -- index updates (§5.4) ---------------------------------------------------

    def insert_table(self, cells: list[list[str]], name: str = "") -> int:
        """Append a new table; returns its table id."""
        corpus = self.corpus
        table = Table(table_id=len(corpus.tables), cells=cells, name=name)
        n_rows, n_cols = table.n_rows, table.n_cols
        if n_cols > corpus.max_cols:
            pad = n_cols - corpus.max_cols
            corpus.cell_value_ids = np.pad(
                corpus.cell_value_ids, ((0, 0), (0, pad)), constant_values=-1
            )
            corpus.max_cols = n_cols
        corpus.tables.append(table)
        corpus.row_base = np.append(corpus.row_base, corpus.row_base[-1] + n_rows)
        corpus.n_cols = np.append(corpus.n_cols, n_cols)
        base = corpus.total_rows
        corpus.total_rows += n_rows

        new_ids = np.full((n_rows, corpus.max_cols), -1, dtype=np.int32)
        new_value_strs: list[str] = []
        for r, row in enumerate(cells):
            for c, v in enumerate(row):
                vid = corpus.value_of.get(v)
                if vid is None:
                    vid = len(corpus.unique_values)
                    corpus.value_of[v] = vid
                    corpus.unique_values.append(v)
                    new_value_strs.append(v)
                new_ids[r, c] = vid
        if new_value_strs:
            new_enc = encoding.encode_values(new_value_strs, corpus.max_len)
            corpus.unique_enc = np.concatenate([corpus.unique_enc, new_enc])
            new_lanes = _hash_unique_values(
                new_value_strs, new_enc, self.cfg, self.hash_name,
                corpus.avg_row_width(),
            )
            self.value_lanes = np.concatenate([self.value_lanes, new_lanes])
        corpus.cell_value_ids = np.concatenate([corpus.cell_value_ids, new_ids])
        new_sk = _aggregate_superkeys(new_ids, self.value_lanes, self.cfg.lanes)
        self.superkeys = np.concatenate([self.superkeys, new_sk])
        for r in range(n_rows):
            for c in range(len(cells[r])):
                vid = new_ids[r, c]
                item = np.array([[base + r, c]], dtype=np.int64)
                self.postings[vid] = (
                    np.concatenate([self.postings[vid], item])
                    if vid in self.postings
                    else item
                )
        return table.table_id

    def delete_table(self, table_id: int) -> None:
        """Tombstone a table (PL items filtered at fetch; §5.4 delete)."""
        self._deleted_tables.add(table_id)
        lo, hi = self.corpus.row_base[table_id], self.corpus.row_base[table_id + 1]
        self.superkeys[lo:hi] = 0

    def update_cell(self, table_id: int, row: int, col: int, value: str) -> None:
        """Update one cell: re-hash the affected row's super key (§5.4)."""
        corpus = self.corpus
        grow = int(corpus.row_base[table_id]) + row
        old_vid = int(corpus.cell_value_ids[grow, col])
        vid = corpus.value_of.get(value)
        if vid is None:
            vid = len(corpus.unique_values)
            corpus.value_of[value] = vid
            corpus.unique_values.append(value)
            new_enc = encoding.encode_values([value], corpus.max_len)
            corpus.unique_enc = np.concatenate([corpus.unique_enc, new_enc])
            self.value_lanes = np.concatenate(
                [
                    self.value_lanes,
                    _hash_unique_values(
                        [value], new_enc, self.cfg, self.hash_name,
                        corpus.avg_row_width(),
                    ),
                ]
            )
        corpus.tables[table_id].cells[row][col] = value
        corpus.cell_value_ids[grow, col] = vid
        # postings: drop old item, add new
        if old_vid in self.postings:
            pl = self.postings[old_vid]
            keep = ~((pl[:, 0] == grow) & (pl[:, 1] == col))
            self.postings[old_vid] = pl[keep]
        item = np.array([[grow, col]], dtype=np.int64)
        self.postings[vid] = (
            np.concatenate([self.postings[vid], item]) if vid in self.postings else item
        )
        # full re-hash of the row's super key
        self.superkeys[grow] = _aggregate_superkeys(
            corpus.cell_value_ids[grow : grow + 1], self.value_lanes, self.cfg.lanes
        )[0]
