"""MATE inverted index with super keys (offline phase, paper §4/§5).

The index extends the classic single-attribute inverted index
``value -> [(table, col, row)]`` with one ``super key`` per row
(Eq. 4 → §5.1): the OR-aggregation of the row's per-cell hashes.

Hash functions are pluggable (``hash_name``): 'xash' uses the vectorised JAX
implementation; 'bf'/'ht'/'murmur'/'md5'/'city'/'simhash' are the paper's
baselines (computed per unique value, cached).  Per-unique-value hashing plus
an id-arena makes index build O(unique values) hash work instead of
O(total cells) — same trick the paper's artifact uses.

The offline phase itself is SHARDABLE (``build_index``): unique-value
hashing runs under ``shard_map`` over a device mesh
(``kernels.ops.xash_values_mesh``) while super-key aggregation and
posting-list construction run per contiguous row shard with a host-side
merge (``merge_shard_postings``) — every artifact (``value_lanes``,
``superkeys``, posting lists, CSR offsets) is BYTE-IDENTICAL to the
single-host ``MateIndex(...)`` constructor at any shard/device count.
``BuildStats`` records the per-phase accounting.

Index updates (§5.4): ``insert_table`` appends rows/postings/super keys;
``delete_table`` tombstones; ``update_cell`` re-hashes the affected row.
They operate on the merged dict/array state, so they compose identically
with sharded- and single-host-built indexes.

Columnar accessors for the batched online engine (``gather_candidates``,
``superkey_of_keys``, ``superkey_of_rows``) expose the index as contiguous
arrays — posting lists concatenated per candidate table in CSR layout and
query-key super keys hashed in one batched call — so the row filter can run
as a single kernel launch with no per-row dict lookups.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core import encoding, hashes, xash
from repro.core import profiles as profiles_lib
from repro.core.corpus import Corpus, Table

_XASH_CHUNK = 1 << 15


def _resolve_cfg(
    corpus: Corpus, cfg: xash.XashConfig, hash_name: str,
    use_corpus_char_freq: bool,
) -> xash.XashConfig:
    """Apply the corpus-level char-frequency prior (§5.2.1) when asked.

    replace() keeps every other knob (bits/width, ablation flags) of the
    caller's config intact.  Shared by the single-host constructor and the
    sharded builder so both resolve the SAME effective config.
    """
    if use_corpus_char_freq and hash_name == "xash":
        cfg = dataclasses.replace(
            cfg, char_freq=tuple(corpus.char_frequencies().tolist())
        )
    return cfg


def _hash_unique_values(
    values: list[str],
    enc: np.ndarray,
    cfg: xash.XashConfig,
    hash_name: str,
    avg_row_width: float,
) -> np.ndarray:
    """uint32[n_unique, lanes] hash lanes per unique value."""
    n = len(values)
    out = np.zeros((n, cfg.lanes), dtype=np.uint32)
    if hash_name == "xash":
        for s in range(0, n, _XASH_CHUNK):
            out[s : s + _XASH_CHUNK] = np.asarray(
                xash.xash(enc[s : s + _XASH_CHUNK], cfg)
            )
        return out
    if hash_name == "bf":
        n_hash = hashes.optimal_bloom_hashes(cfg.bits, avg_row_width)
        fn = hashes.make_bloom(n_hash)
    else:
        fn = hashes.BASELINE_HASHES[hash_name]
    shift_mask = (1 << 32) - 1
    for i, v in enumerate(values):
        h = fn(v, cfg.bits)
        for lane in range(cfg.lanes):
            out[i, lane] = (h >> (32 * lane)) & shift_mask
    return out


def _aggregate_superkeys(
    cell_value_ids: np.ndarray, value_lanes: np.ndarray, lanes: int
) -> np.ndarray:
    """OR per-cell hash lanes into per-row super keys (vectorised)."""
    n_rows = cell_value_ids.shape[0]
    sk = np.zeros((n_rows, lanes), dtype=np.uint32)
    valid = cell_value_ids >= 0
    safe_ids = np.where(valid, cell_value_ids, 0)
    gathered = value_lanes[safe_ids]  # [rows, cols, lanes]
    gathered[~valid] = 0
    np.bitwise_or.reduce(gathered, axis=1, out=sk)
    return sk


# ---------------------------------------------------------------------------
# Posting-list construction (sharded unit + host-side merge)
# ---------------------------------------------------------------------------


def _shard_postings(
    cell_value_ids: np.ndarray, row_lo: int, row_hi: int, n_values: int
) -> tuple[np.ndarray, np.ndarray]:
    """Posting-list items of rows ``[row_lo, row_hi)`` in mergeable form.

    Returns ``(payload, counts)``: ``payload`` int64[m, 2] of
    (global_row, col) grouped by ascending value id — row-major within a
    value id, the PL order the scalar engine fetches — and ``counts``
    int64[n_values] items per value id.  One call over the full row range is
    exactly the single-host build; per-shard calls merge via
    ``merge_shard_postings``.
    """
    ids = cell_value_ids[row_lo:row_hi]
    rows_idx, cols_idx = np.nonzero(ids >= 0)
    vids = ids[rows_idx, cols_idx]
    order = np.argsort(vids, kind="stable")
    payload = np.stack(
        [rows_idx[order] + row_lo, cols_idx[order]], axis=1
    ).astype(np.int64)
    counts = np.bincount(vids, minlength=n_values).astype(np.int64)
    return payload, counts


def _intern_value(index, value: str) -> int:
    """Resolve ``value`` in the corpus value arena, interning (and hashing)
    it if new — the shared §5.4 mutation primitive.  ``index`` is anything
    with ``corpus``/``cfg``/``hash_name``/``value_lanes`` (``MateIndex`` or
    ``routing.ShardedMateIndex``, whose value arena is replicated)."""
    corpus = index.corpus
    vid = corpus.value_of.get(value)
    if vid is not None:
        return vid
    vid = len(corpus.unique_values)
    corpus.value_of[value] = vid
    corpus.unique_values.append(value)
    new_enc = encoding.encode_values([value], corpus.max_len)
    corpus.unique_enc = np.concatenate([corpus.unique_enc, new_enc])
    index.value_lanes = np.concatenate(
        [
            index.value_lanes,
            _hash_unique_values(
                [value], new_enc, index.cfg, index.hash_name,
                corpus.avg_row_width(),
            ),
        ]
    )
    return vid


def _csr_ptr(counts: np.ndarray) -> np.ndarray:
    ptr = np.zeros(len(counts) + 1, dtype=np.int64)
    np.cumsum(counts, out=ptr[1:])
    return ptr


def merge_shard_postings(
    payloads: list[np.ndarray], counts: list[np.ndarray], n_values: int
) -> tuple[np.ndarray, np.ndarray]:
    """Merge per-shard posting payloads into the global CSR layout.

    Shards cover contiguous ascending row ranges, so placing each shard's
    per-vid group after the previous shards' groups reproduces the global
    row-major order within every value id — the merged ``(payload, ptr)`` is
    byte-identical to a single-host ``_shard_postings`` over all rows.
    """
    total = (
        np.sum(np.stack(counts), axis=0)
        if counts
        else np.zeros(n_values, dtype=np.int64)
    )
    ptr = _csr_ptr(total)
    payload = np.empty((int(ptr[-1]), 2), dtype=np.int64)
    write_at = ptr[:-1].copy()  # next free slot per value id
    for pl, cnt in zip(payloads, counts):
        if not len(pl):
            continue
        group_start = np.cumsum(cnt) - cnt  # this shard's per-vid offsets
        within = np.arange(len(pl), dtype=np.int64) - np.repeat(group_start, cnt)
        payload[np.repeat(write_at, cnt) + within] = pl
        write_at += cnt
    return payload, ptr


def _postings_dict(payload: np.ndarray, ptr: np.ndarray) -> dict[int, np.ndarray]:
    """Explode a CSR posting store into the per-value dict the index serves
    (entries are views into ``payload``; §5.4 mutations replace them with
    fresh arrays, never write through)."""
    postings: dict[int, np.ndarray] = {}
    for vid in range(len(ptr) - 1):
        lo, hi = int(ptr[vid]), int(ptr[vid + 1])
        if hi > lo:
            postings[vid] = payload[lo:hi]
    return postings


@dataclasses.dataclass
class BuildStats:
    """Offline-phase accounting for one ``build_index`` run.

    ``shard_values`` / ``shard_rows`` are the balanced contiguous partitions
    the build used (values for the hash pass, corpus rows for super keys and
    postings).  ``shard_hash_seconds`` is per-shard hash wall time: measured
    per shard on the host-sharded path; on the mesh path every launch is an
    SPMD collective, so each shard's entry is the per-launch total it
    participated in (lockstep by construction).
    """

    n_shards: int = 1
    mesh_shape: dict[str, int] | None = None  # None: no device mesh
    values_total: int = 0
    rows_total: int = 0
    bytes_hashed: int = 0  # encoded bytes fed to the unique-value hash pass
    shard_values: list[int] = dataclasses.field(default_factory=list)
    shard_rows: list[int] = dataclasses.field(default_factory=list)
    shard_hash_seconds: list[float] = dataclasses.field(default_factory=list)
    hash_seconds: float = 0.0
    superkey_seconds: float = 0.0
    postings_seconds: float = 0.0
    merge_seconds: float = 0.0
    profile_seconds: float = 0.0  # per-column ProfileStore pass (ranking)
    profile_bytes: int = 0  # ProfileStore footprint (all arrays)
    total_seconds: float = 0.0

    @property
    def sharded(self) -> bool:
        return self.n_shards > 1


@dataclasses.dataclass
class CandidateBlock:
    """All PL items for a set of query values, concatenated per candidate
    table (CSR layout) — the contiguous feed for one batched filter launch.

    Tables are ordered by descending item count (ties by ascending table id),
    the same order Algorithm 1 visits them, so rule-1 cutoffs apply to CSR
    prefixes.  Within a table, items keep fetch order (value-major, PL order).
    """

    rows: np.ndarray  # int64[N] global row ids, grouped by table
    value_idx: np.ndarray  # int32[N] index into the queried ``values`` list
    table_ids: np.ndarray  # int64[T] candidate table ids
    table_ptr: np.ndarray  # int64[T+1] CSR boundaries into rows/value_idx

    @property
    def n_items(self) -> int:
        return int(self.rows.shape[0])

    @property
    def n_tables(self) -> int:
        return int(self.table_ids.shape[0])

    def table_slice(self, t: int) -> slice:
        return slice(int(self.table_ptr[t]), int(self.table_ptr[t + 1]))


class MateIndex:
    """Inverted index + per-row super keys for one corpus."""

    def __init__(
        self,
        corpus: Corpus,
        cfg: xash.XashConfig = xash.DEFAULT_CONFIG,
        hash_name: str = "xash",
        use_corpus_char_freq: bool = False,
    ):
        cfg = _resolve_cfg(corpus, cfg, hash_name, use_corpus_char_freq)
        self.corpus = corpus
        self.cfg = cfg
        self.hash_name = hash_name

        self.value_lanes = _hash_unique_values(
            corpus.unique_values,
            corpus.unique_enc,
            cfg,
            hash_name,
            corpus.avg_row_width(),
        )
        self.superkeys = _aggregate_superkeys(
            corpus.cell_value_ids, self.value_lanes, cfg.lanes
        )

        # posting lists: value id -> int64[n, 2] (global_row, col); one
        # full-range shard of the same construction the sharded build merges
        n_values = len(corpus.unique_values)
        payload, counts = _shard_postings(
            corpus.cell_value_ids, 0, corpus.total_rows, n_values
        )
        self.postings = _postings_dict(payload, _csr_ptr(counts))
        self._deleted_tables: set[int] = set()
        self._mutations = 0
        self._device_store = None
        self._device_store_epoch = -1
        self._deleted_mask: np.ndarray | None = None
        self._deleted_mask_epoch = -1
        self._profiles: profiles_lib.ProfileStore | None = None

    @classmethod
    def _from_build(
        cls,
        corpus: Corpus,
        cfg: xash.XashConfig,
        hash_name: str,
        value_lanes: np.ndarray,
        superkeys: np.ndarray,
        payload: np.ndarray,
        ptr: np.ndarray,
    ) -> "MateIndex":
        """Assemble an index from prebuilt (possibly shard-merged) artifacts
        — the ``build_index`` seam.  ``cfg`` must already be resolved."""
        self = cls.__new__(cls)
        self.corpus = corpus
        self.cfg = cfg
        self.hash_name = hash_name
        self.value_lanes = value_lanes
        self.superkeys = superkeys
        self.postings = _postings_dict(payload, ptr)
        self._deleted_tables = set()
        self._mutations = 0
        self._device_store = None
        self._device_store_epoch = -1
        self._deleted_mask = None
        self._deleted_mask_epoch = -1
        self._profiles = None
        return self

    @property
    def bits(self) -> int:
        """Hash width this index was built at (128/256/512 → 4/8/16 lanes)."""
        return self.cfg.bits

    @property
    def mutation_epoch(self) -> int:
        """Monotonic count of §5.4 mutations (insert/delete/update) applied
        to this index.  Anything derived from index state at epoch e —
        cached top-k results, cached candidate counts — is valid exactly
        while ``mutation_epoch == e`` still holds (``serve.cache`` keys its
        invalidation on this)."""
        return self._mutations

    def device_store(self):
        """Device-resident per-row superkey store: uint32[total_rows, lanes].

        The gather-fused filter backend DMA-gathers candidate rows from this
        array inside the kernel, so it must track every §5.4 mutation:
        the upload is re-done (lazily, on next access) whenever
        ``mutation_epoch`` moved past the epoch the resident copy was taken
        at — in-place superkey edits (``delete_table`` zeroing,
        ``update_cell`` re-hash) bump the epoch too, so a stale device copy
        can never be served.  Rows stay row-major (each row's lanes
        contiguous) — the layout the kernel's per-row DMA descriptors need.
        """
        if self._device_store is None or self._device_store_epoch != self._mutations:
            import jax.numpy as jnp

            self._device_store = jnp.asarray(self.superkeys)
            self._device_store_epoch = self._mutations
        return self._device_store

    # -- column profiles (ranking subsystem) ----------------------------------

    def profiles(self) -> profiles_lib.ProfileStore:
        """Per-column ``ProfileStore`` for this index, epoch-pinned like the
        device superkey store: ``build_index`` populates it at build time,
        and any §5.4 mutation invalidates it — the next access rebuilds from
        the mutated corpus arenas (lazily, exactly the ``device_store``
        refresh discipline), so the profile gate can never prune against a
        value set the lake no longer has."""
        if self._profiles is None or self._profiles.epoch != self._mutations:
            self._profiles = profiles_lib.build_profiles(
                self.corpus, self.value_lanes, epoch=self._mutations
            )
        return self._profiles

    def gate_candidates(
        self, distinct_keys: list[tuple[str, ...]], table_ids: np.ndarray
    ) -> np.ndarray:
        """Profile gate: bool[n] keep-mask over candidate table ids.

        False only for tables whose profiles PROVE joinability 0 against
        every distinct query key (``profiles.gate_tables``) — pure pruning,
        the verified top-k set is unchanged."""
        kvi, probe, len_bucket, vclass = profiles_lib.query_gate_inputs(
            distinct_keys, self.hash_values
        )
        return profiles_lib.gate_tables(
            self.profiles(),
            np.asarray(table_ids, dtype=np.int64),
            kvi, probe, len_bucket, vclass, len(distinct_keys[0]),
        )

    def profile_features(
        self, table_ids: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Scoring-head feature gather: (card_max, n_rows, sketch) rows for
        the given table ids (``core.ranking.quality_scores`` input)."""
        store = self.profiles()
        ids = np.asarray(table_ids, dtype=np.int64)
        return store.card_max[ids], store.n_rows[ids], store.sketch[ids]

    # -- online-side hashing --------------------------------------------------

    def hash_values(self, values: list[str]) -> np.ndarray:
        """Hash arbitrary (query-side) strings with this index's hash fn."""
        enc = encoding.encode_values(values, self.cfg.max_len)
        return _hash_unique_values(
            values, enc, self.cfg, self.hash_name, self.corpus.avg_row_width()
        )

    def superkey_of_keys(self, keys: list[tuple[str, ...]]) -> np.ndarray:
        """Batched query-side key hashing: uint32[len(keys), lanes].

        The super key of a query key is the OR of its value hashes (Alg. 1
        line 6).  For XASH the whole key set is encoded as one
        ``[n_keys, |Q|, max_len]`` block and hashed by a single
        ``xash.superkey`` call; baseline hashes fall back to per-unique-value
        hashing + OR.  Bit-identical to hashing each value separately.

        Every key must have the same width (one n-ary query per batch):
        ragged widths raise ``ValueError`` on BOTH hash paths — the xash
        branch would otherwise crash (or worse, mis-reshape) in the batched
        encode, and the baseline OR loop would silently hash a different
        query than the caller asked for.
        """
        lanes = self.cfg.lanes
        if not keys:
            return np.zeros((0, lanes), dtype=np.uint32)
        width = len(keys[0])
        for i, key in enumerate(keys):
            if len(key) != width:
                raise ValueError(
                    f"ragged key widths: key 0 has {width} value(s) but key"
                    f" {i} has {len(key)} — superkey_of_keys hashes one"
                    " fixed-width n-ary query key set per call"
                )
        if self.hash_name == "xash":
            flat = [v for key in keys for v in key]
            enc = encoding.encode_values(flat, self.cfg.max_len)
            enc = enc.reshape(len(keys), width, self.cfg.max_len)
            return np.asarray(xash.superkey(enc, self.cfg))
        flat_values = sorted({v for key in keys for v in key})
        value_lanes = self.hash_values(flat_values)
        lane_of = {v: value_lanes[i] for i, v in enumerate(flat_values)}
        out = np.zeros((len(keys), lanes), dtype=np.uint32)
        for i, key in enumerate(keys):
            for v in key:
                out[i] |= lane_of[v]
        return out

    # -- lookups --------------------------------------------------------------

    def _deleted_row_mask(self) -> np.ndarray:
        """bool[total_rows] — True for rows of tombstoned tables.

        Cached on ``mutation_epoch``: ``fetch_postings`` runs once per value
        per query, and rebuilding ``list(self._deleted_tables)`` + ``np.isin``
        there made a delete-heavy lake pay O(values × deleted) on every
        gather.  The mask costs one O(total_rows) pass per mutation epoch
        and turns each fetch's tombstone filter into a direct index.
        """
        if self._deleted_mask_epoch != self._mutations:
            mask = np.zeros(self.corpus.total_rows, dtype=bool)
            rb = self.corpus.row_base
            for t in self._deleted_tables:
                mask[int(rb[t]) : int(rb[t + 1])] = True
            self._deleted_mask = mask
            self._deleted_mask_epoch = self._mutations
        return self._deleted_mask

    def fetch_postings(self, value: str) -> np.ndarray:
        """PL items for a value: int64[n, 2] of (global_row, col)."""
        vid = self.corpus.value_of.get(value)
        if vid is None or vid not in self.postings:
            return np.zeros((0, 2), dtype=np.int64)
        pl = self.postings[vid]
        if self._deleted_tables:
            pl = pl[~self._deleted_row_mask()[pl[:, 0]]]
        return pl

    def superkey_of_rows(self, global_rows: np.ndarray) -> np.ndarray:
        """Block gather of per-row super keys: uint32[len(global_rows), lanes]."""
        return self.superkeys[np.asarray(global_rows, dtype=np.int64)]

    def gather_candidates(self, values: list[str]) -> CandidateBlock:
        """Concatenate the posting lists of ``values`` into one CSR block.

        One fetch per value, then a single vectorised group-by-table pass —
        the per-(row, value) dict bookkeeping of the scalar engine collapses
        into three contiguous arrays the filter kernel can consume directly.
        """
        parts_rows: list[np.ndarray] = []
        parts_vidx: list[np.ndarray] = []
        for i, value in enumerate(values):
            pl = self.fetch_postings(value)
            if len(pl):
                parts_rows.append(pl[:, 0])
                parts_vidx.append(np.full(len(pl), i, dtype=np.int32))
        if not parts_rows:
            return CandidateBlock(
                rows=np.zeros(0, dtype=np.int64),
                value_idx=np.zeros(0, dtype=np.int32),
                table_ids=np.zeros(0, dtype=np.int64),
                table_ptr=np.zeros(1, dtype=np.int64),
            )
        rows = np.concatenate(parts_rows)
        vidx = np.concatenate(parts_vidx)
        tids = np.asarray(self.corpus.table_of_row(rows), dtype=np.int64)
        uniq, inv, counts = np.unique(tids, return_inverse=True, return_counts=True)
        # Algorithm 1 visit order: descending item count, ties by table id.
        order = np.lexsort((uniq, -counts))
        rank = np.empty(len(uniq), dtype=np.int64)
        rank[order] = np.arange(len(uniq))
        perm = np.argsort(rank[inv], kind="stable")
        counts_sorted = counts[order]
        ptr = np.zeros(len(uniq) + 1, dtype=np.int64)
        np.cumsum(counts_sorted, out=ptr[1:])
        return CandidateBlock(
            rows=rows[perm],
            value_idx=vidx[perm],
            table_ids=uniq[order],
            table_ptr=ptr,
        )

    # -- index updates (§5.4) ---------------------------------------------------

    def insert_table(self, cells: list[list[str]], name: str = "") -> int:
        """Append a new table; returns its table id."""
        self._mutations += 1
        corpus = self.corpus
        table = Table(table_id=len(corpus.tables), cells=cells, name=name)
        n_rows, n_cols = table.n_rows, table.n_cols
        if n_cols > corpus.max_cols:
            pad = n_cols - corpus.max_cols
            corpus.cell_value_ids = np.pad(
                corpus.cell_value_ids, ((0, 0), (0, pad)), constant_values=-1
            )
            corpus.max_cols = n_cols
        corpus.tables.append(table)
        corpus.row_base = np.append(corpus.row_base, corpus.row_base[-1] + n_rows)
        corpus.n_cols = np.append(corpus.n_cols, n_cols)
        base = corpus.total_rows
        corpus.total_rows += n_rows

        new_ids = np.full((n_rows, corpus.max_cols), -1, dtype=np.int32)
        new_value_strs: list[str] = []
        for r, row in enumerate(cells):
            for c, v in enumerate(row):
                vid = corpus.value_of.get(v)
                if vid is None:
                    vid = len(corpus.unique_values)
                    corpus.value_of[v] = vid
                    corpus.unique_values.append(v)
                    new_value_strs.append(v)
                new_ids[r, c] = vid
        if new_value_strs:
            new_enc = encoding.encode_values(new_value_strs, corpus.max_len)
            corpus.unique_enc = np.concatenate([corpus.unique_enc, new_enc])
            new_lanes = _hash_unique_values(
                new_value_strs, new_enc, self.cfg, self.hash_name,
                corpus.avg_row_width(),
            )
            self.value_lanes = np.concatenate([self.value_lanes, new_lanes])
        corpus.cell_value_ids = np.concatenate([corpus.cell_value_ids, new_ids])
        new_sk = _aggregate_superkeys(new_ids, self.value_lanes, self.cfg.lanes)
        self.superkeys = np.concatenate([self.superkeys, new_sk])
        for r in range(n_rows):
            for c in range(len(cells[r])):
                vid = new_ids[r, c]
                item = np.array([[base + r, c]], dtype=np.int64)
                self.postings[vid] = (
                    np.concatenate([self.postings[vid], item])
                    if vid in self.postings
                    else item
                )
        return table.table_id

    def delete_table(self, table_id: int) -> None:
        """Tombstone a table (PL items filtered at fetch; §5.4 delete)."""
        self._mutations += 1
        self._deleted_tables.add(table_id)
        lo, hi = self.corpus.row_base[table_id], self.corpus.row_base[table_id + 1]
        self.superkeys[lo:hi] = 0

    def update_cell(self, table_id: int, row: int, col: int, value: str) -> None:
        """Update one cell: re-hash the affected row's super key (§5.4)."""
        self._mutations += 1
        corpus = self.corpus
        grow = int(corpus.row_base[table_id]) + row
        old_vid = int(corpus.cell_value_ids[grow, col])
        vid = _intern_value(self, value)
        corpus.tables[table_id].cells[row][col] = value
        corpus.cell_value_ids[grow, col] = vid
        # postings: drop old item, add new
        if old_vid in self.postings:
            pl = self.postings[old_vid]
            keep = ~((pl[:, 0] == grow) & (pl[:, 1] == col))
            self.postings[old_vid] = pl[keep]
        item = np.array([[grow, col]], dtype=np.int64)
        self.postings[vid] = (
            np.concatenate([self.postings[vid], item]) if vid in self.postings else item
        )
        # full re-hash of the row's super key
        self.superkeys[grow] = _aggregate_superkeys(
            corpus.cell_value_ids[grow : grow + 1], self.value_lanes, self.cfg.lanes
        )[0]


def index_artifacts_equal(a: "MateIndex", b: "MateIndex") -> bool:
    """True iff every offline artifact is byte-identical: value hash lanes
    (incl. dtype), per-row super keys, and per-value posting lists.

    The sharded-build contract's single definition — shared by the
    ``index_build`` bench gate, the launch dry-run and the equivalence test
    matrix, so the three can't drift apart on what "identical" means.
    """
    return (
        a.value_lanes.dtype == b.value_lanes.dtype
        and np.array_equal(a.value_lanes, b.value_lanes)
        and np.array_equal(a.superkeys, b.superkeys)
        and set(a.postings) == set(b.postings)
        and all(
            a.postings[v].dtype == b.postings[v].dtype
            and np.array_equal(a.postings[v], b.postings[v])
            for v in b.postings
        )
    )


# ---------------------------------------------------------------------------
# Sharded offline build (the distributed counterpart of ``MateIndex(...)``)
# ---------------------------------------------------------------------------


def build_index(
    corpus: Corpus,
    cfg: xash.XashConfig = xash.DEFAULT_CONFIG,
    hash_name: str = "xash",
    use_corpus_char_freq: bool = False,
    *,
    mesh=None,
    row_axes: tuple[str, ...] | None = None,
    n_shards: int | None = None,
) -> tuple["MateIndex", BuildStats]:
    """Offline phase (§4/§5) with every pass sharded, plus build accounting.

    With a ``mesh`` of >1 devices, unique-value XASH hashing runs under
    ``shard_map`` over ``row_axes`` (``kernels.ops.xash_values_mesh``) —
    the throughput-critical pass, the same way ``core.distributed`` shards
    the online filter.  Super-key aggregation and posting-list construction
    run per contiguous row shard on the host and merge deterministically
    (``merge_shard_postings``).  Without a mesh, ``n_shards`` splits the
    same passes host-side (shard-merge machinery without devices); the
    default ``n_shards=1`` IS the single-host path.

    Every path yields artifacts byte-identical to ``MateIndex(corpus, ...)``:
    per-value hashing has no cross-value term, super keys are per-row, and
    the posting merge preserves global row-major order within each value id.
    Baseline hashes (``hash_name != 'xash'``) are host-side Python and fall
    back to host-sharded hashing under any mesh.

    Returns ``(index, BuildStats)``.
    """
    t_start = time.perf_counter()
    cfg = _resolve_cfg(corpus, cfg, hash_name, use_corpus_char_freq)
    from repro.core import distributed

    mesh_shards = 0
    if mesh is not None:
        row_axes = tuple(row_axes or mesh.axis_names)
        mesh_shards = distributed.mesh_shard_count(mesh, row_axes)
        if n_shards is None:
            n_shards = mesh_shards
        elif n_shards != mesh_shards:
            raise ValueError(
                f"n_shards={n_shards} conflicts with mesh shard count "
                f"{mesh_shards} over axes {row_axes}"
            )
    n_shards = max(int(n_shards or 1), 1)
    # one device (or one shard) falls back to the single-host pass; baseline
    # hashes are host-side Python functions, so only xash hashes on device
    use_mesh = mesh is not None and mesh_shards > 1 and hash_name == "xash"

    n_values = len(corpus.unique_values)
    stats = BuildStats(
        n_shards=n_shards,
        mesh_shape=(
            {a: int(mesh.shape[a]) for a in row_axes} if use_mesh else None
        ),
        values_total=n_values,
        rows_total=corpus.total_rows,
        bytes_hashed=int(corpus.unique_enc.size),
        shard_values=np.diff(distributed.shard_bounds(n_values, n_shards))
        .astype(int).tolist(),
    )
    avg_w = corpus.avg_row_width()

    # -- unique-value hashing (the throughput-critical pass) ----------------
    t0 = time.perf_counter()
    if use_mesh:
        from repro.kernels import ops

        value_lanes = ops.xash_values_mesh(
            corpus.unique_enc, cfg, mesh=mesh, row_axes=row_axes,
            times_out=stats.shard_hash_seconds,
        )
    else:
        value_lanes = np.zeros((n_values, cfg.lanes), dtype=np.uint32)
        vb = distributed.shard_bounds(n_values, n_shards)
        for i in range(n_shards):
            lo, hi = int(vb[i]), int(vb[i + 1])
            ts = time.perf_counter()
            value_lanes[lo:hi] = _hash_unique_values(
                corpus.unique_values[lo:hi], corpus.unique_enc[lo:hi], cfg,
                hash_name, avg_w,
            )
            stats.shard_hash_seconds.append(time.perf_counter() - ts)
    stats.hash_seconds = time.perf_counter() - t0

    # -- per-row-shard super keys + posting lists ---------------------------
    rb = distributed.shard_bounds(corpus.total_rows, n_shards)
    stats.shard_rows = np.diff(rb).astype(int).tolist()
    t0 = time.perf_counter()
    sk_parts = [
        _aggregate_superkeys(
            corpus.cell_value_ids[int(rb[i]) : int(rb[i + 1])],
            value_lanes, cfg.lanes,
        )
        for i in range(n_shards)
    ]
    stats.superkey_seconds = time.perf_counter() - t0
    t0 = time.perf_counter()
    parts = [
        _shard_postings(corpus.cell_value_ids, int(rb[i]), int(rb[i + 1]), n_values)
        for i in range(n_shards)
    ]
    stats.postings_seconds = time.perf_counter() - t0

    # -- host-side merge ----------------------------------------------------
    t0 = time.perf_counter()
    superkeys = np.concatenate(sk_parts)
    payload, ptr = merge_shard_postings(
        [p for p, _ in parts], [c for _, c in parts], n_values
    )
    index = MateIndex._from_build(
        corpus, cfg, hash_name, value_lanes, superkeys, payload, ptr
    )
    stats.merge_seconds = time.perf_counter() - t0

    # -- per-column profiles (ranking subsystem) ----------------------------
    # Sharded over contiguous TABLE ranges (profiles are per-table, so the
    # row bounds above don't apply) and concatenated — byte-identical to the
    # single-host pass at any shard count, like every artifact above.
    t0 = time.perf_counter()
    n_tables = len(corpus.row_base) - 1
    tb = distributed.shard_bounds(n_tables, n_shards)
    index._profiles = profiles_lib.merge_profiles(
        [
            profiles_lib.build_profiles(
                corpus, value_lanes, int(tb[i]), int(tb[i + 1])
            )
            for i in range(n_shards)
        ]
    )
    stats.profile_seconds = time.perf_counter() - t0
    stats.profile_bytes = index._profiles.nbytes

    stats.total_seconds = time.perf_counter() - t_start
    return index, stats
