"""Fixed-width string encoding for MATE.

The paper's XASH operates on the 37-character alphanumeric alphabet
(a-z, 0-9, space).  TPU-side code cannot hold Python strings, so every cell
value is encoded once, offline, into a fixed-width ``uint8`` vector:

    0          -> padding (also: missing cell)
    1 .. 26    -> 'a' .. 'z'   (values are lowercased)
    27 .. 36   -> '0' .. '9'
    37         -> ' '  (any character outside the alphabet maps to space)

``MAX_LEN`` bounds the value length; longer values are truncated (the paper's
length feature uses ``l_v mod L`` so truncation only perturbs, never breaks,
the no-false-negative property as long as the SAME encoding is used on both
the corpus and the query side — which it is).
"""

from __future__ import annotations

import numpy as np

ALPHABET_SIZE = 37
PAD = 0
MAX_LEN = 48  # default fixed width; configurable per corpus

_CHAR_TO_CODE = np.zeros(256, dtype=np.uint8)
for _i in range(26):
    _CHAR_TO_CODE[ord("a") + _i] = 1 + _i
    _CHAR_TO_CODE[ord("A") + _i] = 1 + _i
for _i in range(10):
    _CHAR_TO_CODE[ord("0") + _i] = 27 + _i
# everything else (incl. real spaces) → space code 37, except NUL padding
for _b in range(1, 256):
    if _CHAR_TO_CODE[_b] == 0:
        _CHAR_TO_CODE[_b] = 37
_CHAR_TO_CODE[0] = 0


# English letter frequencies (per-mille, approximate; Lewand ordering) plus
# digit/space priors.  XASH picks the LEAST frequent characters of a value as
# its most discriminative features; the paper computes corpus-level
# frequencies offline — ``CorpusIndex.char_frequencies`` does that too, and
# this table is the query-independent default prior.
DEFAULT_CHAR_FREQ = np.array(
    [
        # a      b      c      d      e      f      g      h      i
        8.167, 1.492, 2.782, 4.253, 12.702, 2.228, 2.015, 6.094, 6.966,
        # j      k      l      m      n      o      p      q      r
        0.153, 0.772, 4.025, 2.406, 6.749, 7.507, 1.929, 0.095, 5.987,
        # s      t      u      v      w      x      y      z
        6.327, 9.056, 2.758, 0.978, 2.360, 0.150, 1.974, 0.074,
        # 0     1     2     3     4     5     6     7     8     9
        1.0, 1.2, 0.9, 0.8, 0.7, 0.7, 0.6, 0.6, 0.6, 0.6,
        # space
        13.000,
    ],
    dtype=np.float64,
)
assert DEFAULT_CHAR_FREQ.shape == (ALPHABET_SIZE,)


def freq_rank(char_freq: np.ndarray | None = None) -> np.ndarray:
    """Rank of each character code (0-based char id) by ascending frequency.

    ``rank[char_id]`` is small for rare characters.  Ties break by char id so
    the ranking — and therefore XASH — is fully deterministic.
    """
    f = DEFAULT_CHAR_FREQ if char_freq is None else np.asarray(char_freq)
    order = np.lexsort((np.arange(ALPHABET_SIZE), f))
    rank = np.empty(ALPHABET_SIZE, dtype=np.int32)
    rank[order] = np.arange(ALPHABET_SIZE, dtype=np.int32)
    return rank


def encode_value(value: str, max_len: int = MAX_LEN) -> np.ndarray:
    """Encode one string to a ``uint8[max_len]`` vector."""
    raw = value.encode("utf-8", errors="replace")[:max_len]
    out = np.zeros(max_len, dtype=np.uint8)
    if raw:
        out[: len(raw)] = _CHAR_TO_CODE[np.frombuffer(raw, dtype=np.uint8)]
    return out


def encode_values(values: list[str], max_len: int = MAX_LEN) -> np.ndarray:
    """Encode a list of strings to ``uint8[n, max_len]`` (vectorised)."""
    n = len(values)
    out = np.zeros((n, max_len), dtype=np.uint8)
    for i, v in enumerate(values):
        raw = v.encode("utf-8", errors="replace")[:max_len]
        if raw:
            out[i, : len(raw)] = _CHAR_TO_CODE[np.frombuffer(raw, dtype=np.uint8)]
    return out


def decode_value(enc: np.ndarray) -> str:
    """Best-effort inverse of :func:`encode_value` (for debugging)."""
    chars = []
    for code in enc:
        if code == PAD:
            break
        if 1 <= code <= 26:
            chars.append(chr(ord("a") + code - 1))
        elif 27 <= code <= 36:
            chars.append(chr(ord("0") + code - 27))
        else:
            chars.append(" ")
    return "".join(chars)
