"""MATE online discovery (paper §6, Algorithm 1) — faithful implementation.

Four phases: initialization (§6.1), table filtering (§6.2), row filtering
(§6.3), exact joinability calculation (calculateJ).  ``row_filter=False``
yields the SCI baseline (single-column index adapted for n-ary joins: table
filtering allowed, no super-key row filter — §7.2).

Joinability follows Eq. (2): the count of DISTINCT query key combinations
matched under the single column mapping Y' that maximises the overlap.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import defaultdict

import numpy as np

from repro.core.corpus import Corpus, Table
from repro.core.index import MateIndex
from repro.kernels import ops


@dataclasses.dataclass
class DiscoveryStats:
    tables_fetched: int = 0
    tables_evaluated: int = 0
    tables_pruned_rule1: int = 0  # remaining tables skipped when rule 1 fires
    tables_pruned_rule2: int = 0
    pl_items_total: int = 0
    pl_items_checked: int = 0
    filter_checks: int = 0  # (query row, candidate row) super-key probes
    filter_passed: int = 0  # pairs surviving the row filter
    verified_tp: int = 0  # pairs passing exact verification
    verified_fp: int = 0  # pairs surviving filter but failing verification
    # batched-engine transfer accounting (device-side rule 1/2):
    filter_matrix_bytes: int = 0  # full match-matrix bytes the filter produced
    filter_readback_bytes: int = 0  # match bytes materialised host-side
    # (counts vectors + verification slices on the device path; the whole
    # matrix when a host/numpy dispatch produced it directly)
    filter_fused_launches: int = 0  # fused filter+segment-count launches:
    # the match matrix was never produced (not even in HBM), so these
    # contribute ZERO to filter_matrix_bytes — counts-only readback plus
    # on-demand recomputed slices for the tables that survive pruning
    gather_bytes_saved: int = 0  # bytes the gather-fused launches never
    # moved: the composed path ships n×lanes×4 host-gathered superkey bytes
    # per launch, the gather-fused kernel ships n×4 offset bytes and pulls
    # the rows from the device store by DMA (n × (lanes·4 − 4) per launch)
    filter_lanes: int = 0  # uint32 lanes the filter launch probed (0: the
    # scalar engine, which has no lane-sliced filter).  Below the index
    # width this was a DEGRADED launch (serving-tier pressure relief): a
    # lane-prefix subsumption test is a pure relaxation — no false
    # negatives — so exact verification still yields bit-identical top-k,
    # just with more survivors to verify.
    # routed-index accounting (``core.routing.ShardedMateIndex``): the only
    # bytes that cross a shard boundary on the routed path are per-table
    # count vectors — superkey rows never do (owning-shard launches +
    # owning-shard re-gathers for verification).
    shard_launches: int = 0  # shard-local filter launches the routed path ran
    route_bytes_merged: int = 0  # per-table count bytes merged across shards
    # (the ENTIRE cross-shard traffic of a routed filter; compare against
    # n_items × lanes × 4, the superkey bytes a host-gather path would ship)
    shard_gather_demotions: int = 0  # shard launches demoted off the
    # gather-fused path (store over budget / scatter-tile cap / no per-shard
    # store, e.g. the pre-routed mesh row filter) — each is also debug-logged
    # ranking-subsystem accounting (``core.profiles`` / ``core.ranking``):
    tables_gated: int = 0  # candidate tables the profile gate dropped before
    # any filter launch (provably joinability 0 — pure pruning, so the
    # verified top-k set is unchanged; see profiles.gate_tables)
    gate_bytes_saved: int = 0  # superkey bytes the filter launches never
    # touched because the gate dropped those tables' posting items first
    # (items × lanes × 4, same units as gather_bytes_saved)
    ranking_launches: int = 0  # quality-scoring launches (one per batch
    # under rank='quality'; see core.ranking.quality_scores)
    # FD-workload accounting (``core.fd.discover_fds``): counts-as-refutation
    # prunes candidate tables whose filter count upper bound is below
    # min_support (exact on the negative side — the §6.3 filter has no false
    # negatives, so a count below the bar PROVES true support is too), and
    # only survivors pay the validation re-gather.
    fd_candidates: int = 0  # candidate tables entering the FD workload (every
    # table with a posting item for the determinant init column)
    fd_validated: int = 0  # tables surviving the count prune — these re-gather
    # rows for the exact determinant-group → dependent-value check
    fd_bytes_verified: int = 0  # superkey bytes the validation pass re-gathered
    # (n_items × lanes × 4 per surviving table; the prune's whole point is
    # keeping this a small fraction of what validating every candidate costs)

    def merge(self, other: "DiscoveryStats") -> "DiscoveryStats":
        """Accumulate ``other``'s counters into self, field by field.

        Driven by ``dataclasses.fields`` so a newly added counter can never
        be silently dropped — the shard/gather counters of PRs 7–8 each
        hand-patched every aggregation site and this is the one replacement
        for all of them (``SessionStats.absorb``, bench aggregation, ...).
        """
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))
        return self

    @property
    def readback_frac(self) -> float:
        """Fraction of the match matrix materialised on the host (batched
        engines; ~1.0 is the transfer-everything behaviour)."""
        if not self.filter_matrix_bytes:
            return 0.0
        return self.filter_readback_bytes / self.filter_matrix_bytes

    @property
    def precision(self) -> float:
        denom = self.verified_tp + self.verified_fp
        return self.verified_tp / denom if denom else 1.0


@dataclasses.dataclass
class TopKEntry:
    table_id: int
    joinability: int
    mapping: tuple[int, ...] | None  # candidate cols per query col
    quality: float | None = None  # join-quality score (rank='quality' only;
    # annotation — never part of heap selection, see core.ranking)


def init_column_selection(
    query: Table, q_cols: list[int], mode: str = "cardinality",
    index: MateIndex | None = None,
) -> int:
    """§6.1 heuristic (+ Fig. 8 baselines: order / tls / best / worst)."""
    if mode == "order":
        return q_cols[0]
    if mode == "tls":  # longest string
        return max(q_cols, key=lambda c: max((len(v) for v in query.column(c)), default=0))
    if mode in ("best", "worst"):
        assert index is not None, "best/worst need index ground truth"
        totals = {
            c: sum(len(index.fetch_postings(v)) for v in set(query.column(c)))
            for c in q_cols
        }
        return (min if mode == "best" else max)(totals, key=totals.get)
    # cardinality (MATE default): fewest unique values
    return min(q_cols, key=lambda c: (len(set(query.column(c))), q_cols.index(c)))


def build_query_superkeys(index: MateIndex, query: Table, q_cols: list[int]):
    """Map init-column value -> [(key tuple, super key lanes)] (Alg. 1 line 6).

    The query super key of a row is the OR of the XASH (or baseline hash) of
    its |Q| key values only.  Hashing is batched: all distinct keys go through
    ``MateIndex.superkey_of_keys`` in one call (one ``xash.superkey`` launch
    for XASH indexes) instead of per-value host loops.
    """
    keys = [tuple(row[c] for c in q_cols) for row in query.cells]
    distinct = list(dict.fromkeys(keys))
    sks = index.superkey_of_keys(distinct)
    sk_of_key = {key: sks[i] for i, key in enumerate(distinct)}
    return keys, sk_of_key


def _subsumes_np(q_sk: np.ndarray, row_sk: np.ndarray) -> bool:
    return bool(np.all((q_sk & ~row_sk) == 0))


def _verify_pair(
    key: tuple[str, ...], cand_values: list[str]
) -> list[tuple[int, ...]]:
    """All distinct-column mappings (cand col per query col) matching ``key``."""
    per_col: list[list[int]] = []
    for q_val in key:
        cols = [c for c, v in enumerate(cand_values) if v == q_val]
        if not cols:
            return []
        per_col.append(cols)
    out = []
    for assign in itertools.product(*per_col):
        if len(set(assign)) == len(assign):
            out.append(assign)
    return out


def discover(
    index: MateIndex,
    query: Table,
    q_cols: list[int],
    k: int = 10,
    row_filter: bool = True,
    init_mode: str = "cardinality",
) -> tuple[list[TopKEntry], DiscoveryStats]:
    """Algorithm 1. Returns top-k tables (sorted desc) and statistics."""
    stats = DiscoveryStats()
    corpus = index.corpus

    # ---- initialization (lines 3-6) ----
    init_col = init_column_selection(query, q_cols, init_mode, index)
    keys, sk_of_key = build_query_superkeys(index, query, q_cols)
    init_idx = q_cols.index(init_col)
    # init value -> list of distinct key tuples having that init value
    keys_of_value: dict[str, list[tuple]] = defaultdict(list)
    for key in dict.fromkeys(keys):  # distinct keys, stable order
        keys_of_value[key[init_idx]].append(key)

    # fetch PLs for the init column's values, group by table (lines 4-5)
    by_table: dict[int, list[tuple[int, int, str]]] = defaultdict(list)
    for value in dict.fromkeys(query.column(init_col)):
        pl = index.fetch_postings(value)
        stats.pl_items_total += len(pl)
        if len(pl) == 0:
            continue
        tids = corpus.table_of_row(pl[:, 0])
        for (grow, _col), tid in zip(pl.tolist(), np.atleast_1d(tids).tolist()):
            by_table[int(tid)].append((int(grow), int(_col), value))
    candidate_tables = sorted(
        by_table, key=lambda t: (-len(by_table[t]), t)
    )
    stats.tables_fetched = len(candidate_tables)

    # ---- main loop ----
    heap: list[tuple[int, int]] = []  # (J, -table_id) min-heap
    best_mapping: dict[int, tuple[int, ...] | None] = {}

    def j_k() -> int:
        return heap[0][0] if len(heap) >= k else 0

    for pos, tid in enumerate(candidate_tables):
        table_pls = by_table[tid]
        l_t = len(table_pls)
        # table filter rule 1 (lines 9-10): sorted desc → BREAK
        if len(heap) >= k and l_t <= j_k():
            stats.tables_pruned_rule1 += len(candidate_tables) - pos
            break
        stats.tables_evaluated += 1

        # Vectorised row filter: one bitwise subsumption op per table for all
        # (PL item × key) pairs — the C-speed equivalent of the paper's
        # per-row machine-word AND (per-pair Python calls would swamp the
        # measurement with interpreter overhead).  Rule-2 bookkeeping below
        # consumes the precomputed matches in the paper's original order.
        rows_arr = np.fromiter((g for g, _c, _v in table_pls), np.int64, l_t)
        row_sks = index.superkey_of_rows(rows_arr)  # [L, lanes]
        if row_filter:
            for _g, _c, value in table_pls:
                stats.filter_checks += len(keys_of_value[value])
            # group rows by init value → probe each key against its rows
            by_value: dict[str, list[int]] = defaultdict(list)
            for i, (_g, _c, value) in enumerate(table_pls):
                by_value[value].append(i)
            matched_keys: list[list[tuple]] = [[] for _ in range(l_t)]
            for value, idxs in by_value.items():
                keys_here = keys_of_value[value]
                if not keys_here:
                    continue
                q = np.stack([sk_of_key[key] for key in keys_here])  # [m, lanes]
                sub = row_sks[idxs]  # [n, lanes]
                hit = ops.subsume_np(sub, q)  # [n, m]
                for a, i in enumerate(idxs):
                    matched_keys[i] = [
                        key for b, key in enumerate(keys_here) if hit[a, b]
                    ]
        else:
            matched_keys = [keys_of_value[v] for _g, _c, v in table_pls]
            for km in matched_keys:
                stats.filter_checks += len(km)

        r_checked = 0
        matched_items = 0
        pairs: list[tuple[tuple, int]] = []  # (query key, global row)
        pruned = False
        for i, (grow, _col, value) in enumerate(table_pls):
            # table filter rule 2 (lines 14-15)
            if len(heap) >= k and l_t - r_checked + matched_items <= j_k():
                stats.tables_pruned_rule2 += 1
                pruned = True
                break
            km = matched_keys[i]
            stats.filter_passed += len(km)
            for key in km:
                pairs.append((key, grow))
            matched_items += int(bool(km))
            r_checked += 1
            stats.pl_items_checked += 1
        if pruned:
            continue

        # ---- calculateJ (line 21): exact verification + mapping argmax ----
        rows_per_mapping: dict[tuple[int, ...], set] = defaultdict(set)
        for key, grow in pairs:
            mappings = _verify_pair(key, corpus.row_values(grow))
            if mappings:
                stats.verified_tp += 1
                for m in mappings:
                    rows_per_mapping[m].add(key)
            else:
                stats.verified_fp += 1
        if rows_per_mapping:
            mapping, rows = max(
                rows_per_mapping.items(), key=lambda kv: (len(kv[1]), kv[0])
            )
            joinability = len(rows)
        else:
            mapping, joinability = None, 0

        best_mapping[tid] = mapping
        if joinability > 0:
            if len(heap) < k:
                heapq.heappush(heap, (joinability, -tid))
            elif joinability > heap[0][0]:
                heapq.heapreplace(heap, (joinability, -tid))

    entries = [
        TopKEntry(table_id=-neg, joinability=j, mapping=best_mapping.get(-neg))
        for j, neg in heap
    ]
    entries.sort(key=lambda e: (-e.joinability, e.table_id))
    return entries, stats


# ---------------------------------------------------------------------------
# Brute-force oracle (tests): exact top-k by scanning every table.
# ---------------------------------------------------------------------------

def joinability_bruteforce(
    corpus: Corpus, table_id: int, query: Table, q_cols: list[int]
) -> int:
    keys = {tuple(row[c] for c in q_cols) for row in query.cells}
    rows_per_mapping: dict[tuple[int, ...], set] = defaultdict(set)
    for row in corpus.tables[table_id].cells:
        for key in keys:
            for m in _verify_pair(key, row):
                rows_per_mapping[m].add(key)
    return max((len(s) for s in rows_per_mapping.values()), default=0)


def topk_bruteforce(
    corpus: Corpus, query: Table, q_cols: list[int], k: int
) -> list[tuple[int, int]]:
    scores = [
        (joinability_bruteforce(corpus, t.table_id, query, q_cols), t.table_id)
        for t in corpus.tables
    ]
    scores = [(j, t) for j, t in scores if j > 0]
    scores.sort(key=lambda x: (-x[0], x[1]))
    return [(t, j) for j, t in scores[:k]]
