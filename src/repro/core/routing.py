"""Routed multi-host index: per-shard ownership + count-merge query routing.

PR 5's sharded build still merged every shard's postings back onto one host
and PR 7's device store served from one host's memory — fine for one box,
the hard ceiling for a billion-value lake (ROADMAP item 1).  This module
keeps each shard's state RESIDENT where it was built and routes queries to
the data instead:

  * ``MateShard`` — one shard's postings, CSR payload, superkey slice and
    epoch-pinned device store.  Shards own contiguous ascending row ranges
    (the ``merge_shard_postings`` contract), SNAPPED TO TABLE BOUNDARIES so
    every table is wholly owned by exactly one shard.
  * ``ShardedMateIndex`` — duck-types ``MateIndex`` for the engines and the
    serving tier, but holds NO global superkey array and NO global device
    store.  The §6.3 filter runs as shard-local counts-only launches
    (``ops.gather_filter_table_counts`` against each shard's own store, or
    the fused/host fallbacks), and only per-table count vectors are merged
    across shards.  Phase-B verification re-gathers surviving tables'
    superkey slices from the owning shard only.  §5.4 mutations apply
    shard-locally: per-shard ``mutation_epoch``, so an update refreshes one
    shard's device store, never the lake's.

The routed invariant (pinned by ``tests/test_routed.py``): NO superkey row
ever crosses a shard boundary on the filter path — the cross-shard traffic
is exactly ``DiscoveryStats.route_bytes_merged`` bytes of int32 counts
(compare with the ``n_items × lanes × 4`` superkey bytes a host-gather
design ships), over ``DiscoveryStats.shard_launches`` launches.

Table-aligned ownership is what makes the count merge exact: a candidate
table's rows all live on one shard, so per-table counts from different
shards never partially overlap — the merge is a plain sum (the all-reduce
the mesh mode runs as ``jax.lax.psum``), bit-identical to the single-host
counts vector.

Mesh mode (``attach_mesh``): the same shard-local filter runs as ONE
``shard_map`` launch over the per-shard store blocks with the count merge
as an in-program ``psum`` (``core.distributed.make_routed_filter``); without
a mesh the shards launch host-routed, one per owning shard, each against its
own (optionally per-device) resident store.  Both modes produce the same
counts, and both keep superkey rows shard-local.
"""

from __future__ import annotations

import dataclasses
import logging
import time

import numpy as np

from repro.core import profiles as profiles_lib
from repro.core import xash
from repro.core.corpus import Corpus, Table
from repro.core.index import (
    BuildStats,
    MateIndex,
    _aggregate_superkeys,
    _csr_ptr,
    _hash_unique_values,
    _intern_value,
    _postings_dict,
    _resolve_cfg,
    _shard_postings,
)
from repro.kernels import ops, registry
from repro.kernels.registry import Backend

_LOG = logging.getLogger(__name__)


def table_aligned_bounds(row_base: np.ndarray, n_shards: int) -> np.ndarray:
    """int64[n_shards+1] contiguous row bounds over ``row_base`` tables,
    balanced like ``distributed.shard_bounds`` but SNAPPED UP to the next
    table boundary — every table's rows land wholly inside one shard.

    Whole-table ownership is the routing contract: per-table candidate
    counts then come from exactly one shard each, so the cross-shard count
    merge is an exact sum (non-owning shards contribute zero) and phase-B
    verification re-gathers any surviving table from a single shard.
    """
    from repro.core import distributed

    row_base = np.asarray(row_base, dtype=np.int64)
    total = int(row_base[-1])
    ideal = distributed.shard_bounds(total, n_shards)
    bounds = np.zeros(n_shards + 1, dtype=np.int64)
    for i in range(1, n_shards):
        t = int(np.searchsorted(row_base, ideal[i], side="left"))
        t = min(t, len(row_base) - 1)
        bounds[i] = max(int(row_base[t]), int(bounds[i - 1]))
    bounds[n_shards] = total
    return bounds


@dataclasses.dataclass
class MateShard:
    """One shard's resident state: rows [row_lo, row_hi) of the corpus —
    whole tables [table_lo, table_hi) — with the shard's own superkey slice,
    posting lists (GLOBAL row ids, shard-local membership) and an
    epoch-pinned device store.  Mutations bump ``_mutations`` (this shard's
    epoch) only; other shards' stores stay untouched."""

    shard_id: int
    row_lo: int
    row_hi: int
    table_lo: int
    table_hi: int
    superkeys: np.ndarray  # uint32[row_hi-row_lo, lanes]
    postings: dict[int, np.ndarray]  # value id -> int64[m, 2] (global row, col)
    device: object | None = None  # jax device pinning this shard's store
    _mutations: int = 0
    _store: object = None
    _store_epoch: int = -1
    _deleted_tables: set = dataclasses.field(default_factory=set)
    _deleted_mask: np.ndarray | None = None
    _deleted_mask_epoch: int = -1
    # this shard's column-profile store (ranking subsystem), epoch-pinned to
    # THIS shard's mutations exactly like the device store
    _profiles: object = None

    @property
    def n_rows(self) -> int:
        return self.row_hi - self.row_lo

    @property
    def mutation_epoch(self) -> int:
        """Monotonic count of §5.4 mutations applied TO THIS SHARD."""
        return self._mutations

    def owns_table(self, table_id: int) -> bool:
        return self.table_lo <= table_id < self.table_hi

    def device_store(self):
        """This shard's device-resident superkey store, re-uploaded lazily
        when (and only when) THIS shard's mutation epoch moved — the
        per-shard counterpart of ``MateIndex.device_store``."""
        if self._store is None or self._store_epoch != self._mutations:
            import jax
            import jax.numpy as jnp

            arr = jnp.asarray(self.superkeys)
            if self.device is not None:
                arr = jax.device_put(arr, self.device)
            self._store = arr
            self._store_epoch = self._mutations
        return self._store


class ShardedMateIndex:
    """Routed multi-shard index, duck-typing ``MateIndex`` for the engines.

    The engines detect the routed path via the ``routed`` class attribute
    and divert their filter launches to ``routed_counts`` BEFORE touching
    any global-array surface (there is none here: superkeys live per shard).
    Everything row-free — query-key hashing, candidate CSR assembly, the
    Algorithm 1 visit order — reuses ``MateIndex``'s own methods unchanged,
    so the two index types cannot drift apart on query semantics.
    """

    routed = True

    def __init__(
        self,
        corpus: Corpus,
        cfg: xash.XashConfig = xash.DEFAULT_CONFIG,
        hash_name: str = "xash",
        use_corpus_char_freq: bool = False,
        n_shards: int = 2,
        devices: list | None = None,
    ):
        cfg = _resolve_cfg(corpus, cfg, hash_name, use_corpus_char_freq)
        value_lanes = _hash_unique_values(
            corpus.unique_values, corpus.unique_enc, cfg, hash_name,
            corpus.avg_row_width(),
        )
        self._init_from_parts(
            corpus, cfg, hash_name, value_lanes, n_shards, devices
        )

    def _init_from_parts(
        self, corpus, cfg, hash_name, value_lanes, n_shards, devices=None
    ) -> None:
        """Shared constructor tail: per-shard superkeys + postings from the
        replicated value-hash arena (``build_routed_index`` seam)."""
        self.corpus = corpus
        self.cfg = cfg
        self.hash_name = hash_name
        self.value_lanes = value_lanes
        n_shards = max(int(n_shards), 1)
        n_values = len(corpus.unique_values)
        bounds = table_aligned_bounds(corpus.row_base, n_shards)
        table_bounds = np.searchsorted(corpus.row_base, bounds)
        if devices is None:
            try:
                import jax

                devices = jax.devices()
            except Exception:  # pragma: no cover - jax always importable here
                devices = []
        self.shards: list[MateShard] = []
        for i in range(n_shards):
            lo, hi = int(bounds[i]), int(bounds[i + 1])
            payload, counts = _shard_postings(
                corpus.cell_value_ids, lo, hi, n_values
            )
            self.shards.append(
                MateShard(
                    shard_id=i,
                    row_lo=lo,
                    row_hi=hi,
                    table_lo=int(table_bounds[i]),
                    table_hi=int(table_bounds[i + 1]),
                    superkeys=_aggregate_superkeys(
                        corpus.cell_value_ids[lo:hi], value_lanes, cfg.lanes
                    ),
                    postings=_postings_dict(payload, _csr_ptr(counts)),
                    device=devices[i % len(devices)] if devices else None,
                )
            )
        self._mesh = None
        self._row_axes = None
        self._mesh_filter_cache: dict = {}
        self._mesh_store_cache: tuple | None = None

    @classmethod
    def _from_build(
        cls, corpus, cfg, hash_name, value_lanes, n_shards, devices=None
    ) -> "ShardedMateIndex":
        """Assemble from a prebuilt (possibly mesh-hashed) value arena —
        the ``build_routed_index`` seam.  ``cfg`` must be resolved."""
        self = cls.__new__(cls)
        self._init_from_parts(
            corpus, cfg, hash_name, value_lanes, n_shards, devices
        )
        return self

    # -- MateIndex duck-type surface (row-free paths reused verbatim) -------

    hash_values = MateIndex.hash_values
    superkey_of_keys = MateIndex.superkey_of_keys
    gather_candidates = MateIndex.gather_candidates

    @property
    def bits(self) -> int:
        return self.cfg.bits

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def shard_row_bounds(self) -> np.ndarray:
        """int64[n_shards+1] — the contiguous ascending ownership bounds."""
        return np.asarray(
            [self.shards[0].row_lo] + [s.row_hi for s in self.shards],
            dtype=np.int64,
        )

    @property
    def mutation_epoch(self) -> int:
        """Aggregate §5.4 epoch: the SUM of per-shard epochs — monotonic, so
        everything keyed on it (serve caches, ``PlanCounts.epoch``)
        invalidates exactly when any shard changed.  Per-shard staleness
        (which store actually re-uploads) is tracked per shard."""
        return sum(s.mutation_epoch for s in self.shards)

    def shard_of_table(self, table_id: int) -> MateShard:
        """The one shard owning ``table_id`` (whole-table ownership)."""
        rb = int(self.corpus.row_base[table_id])
        return self.shards[self._shard_ids_of_rows(np.asarray([rb]))[0]]

    def _shard_ids_of_rows(self, global_rows: np.ndarray) -> np.ndarray:
        bounds = self.shard_row_bounds
        sid = np.searchsorted(bounds, np.asarray(global_rows), side="right") - 1
        return np.clip(sid, 0, len(self.shards) - 1).astype(np.int64)

    # -- lookups ------------------------------------------------------------

    def fetch_postings(self, value: str) -> np.ndarray:
        """PL items for a value, shard-merged: int64[n, 2] (global row, col).

        Shards cover contiguous ascending row ranges, so concatenating their
        per-value slices in shard order IS the global row-major PL order —
        the ``merge_shard_postings`` argument, applied at fetch time instead
        of build time.  Bit-identical to ``MateIndex.fetch_postings``.
        """
        vid = self.corpus.value_of.get(value)
        if vid is None:
            return np.zeros((0, 2), dtype=np.int64)
        parts = []
        for s in self.shards:
            pl = s.postings.get(vid)
            if pl is None:
                continue
            if s._deleted_tables:
                pl = pl[~self._shard_deleted_mask(s)[pl[:, 0] - s.row_lo]]
            if len(pl):
                parts.append(pl)
        if not parts:
            return np.zeros((0, 2), dtype=np.int64)
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    def _shard_deleted_mask(self, shard: MateShard) -> np.ndarray:
        """Shard-local tombstone row mask, epoch-cached on the SHARD."""
        if shard._deleted_mask_epoch != shard._mutations:
            mask = np.zeros(shard.n_rows, dtype=bool)
            rb = self.corpus.row_base
            for t in shard._deleted_tables:
                mask[int(rb[t]) - shard.row_lo : int(rb[t + 1]) - shard.row_lo] = True
            shard._deleted_mask = mask
            shard._deleted_mask_epoch = shard._mutations
        return shard._deleted_mask

    def superkey_of_rows(self, global_rows: np.ndarray) -> np.ndarray:
        """Routed block gather: each row's superkey comes from its OWNING
        shard's slice — the phase-B verification re-gather.  Surviving
        tables are wholly owned, so a table's slice touches one shard."""
        rows = np.asarray(global_rows, dtype=np.int64)
        out = np.empty((rows.shape[0], self.cfg.lanes), dtype=np.uint32)
        if rows.shape[0] == 0:
            return out
        sid = self._shard_ids_of_rows(rows)
        for s in np.unique(sid):
            shard = self.shards[int(s)]
            m = sid == s
            out[m] = shard.superkeys[rows[m] - shard.row_lo]
        return out

    # -- column profiles (ranking subsystem), shard-local -------------------

    def _shard_ids_of_tables(self, table_ids: np.ndarray) -> np.ndarray:
        """Owning shard id per table (whole-table ownership, vectorised)."""
        his = np.asarray([s.table_hi for s in self.shards], dtype=np.int64)
        sid = np.searchsorted(his, np.asarray(table_ids), side="right")
        return np.clip(sid, 0, len(self.shards) - 1).astype(np.int64)

    def _shard_profiles(self, shard: MateShard) -> profiles_lib.ProfileStore:
        """The shard's own ``ProfileStore`` over its tables [table_lo,
        table_hi), rebuilt lazily when THIS shard's §5.4 epoch moved — the
        per-shard counterpart of ``MateIndex.profiles`` (and the same
        refresh discipline as ``MateShard.device_store``)."""
        if (
            shard._profiles is None
            or shard._profiles.epoch != shard._mutations
        ):
            shard._profiles = profiles_lib.build_profiles(
                self.corpus, self.value_lanes,
                shard.table_lo, shard.table_hi,
                epoch=shard._mutations,
            )
        return shard._profiles

    def gate_candidates(
        self, distinct_keys: list[tuple[str, ...]], table_ids: np.ndarray
    ) -> np.ndarray:
        """Routed profile gate: the query's gate inputs are computed once,
        each candidate table is gated against its OWNING shard's profile
        store — no profile bytes cross shards, matching the filter-path
        routing contract.  Same keep-mask as the single-host gate."""
        ids = np.asarray(table_ids, dtype=np.int64)
        keep = np.ones(ids.shape[0], dtype=bool)
        if ids.shape[0] == 0 or not distinct_keys:
            return keep
        kvi, probe, len_bucket, vclass = profiles_lib.query_gate_inputs(
            distinct_keys, self.hash_values
        )
        width = len(distinct_keys[0])
        sid = self._shard_ids_of_tables(ids)
        for s in np.unique(sid):
            shard = self.shards[int(s)]
            m = sid == s
            keep[m] = profiles_lib.gate_tables(
                self._shard_profiles(shard), ids[m] - shard.table_lo,
                kvi, probe, len_bucket, vclass, width,
            )
        return keep

    def profile_features(
        self, table_ids: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Scoring-head feature gather, each row from its owning shard's
        store (``MateIndex.profile_features`` routed counterpart)."""
        ids = np.asarray(table_ids, dtype=np.int64)
        n = ids.shape[0]
        card = np.zeros(n, dtype=np.int32)
        rows = np.zeros(n, dtype=np.int32)
        sketch = np.zeros((n, profiles_lib.SKETCH_K), dtype=np.uint32)
        if n == 0:
            return card, rows, sketch
        sid = self._shard_ids_of_tables(ids)
        for s in np.unique(sid):
            shard = self.shards[int(s)]
            m = sid == s
            store = self._shard_profiles(shard)
            local = ids[m] - shard.table_lo
            card[m] = store.card_max[local]
            rows[m] = store.n_rows[local]
            sketch[m] = store.sketch[local]
        return card, rows, sketch

    # -- the routed filter --------------------------------------------------

    def attach_mesh(self, mesh, row_axes: tuple[str, ...] | None = None) -> None:
        """Run the routed filter as ONE ``shard_map`` launch over the mesh
        (count merge = in-program ``psum``) instead of host-routed per-shard
        launches.  The mesh's shard count must equal ``n_shards`` — shard i's
        store block lives on mesh slot i, so ownership and placement agree.
        """
        from repro.core import distributed

        row_axes = tuple(row_axes or mesh.axis_names)
        n = distributed.mesh_shard_count(mesh, row_axes)
        if n != self.n_shards:
            raise ValueError(
                f"mesh shards ({n} over axes {row_axes}) must match index"
                f" shards ({self.n_shards})"
            )
        self._mesh = mesh
        self._row_axes = row_axes
        self._mesh_filter_cache.clear()
        self._mesh_store_cache = None

    def detach_mesh(self) -> None:
        self._mesh = None
        self._row_axes = None
        self._mesh_filter_cache.clear()
        self._mesh_store_cache = None

    def routed_counts(
        self,
        rows: np.ndarray,
        query_sk: np.ndarray,
        elig: np.ndarray,
        seg_ids: np.ndarray,
        n_tables: int,
        *,
        backend: Backend | str | None = None,
        fused_block_n: int | None = None,
        stats=None,
    ) -> np.ndarray:
        """Per-table eligible-hit counts for one batch, computed WHERE THE
        ROWS LIVE: one counts-only launch per owning shard against that
        shard's resident store, merged by summation.  Bit-identical to the
        single-host counts (whole-table ownership: each table's count comes
        from exactly one shard; the others contribute zero).

        ``stats`` (a ``DiscoveryStats``) receives the routed accounting:
        ``shard_launches``, ``route_bytes_merged`` (the ONLY cross-shard
        bytes), ``filter_fused_launches``/``gather_bytes_saved`` for the
        launches that ran fused/gather-fused, and ``shard_gather_demotions``
        (+ a debug log) when a gather-capable backend had to demote.
        """
        bk = registry.resolve_backend(backend)
        counts = np.zeros(n_tables, dtype=np.int32)
        rows = np.asarray(rows, dtype=np.int64)
        n, q = rows.shape[0], query_sk.shape[0]
        if n == 0 or q == 0 or n_tables == 0:
            return counts
        if self._mesh is not None and self.n_shards > 1:
            return self._routed_counts_mesh(
                rows, query_sk, elig, seg_ids, n_tables, bk, stats
            )
        sid = self._shard_ids_of_rows(rows)
        for s in np.unique(sid):
            shard = self.shards[int(s)]
            m = sid == s
            local = rows[m] - shard.row_lo
            elig_s = elig[m]
            seg_s = np.asarray(seg_ids)[m]
            c = self._shard_counts(
                shard, local, query_sk, elig_s, seg_s, n_tables, bk,
                fused_block_n, stats,
            )
            counts += c
            if stats is not None:
                stats.shard_launches += 1
                # the merge ships this shard's counts vector — nothing else
                stats.route_bytes_merged += int(c.nbytes)
        return counts

    def _shard_counts(
        self, shard, local, query_sk, elig_s, seg_s, n_tables, bk,
        fused_block_n, stats,
    ) -> np.ndarray:
        """One shard-local counts-only launch (gather-fused → fused → host)."""
        fl = query_sk.shape[1]
        if (
            bk.gather
            and n_tables <= ops._FUSED_MAX_TABLES
            and ops.gather_store_fits(shard.superkeys)
        ):
            c = ops.gather_filter_table_counts(
                shard.device_store(), local, query_sk, elig_s, seg_s,
                n_tables, block_n=fused_block_n,
            )
            if stats is not None:
                stats.filter_fused_launches += 1
                stats.gather_bytes_saved += int(local.shape[0]) * (fl * 4 - 4)
            return c
        if bk.gather:
            _LOG.debug(
                "routed shard %d: demoting fused-gather (tables=%d, store"
                " %d bytes) to the host-gather fused launch",
                shard.shard_id, n_tables, shard.superkeys.nbytes,
            )
            if stats is not None:
                stats.shard_gather_demotions += 1
        row_sk = shard.superkeys[local][:, :fl]
        if (bk.fused or bk.gather) and n_tables <= ops._FUSED_MAX_TABLES:
            c = ops.filter_table_counts(
                row_sk, query_sk, elig_s, seg_s, n_tables,
                block_n=fused_block_n,
            )
            if stats is not None:
                stats.filter_fused_launches += 1
            return c
        # composed/host backends (and the over-cap fallback): counts-only by
        # construction — the shard-local matrix never leaves the shard.
        hits = ops.subsume_np(row_sk, query_sk) & np.asarray(elig_s, dtype=bool)
        return np.bincount(
            np.asarray(seg_s, dtype=np.int64),
            weights=hits.sum(axis=1),
            minlength=n_tables,
        ).astype(np.int32)[:n_tables]

    def _routed_counts_mesh(
        self, rows, query_sk, elig, seg_ids, n_tables, bk, stats
    ) -> np.ndarray:
        """Mesh mode: ONE shard_map launch, per-shard filter + psum merge."""
        from repro.core import distributed

        counts, demoted = distributed.routed_filter_counts_mesh(
            self, rows, query_sk, elig, seg_ids, n_tables, bk
        )
        if stats is not None:
            stats.shard_launches += self.n_shards
            stats.route_bytes_merged += int(counts.nbytes) * self.n_shards
            if demoted:
                stats.shard_gather_demotions += self.n_shards
            else:
                stats.filter_fused_launches += self.n_shards
        return counts

    # -- index updates (§5.4), applied shard-locally ------------------------

    def insert_table(self, cells: list[list[str]], name: str = "") -> int:
        """Append a table to the LAST shard (preserves contiguous ascending
        ownership) — only that shard's epoch bumps, so only its device store
        re-uploads; every other shard's resident state is untouched."""
        corpus = self.corpus
        shard = self.shards[-1]
        shard._mutations += 1
        table = Table(table_id=len(corpus.tables), cells=cells, name=name)
        n_rows, n_cols = table.n_rows, table.n_cols
        if n_cols > corpus.max_cols:
            corpus.cell_value_ids = np.pad(
                corpus.cell_value_ids,
                ((0, 0), (0, n_cols - corpus.max_cols)),
                constant_values=-1,
            )
            corpus.max_cols = n_cols
        corpus.tables.append(table)
        corpus.row_base = np.append(corpus.row_base, corpus.row_base[-1] + n_rows)
        corpus.n_cols = np.append(corpus.n_cols, n_cols)
        base = corpus.total_rows
        corpus.total_rows += n_rows

        new_ids = np.full((n_rows, corpus.max_cols), -1, dtype=np.int32)
        for r, row in enumerate(cells):
            for c, v in enumerate(row):
                new_ids[r, c] = _intern_value(self, v)
        corpus.cell_value_ids = np.concatenate([corpus.cell_value_ids, new_ids])
        new_sk = _aggregate_superkeys(new_ids, self.value_lanes, self.cfg.lanes)
        shard.superkeys = np.concatenate([shard.superkeys, new_sk])
        shard.row_hi += n_rows
        shard.table_hi += 1
        for r in range(n_rows):
            for c in range(len(cells[r])):
                vid = int(new_ids[r, c])
                item = np.array([[base + r, c]], dtype=np.int64)
                shard.postings[vid] = (
                    np.concatenate([shard.postings[vid], item])
                    if vid in shard.postings
                    else item
                )
        return table.table_id

    def delete_table(self, table_id: int) -> None:
        """Tombstone on the OWNING shard only (its epoch, its store)."""
        shard = self.shard_of_table(table_id)
        shard._mutations += 1
        shard._deleted_tables.add(table_id)
        lo = int(self.corpus.row_base[table_id]) - shard.row_lo
        hi = int(self.corpus.row_base[table_id + 1]) - shard.row_lo
        shard.superkeys[lo:hi] = 0

    def update_cell(self, table_id: int, row: int, col: int, value: str) -> None:
        """Update one cell: postings swap + row re-hash, all on the owning
        shard — the other shards' epochs (and device stores) do not move."""
        corpus = self.corpus
        shard = self.shard_of_table(table_id)
        shard._mutations += 1
        grow = int(corpus.row_base[table_id]) + row
        old_vid = int(corpus.cell_value_ids[grow, col])
        vid = _intern_value(self, value)
        corpus.tables[table_id].cells[row][col] = value
        corpus.cell_value_ids[grow, col] = vid
        if old_vid in shard.postings:
            pl = shard.postings[old_vid]
            keep = ~((pl[:, 0] == grow) & (pl[:, 1] == col))
            shard.postings[old_vid] = pl[keep]
        item = np.array([[grow, col]], dtype=np.int64)
        shard.postings[vid] = (
            np.concatenate([shard.postings[vid], item])
            if vid in shard.postings
            else item
        )
        shard.superkeys[grow - shard.row_lo] = _aggregate_superkeys(
            corpus.cell_value_ids[grow : grow + 1], self.value_lanes,
            self.cfg.lanes,
        )[0]

    def __repr__(self) -> str:
        return (
            f"ShardedMateIndex(shards={self.n_shards}, "
            f"rows={self.corpus.total_rows}, bits={self.bits}, "
            f"mesh={'attached' if self._mesh is not None else 'none'})"
        )


def build_routed_index(
    corpus: Corpus,
    cfg: xash.XashConfig = xash.DEFAULT_CONFIG,
    hash_name: str = "xash",
    use_corpus_char_freq: bool = False,
    *,
    n_shards: int | None = None,
    mesh=None,
    row_axes: tuple[str, ...] | None = None,
    devices: list | None = None,
) -> tuple[ShardedMateIndex, BuildStats]:
    """Offline phase for the ROUTED lake: same sharded passes as
    ``core.index.build_index`` (mesh-sharded unique-value hashing when a
    mesh is given), but per-shard artifacts are NEVER merged — each shard
    keeps its postings/superkeys resident and the index routes to them.
    ``BuildStats.merge_seconds`` is therefore structurally zero here.

    With a ``mesh``, ``n_shards`` defaults to the mesh shard count and the
    returned index comes with the mesh ATTACHED (shard_map filter mode).
    """
    t_start = time.perf_counter()
    cfg = _resolve_cfg(corpus, cfg, hash_name, use_corpus_char_freq)
    from repro.core import distributed

    mesh_shards = 0
    if mesh is not None:
        row_axes = tuple(row_axes or mesh.axis_names)
        mesh_shards = distributed.mesh_shard_count(mesh, row_axes)
        if n_shards is None:
            n_shards = mesh_shards
        elif n_shards != mesh_shards:
            raise ValueError(
                f"n_shards={n_shards} conflicts with mesh shard count "
                f"{mesh_shards} over axes {row_axes}"
            )
    n_shards = max(int(n_shards or 1), 1)
    use_mesh = mesh is not None and mesh_shards > 1 and hash_name == "xash"

    n_values = len(corpus.unique_values)
    stats = BuildStats(
        n_shards=n_shards,
        mesh_shape=(
            {a: int(mesh.shape[a]) for a in row_axes} if use_mesh else None
        ),
        values_total=n_values,
        rows_total=corpus.total_rows,
        bytes_hashed=int(corpus.unique_enc.size),
        shard_values=np.diff(distributed.shard_bounds(n_values, n_shards))
        .astype(int).tolist(),
    )

    t0 = time.perf_counter()
    if use_mesh:
        value_lanes = ops.xash_values_mesh(
            corpus.unique_enc, cfg, mesh=mesh, row_axes=row_axes,
            times_out=stats.shard_hash_seconds,
        )
    else:
        value_lanes = np.zeros((n_values, cfg.lanes), dtype=np.uint32)
        vb = distributed.shard_bounds(n_values, n_shards)
        for i in range(n_shards):
            lo, hi = int(vb[i]), int(vb[i + 1])
            ts = time.perf_counter()
            value_lanes[lo:hi] = _hash_unique_values(
                corpus.unique_values[lo:hi], corpus.unique_enc[lo:hi], cfg,
                hash_name, corpus.avg_row_width(),
            )
            stats.shard_hash_seconds.append(time.perf_counter() - ts)
    stats.hash_seconds = time.perf_counter() - t0

    t0 = time.perf_counter()
    index = ShardedMateIndex._from_build(
        corpus, cfg, hash_name, value_lanes, n_shards, devices
    )
    stats.shard_rows = [s.n_rows for s in index.shards]
    stats.superkey_seconds = time.perf_counter() - t0  # superkeys + postings
    # per-shard column profiles (ranking subsystem): built where the tables
    # live and NEVER merged — the routed gate/score paths read each owning
    # shard's store, mirroring the resident-postings design above.
    t0 = time.perf_counter()
    for s in index.shards:
        s._profiles = profiles_lib.build_profiles(
            corpus, value_lanes, s.table_lo, s.table_hi, epoch=0
        )
    stats.profile_seconds = time.perf_counter() - t0
    stats.profile_bytes = sum(s._profiles.nbytes for s in index.shards)
    if use_mesh:
        index.attach_mesh(mesh, row_axes)
    stats.total_seconds = time.perf_counter() - t_start
    return index, stats
