"""Distributed MATE discovery: corpus sharded over the device mesh.

Both halves of the system shard the same way.  The ONLINE filtering layer
(the paper's hot loop) is embarrassingly parallel over candidate rows; the
OFFLINE build (``core.index.build_index``) is embarrassingly parallel over
unique values (hashing) and corpus rows (super keys, posting lists).  The
shard helpers at the bottom of this module (``shard_bounds``,
``mesh_shard_count``, ``pad_rows_to_shards``, ``shard_map_compat``) are the
shared vocabulary: contiguous balanced row/value blocks, padded to the mesh
where device work needs equal shards.

For the online filter the natural large-scale layout is:

  * per-row super keys  uint32[n_rows, lanes]   → sharded over ALL mesh axes
    (rows are block-partitioned; a row's table never matters to the filter)
  * row→table ids       int32[n_rows]           → sharded identically
  * query super keys    uint32[n_keys, lanes]   → replicated
  * per-table candidate counts int32[n_tables]  → psum over row shards

A 512-chip pod-pair therefore filters ~512× the rows per step; the host-side
top-k logic (tiny) consumes the psum'ed per-table counts.  This module is the
dry-run/roofline target for the paper's own technique ("mate-filter" row in
EXPERIMENTS.md §Roofline).

Elastic scaling: the arrays are resharded by ``jax.device_put`` with a new
mesh — no host state depends on the mesh shape.  Straggler mitigation: row
blocks are balanced by construction (equal shard sizes after padding).
"""

from __future__ import annotations

import functools
import inspect

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels import registry
from repro.kernels.registry import Backend

# jax.shard_map landed after 0.4.x; fall back to the experimental home
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map

# the version-compat shard_map entry shared with the offline build
# (kernels.ops.xash_values_mesh) — same callable the filter wraps below
shard_map_compat = _shard_map


def _no_rep_check_kwargs() -> dict:
    """shard_map kwargs disabling the replication-rule check (pallas_call has
    no replication rule); the flag was renamed check_rep → check_vma."""
    params = inspect.signature(_shard_map).parameters
    for name in ("check_rep", "check_vma"):
        if name in params:
            return {name: False}
    return {}


def filter_counts_local(
    superkeys: jnp.ndarray,  # uint32[rows_local, lanes]
    row_tables: jnp.ndarray,  # int32[rows_local] (-1 for padding rows)
    query_sks: jnp.ndarray,  # uint32[n_keys, lanes]
    n_tables: int,
):
    """Per-table and per-key candidate counts for a local row shard."""
    conflict = query_sks[None, :, :] & ~superkeys[:, None, :]
    match = jnp.all(conflict == 0, axis=-1)  # [rows, keys]
    valid = (row_tables >= 0)[:, None]
    match = match & valid
    per_row = jnp.any(match, axis=-1).astype(jnp.int32)  # row matches ≥1 key
    table_counts = jnp.zeros((n_tables,), jnp.int32).at[
        jnp.maximum(row_tables, 0)
    ].add(per_row)
    key_counts = jnp.sum(match, axis=0, dtype=jnp.int32)  # [keys]
    return table_counts, key_counts


def filter_counts_local_blocked(
    superkeys: jnp.ndarray,
    row_tables: jnp.ndarray,
    query_sks: jnp.ndarray,
    n_tables: int,
    row_block: int = 1 << 16,
):
    """Memory-optimised probe: lane-unrolled (never materialises the
    [rows, keys, lanes] conflict tensor — peak is [block, keys] bool) and
    row-blocked via ``lax.map`` so HBM traffic is one streaming pass over the
    super keys (§Perf hillclimb 'mate-filter')."""
    lanes = superkeys.shape[1]
    n = superkeys.shape[0]
    nb = -(-n // row_block)
    pad = nb * row_block - n
    sk = jnp.pad(superkeys, ((0, pad), (0, 0)))
    rt = jnp.pad(row_tables, (0, pad), constant_values=-1)
    sk = sk.reshape(nb, row_block, lanes)
    rt = rt.reshape(nb, row_block)

    def block(args):
        skb, rtb = args
        ok = None
        for l in range(lanes):
            conflict_l = (query_sks[None, :, l] & ~skb[:, l : l + 1]) == 0
            ok = conflict_l if ok is None else (ok & conflict_l)
        ok = ok & (rtb >= 0)[:, None]
        per_row = jnp.any(ok, axis=-1).astype(jnp.int32)
        tc = jnp.zeros((n_tables,), jnp.int32).at[jnp.maximum(rtb, 0)].add(per_row)
        return tc, jnp.sum(ok, axis=0, dtype=jnp.int32)

    tcs, kcs = jax.lax.map(block, (sk, rt))
    return jnp.sum(tcs, axis=0), jnp.sum(kcs, axis=0)


def filter_counts_local_fused(
    superkeys: jnp.ndarray,
    row_tables: jnp.ndarray,
    query_sks: jnp.ndarray,
    n_tables: int,
):
    """Fused-kernel probe: the per-shard filter runs as ONE
    ``filter_kernel.filter_table_counts`` launch (mode='any'), so the
    [rows, keys] match tensor never exists per shard either — subsumption,
    the per-row any-reduction and the table-id scatter all happen in VMEM and
    only the two counts vectors leave the kernel.  Padding rows carry
    ``row_tables == -1`` (the kernel's own padding convention) and padded
    queries all-ones super keys (subsumed by nothing).  Above the kernel's
    table cap (the one-hot scatter tile is [block_n, tb] f32 in VMEM) the
    shard falls back to the lane-unrolled streaming impl."""
    from repro.kernels import filter_kernel

    interpret = jax.default_backend() != "tpu"
    n, lanes = superkeys.shape
    q = query_sks.shape[0]
    qb = max(-(-q // 128) * 128, 128)
    tb = max(-(-n_tables // 128) * 128, 128)
    if tb > filter_kernel.FUSED_MAX_TABLES:
        return filter_counts_local_blocked(
            superkeys, row_tables, query_sks, n_tables
        )
    block_n = filter_kernel.fused_block_n(tb)
    nb = max(-(-n // block_n) * block_n, block_n)
    sk = jnp.pad(superkeys, ((0, nb - n), (0, 0)))
    rt = jnp.pad(
        row_tables.astype(jnp.int32), (0, nb - n), constant_values=-1
    )
    qs = jnp.pad(
        query_sks, ((0, qb - q), (0, 0)),
        constant_values=np.uint32(0xFFFFFFFF),
    )
    counts, key_counts = filter_kernel.filter_table_counts(
        sk.T, qs.T, None, rt,
        n_tables=tb, n_queries=q, block_n=block_n, block_q=qb, mode="any",
        interpret=interpret,
    )
    return counts[:n_tables], key_counts[:q]


_FILTER_IMPLS = {
    "broadcast": filter_counts_local,
    "blocked": filter_counts_local_blocked,
    "fused": filter_counts_local_fused,
}

def shard_impl_for(backend: Backend | str | None) -> str:
    """Map a resolved filter ``Backend`` onto a per-shard impl name.

    A shard-impl name ('broadcast' | 'blocked' | 'fused') passes through
    directly; a registry backend maps 'fused' -> the fused per-shard launch
    and every composed/host backend -> the broadcast baseline (the composed
    backends differ only in how the ENGINES consume the match matrix, which
    never exists per shard here).  None follows the registry precedence, so
    ``MATE_FILTER_BACKEND=fused`` and the TPU platform default select the
    fused shard launch without any caller plumbing.
    """
    if isinstance(backend, str) and backend in _FILTER_IMPLS:
        return backend
    bk = registry.resolve_backend(backend)
    return "fused" if bk.fused else "broadcast"


def make_distributed_filter(
    mesh: Mesh,
    n_tables: int,
    row_axes: tuple[str, ...],
    backend: Backend | str | None = None,
):
    """jit'd (superkeys, row_tables, query_sks) -> (table_counts, key_counts)
    with rows sharded over ``row_axes`` and outputs replicated (psum).

    ``backend`` is a resolved registry ``Backend``, a registered backend
    name, or a shard-impl name: 'broadcast' (baseline) | 'blocked'
    (lane-unrolled streaming) | 'fused' (single Pallas filter+segment-count
    launch per shard).  None resolves via the registry (env var, then
    platform default).  The pre-registry ``impl=`` kwarg was removed after
    its one-release deprecation window (PR 4): passing it raises TypeError.
    """
    impl = shard_impl_for(backend)
    local = _FILTER_IMPLS[impl]
    extra = _no_rep_check_kwargs() if impl == "fused" else {}

    @functools.partial(
        _shard_map,
        mesh=mesh,
        in_specs=(P(row_axes), P(row_axes), P()),
        out_specs=(P(), P()),
        **extra,
    )
    def _sharded(superkeys, row_tables, query_sks):
        tc, kc = local(superkeys, row_tables, query_sks, n_tables)
        tc = jax.lax.psum(tc, row_axes)
        kc = jax.lax.psum(kc, row_axes)
        return tc, kc

    return jax.jit(_sharded)


# ---------------------------------------------------------------------------
# Shard helpers shared by the online filter and the offline index build
# ---------------------------------------------------------------------------


def mesh_shard_count(mesh: Mesh, axes: tuple[str, ...]) -> int:
    """Number of shards a block-partition over ``axes`` produces."""
    return int(np.prod([mesh.shape[a] for a in axes]))


def shard_bounds(n: int, n_shards: int) -> np.ndarray:
    """int64[n_shards+1] contiguous balanced shard boundaries over ``n``
    items: shard ``i`` covers ``[bounds[i], bounds[i+1])``.

    Prefix shards take ``ceil(n / n_shards)`` items, trailing shards may be
    short or empty — the SAME contiguous-ascending layout a padded equal-size
    device partition induces, which is what makes the offline build's
    shard-merge order-preserving (shard outputs concatenate back into global
    row/value order).
    """
    size = -(-n // n_shards) if n else 0
    return np.minimum(
        np.arange(n_shards + 1, dtype=np.int64) * size, np.int64(n)
    )


def pad_rows_to_shards(x: np.ndarray, n_shards: int, value=0) -> np.ndarray:
    """Pad the leading dim up to an equal-shard multiple (≥ 1 row/shard)."""
    n = x.shape[0]
    target = max(-(-n // n_shards) * n_shards, n_shards)
    if target == n:
        return x
    pads = [(0, 0)] * x.ndim
    pads[0] = (0, target - n)
    return np.pad(x, pads, constant_values=value)


def shard_corpus_rows(
    superkeys: np.ndarray,
    row_tables: np.ndarray,
    mesh: Mesh,
    row_axes: tuple[str, ...],
):
    """Pad to shard multiple and device_put with the row sharding.

    Re-invoking with a different mesh is the elastic-scaling path: arrays are
    repartitioned from the host copy (or via d2d reshard when alive).
    """
    n_shards = mesh_shard_count(mesh, row_axes)
    sk = pad_rows_to_shards(np.asarray(superkeys, dtype=np.uint32), n_shards)
    rt = pad_rows_to_shards(
        np.asarray(row_tables, dtype=np.int32), n_shards, value=-1
    )
    sharding = NamedSharding(mesh, P(row_axes))
    return (
        jax.device_put(sk, sharding),
        jax.device_put(rt, sharding),
    )
