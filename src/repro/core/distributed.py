"""Distributed MATE discovery: corpus sharded over the device mesh.

Both halves of the system shard the same way.  The ONLINE filtering layer
(the paper's hot loop) is embarrassingly parallel over candidate rows; the
OFFLINE build (``core.index.build_index``) is embarrassingly parallel over
unique values (hashing) and corpus rows (super keys, posting lists).  The
shard helpers at the bottom of this module (``shard_bounds``,
``mesh_shard_count``, ``pad_rows_to_shards``, ``shard_map_compat``) are the
shared vocabulary: contiguous balanced row/value blocks, padded to the mesh
where device work needs equal shards.

For the online filter the natural large-scale layout is:

  * per-row super keys  uint32[n_rows, lanes]   → sharded over ALL mesh axes
    (rows are block-partitioned; a row's table never matters to the filter)
  * row→table ids       int32[n_rows]           → sharded identically
  * query super keys    uint32[n_keys, lanes]   → replicated
  * per-table candidate counts int32[n_tables]  → psum over row shards

A 512-chip pod-pair therefore filters ~512× the rows per step; the host-side
top-k logic (tiny) consumes the psum'ed per-table counts.  This module is the
dry-run/roofline target for the paper's own technique ("mate-filter" row in
EXPERIMENTS.md §Roofline).

Elastic scaling: the arrays are resharded by ``jax.device_put`` with a new
mesh — no host state depends on the mesh shape.  Straggler mitigation: row
blocks are balanced by construction (equal shard sizes after padding).
"""

from __future__ import annotations

import functools
import inspect
import logging

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.kernels import registry
from repro.kernels.registry import Backend

_LOG = logging.getLogger(__name__)

# jax.shard_map landed after 0.4.x; fall back to the experimental home
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map

# the version-compat shard_map entry shared with the offline build
# (kernels.ops.xash_values_mesh) — same callable the filter wraps below
shard_map_compat = _shard_map


def _no_rep_check_kwargs() -> dict:
    """shard_map kwargs disabling the replication-rule check (pallas_call has
    no replication rule); the flag was renamed check_rep → check_vma."""
    params = inspect.signature(_shard_map).parameters
    for name in ("check_rep", "check_vma"):
        if name in params:
            return {name: False}
    return {}


def filter_counts_local(
    superkeys: jnp.ndarray,  # uint32[rows_local, lanes]
    row_tables: jnp.ndarray,  # int32[rows_local] (-1 for padding rows)
    query_sks: jnp.ndarray,  # uint32[n_keys, lanes]
    n_tables: int,
):
    """Per-table and per-key candidate counts for a local row shard."""
    conflict = query_sks[None, :, :] & ~superkeys[:, None, :]
    match = jnp.all(conflict == 0, axis=-1)  # [rows, keys]
    valid = (row_tables >= 0)[:, None]
    match = match & valid
    per_row = jnp.any(match, axis=-1).astype(jnp.int32)  # row matches ≥1 key
    table_counts = jnp.zeros((n_tables,), jnp.int32).at[
        jnp.maximum(row_tables, 0)
    ].add(per_row)
    key_counts = jnp.sum(match, axis=0, dtype=jnp.int32)  # [keys]
    return table_counts, key_counts


def filter_counts_local_blocked(
    superkeys: jnp.ndarray,
    row_tables: jnp.ndarray,
    query_sks: jnp.ndarray,
    n_tables: int,
    row_block: int = 1 << 16,
):
    """Memory-optimised probe: lane-unrolled (never materialises the
    [rows, keys, lanes] conflict tensor — peak is [block, keys] bool) and
    row-blocked via ``lax.map`` so HBM traffic is one streaming pass over the
    super keys (§Perf hillclimb 'mate-filter')."""
    lanes = superkeys.shape[1]
    n = superkeys.shape[0]
    nb = -(-n // row_block)
    pad = nb * row_block - n
    sk = jnp.pad(superkeys, ((0, pad), (0, 0)))
    rt = jnp.pad(row_tables, (0, pad), constant_values=-1)
    sk = sk.reshape(nb, row_block, lanes)
    rt = rt.reshape(nb, row_block)

    def block(args):
        skb, rtb = args
        ok = None
        for l in range(lanes):
            conflict_l = (query_sks[None, :, l] & ~skb[:, l : l + 1]) == 0
            ok = conflict_l if ok is None else (ok & conflict_l)
        ok = ok & (rtb >= 0)[:, None]
        per_row = jnp.any(ok, axis=-1).astype(jnp.int32)
        tc = jnp.zeros((n_tables,), jnp.int32).at[jnp.maximum(rtb, 0)].add(per_row)
        return tc, jnp.sum(ok, axis=0, dtype=jnp.int32)

    tcs, kcs = jax.lax.map(block, (sk, rt))
    return jnp.sum(tcs, axis=0), jnp.sum(kcs, axis=0)


def filter_counts_local_fused(
    superkeys: jnp.ndarray,
    row_tables: jnp.ndarray,
    query_sks: jnp.ndarray,
    n_tables: int,
):
    """Fused-kernel probe: the per-shard filter runs as ONE
    ``filter_kernel.filter_table_counts`` launch (mode='any'), so the
    [rows, keys] match tensor never exists per shard either — subsumption,
    the per-row any-reduction and the table-id scatter all happen in VMEM and
    only the two counts vectors leave the kernel.  Padding rows carry
    ``row_tables == -1`` (the kernel's own padding convention) and padded
    queries all-ones super keys (subsumed by nothing).  Above the kernel's
    table cap (the one-hot scatter tile is [block_n, tb] f32 in VMEM) the
    shard falls back to the lane-unrolled streaming impl."""
    from repro.kernels import filter_kernel

    interpret = jax.default_backend() != "tpu"
    n, lanes = superkeys.shape
    q = query_sks.shape[0]
    qb = max(-(-q // 128) * 128, 128)
    tb = max(-(-n_tables // 128) * 128, 128)
    if tb > filter_kernel.FUSED_MAX_TABLES:
        return filter_counts_local_blocked(
            superkeys, row_tables, query_sks, n_tables
        )
    block_n = filter_kernel.fused_block_n(tb)
    nb = max(-(-n // block_n) * block_n, block_n)
    sk = jnp.pad(superkeys, ((0, nb - n), (0, 0)))
    rt = jnp.pad(
        row_tables.astype(jnp.int32), (0, nb - n), constant_values=-1
    )
    qs = jnp.pad(
        query_sks, ((0, qb - q), (0, 0)),
        constant_values=np.uint32(0xFFFFFFFF),
    )
    counts, key_counts = filter_kernel.filter_table_counts(
        sk.T, qs.T, None, rt,
        n_tables=tb, n_queries=q, block_n=block_n, block_q=qb, mode="any",
        interpret=interpret,
    )
    return counts[:n_tables], key_counts[:q]


_FILTER_IMPLS = {
    "broadcast": filter_counts_local,
    "blocked": filter_counts_local_blocked,
    "fused": filter_counts_local_fused,
}

def shard_impl_for(backend: Backend | str | None, stats=None) -> str:
    """Map a resolved filter ``Backend`` onto a per-shard impl name.

    A shard-impl name ('broadcast' | 'blocked' | 'fused') passes through
    directly; a registry backend maps 'fused' -> the fused per-shard launch
    and every composed/host backend -> the broadcast baseline (the composed
    backends differ only in how the ENGINES consume the match matrix, which
    never exists per shard here).  None follows the registry precedence, so
    ``MATE_FILTER_BACKEND=fused`` and the TPU platform default select the
    fused shard launch without any caller plumbing.

    A 'fused-gather' backend DEMOTES to the fused shard impl here — and says
    so: this mesh row-filter API receives pre-gathered, pre-sharded superkey
    blocks, so there is no posting-list gather left to fuse.  The demotion is
    debug-logged and counted on ``stats`` (a ``DiscoveryStats``) when one is
    passed; the path that runs gather-fused WITHOUT demotion is the routed
    index (``core.routing.ShardedMateIndex``), whose per-shard epoch-pinned
    device stores give the gather kernel something shard-local to gather
    from.
    """
    if isinstance(backend, str) and backend in _FILTER_IMPLS:
        return backend
    bk = registry.resolve_backend(backend)
    if bk.gather:
        _LOG.debug(
            "shard_impl_for: demoting %r to the 'fused' shard impl — the"
            " mesh row filter takes pre-gathered superkey shards (use a"
            " routed ShardedMateIndex for shard-local gather-fused launches)",
            bk.name,
        )
        if stats is not None:
            stats.shard_gather_demotions += 1
        return "fused"
    return "fused" if bk.fused else "broadcast"


def make_distributed_filter(
    mesh: Mesh,
    n_tables: int,
    row_axes: tuple[str, ...],
    backend: Backend | str | None = None,
):
    """jit'd (superkeys, row_tables, query_sks) -> (table_counts, key_counts)
    with rows sharded over ``row_axes`` and outputs replicated (psum).

    ``backend`` is a resolved registry ``Backend``, a registered backend
    name, or a shard-impl name: 'broadcast' (baseline) | 'blocked'
    (lane-unrolled streaming) | 'fused' (single Pallas filter+segment-count
    launch per shard).  None resolves via the registry (env var, then
    platform default).  The pre-registry ``impl=`` kwarg was removed after
    its one-release deprecation window (PR 4): passing it raises TypeError.
    """
    impl = shard_impl_for(backend)
    local = _FILTER_IMPLS[impl]
    extra = _no_rep_check_kwargs() if impl == "fused" else {}

    @functools.partial(
        _shard_map,
        mesh=mesh,
        in_specs=(P(row_axes), P(row_axes), P()),
        out_specs=(P(), P()),
        **extra,
    )
    def _sharded(superkeys, row_tables, query_sks):
        tc, kc = local(superkeys, row_tables, query_sks, n_tables)
        tc = jax.lax.psum(tc, row_axes)
        kc = jax.lax.psum(kc, row_axes)
        return tc, kc

    return jax.jit(_sharded)


# ---------------------------------------------------------------------------
# Routed-index mesh filter (core.routing.ShardedMateIndex, mesh mode)
# ---------------------------------------------------------------------------


def _routed_local_counts_fn(
    row_axes, n_shards, pad_store, pad_items, qb, q, fl, n_tables, impl: str,
):
    """Build the jitted shard_map'd routed filter for one shape bucket.

    Inputs (leading dim sharded over ``row_axes``, one block per shard):
      store  uint32[n_shards·pad_store, lanes] — per-shard superkey stores
      rows   int32[n_shards·pad_items]         — SHARD-LOCAL row offsets
      seg    int32[n_shards·pad_items]         — batch table ids (-1 pads)
      elig   int8[n_shards·pad_items, qb]      — eligibility (0 pads)
      qry    uint32[qb, fl] (replicated)       — query superkeys
    Output: int32[n_tables], psum'ed — per-table counts, replicated.

    Each shard gathers ONLY from its own store block and the single
    cross-shard exchange is the counts psum: superkey rows never leave
    their shard.  ``impl`` 'fused' runs the Pallas fused counts kernel per
    shard (mode='sum'); 'xla' is the lane-unrolled fallback — bit-identical
    counts either way.
    """
    from repro.kernels import filter_kernel

    def _local(store, rows, seg, elig, qry):
        sk = store[rows][:, :fl]
        if impl == "fused":
            interpret = jax.default_backend() != "tpu"
            tb = max(-(-n_tables // 128) * 128, 128)
            block_n = min(pad_items, filter_kernel.fused_block_n(tb))
            block_q = min(qb, filter_kernel.DEFAULT_BLOCK_Q)
            counts, _ = filter_kernel.filter_table_counts(
                sk.T, qry.T, elig, seg,
                n_tables=tb, n_queries=q, block_n=block_n, block_q=block_q,
                mode="sum", interpret=interpret,
            )
            counts = counts[:n_tables]
        else:
            ok = None
            for lane in range(fl):
                c = (qry[None, :, lane] & ~sk[:, lane : lane + 1]) == 0
                ok = c if ok is None else ok & c
            ok = ok & (elig > 0)
            per_row = jnp.sum(ok, axis=1).astype(jnp.int32)
            counts = (
                jnp.zeros((n_tables,), jnp.int32)
                .at[jnp.maximum(seg, 0)]
                .add(jnp.where(seg >= 0, per_row, 0))
            )
        return jax.lax.psum(counts, row_axes)

    def wrap(mesh):
        extra = _no_rep_check_kwargs() if impl == "fused" else {}
        return jax.jit(
            _shard_map(
                _local,
                mesh=mesh,
                in_specs=(
                    P(row_axes), P(row_axes), P(row_axes), P(row_axes), P()
                ),
                out_specs=P(),
                **extra,
            )
        )

    return wrap


def _routed_mesh_store(index):
    """The stacked equal-padded per-shard store blocks, device_put with the
    shard partitioning — cached on the tuple of PER-SHARD epochs, so a §5.4
    mutation on shard i re-uploads the stack once, lazily."""
    epochs = tuple(s.mutation_epoch for s in index.shards)
    cached = index._mesh_store_cache
    if cached is not None and cached[0] == epochs:
        return cached[1], cached[2]
    pad_store = max(max(s.n_rows for s in index.shards), 1)
    lanes = index.cfg.lanes
    stack = np.zeros((index.n_shards * pad_store, lanes), dtype=np.uint32)
    for i, s in enumerate(index.shards):
        stack[i * pad_store : i * pad_store + s.n_rows] = s.superkeys
    sharding = NamedSharding(index._mesh, P(index._row_axes))
    store = jax.device_put(stack, sharding)
    index._mesh_store_cache = (epochs, store, pad_store)
    return store, pad_store


def routed_filter_counts_mesh(
    index,
    rows: np.ndarray,
    query_sk: np.ndarray,
    elig: np.ndarray,
    seg_ids: np.ndarray,
    n_tables: int,
    backend: Backend | str | None = None,
) -> tuple[np.ndarray, bool]:
    """One shard_map launch of the routed filter over ``index``'s mesh.

    Partitions the batch's candidate items by owning shard, pads each
    shard's slice to a shared pow2 bucket, and runs the per-shard filter +
    counts psum as a single SPMD program.  Returns ``(counts, demoted)``:
    ``counts`` int32[n_tables] bit-identical to the host-routed (and
    single-host) counts; ``demoted`` True when a fused/gather backend fell
    back to the lane-unrolled XLA shard body (Pallas unavailable under this
    mesh — logged, counted by the caller on ``DiscoveryStats``).
    """
    from repro.kernels import ops

    bk = registry.resolve_backend(backend)
    mesh, row_axes = index._mesh, index._row_axes
    n_shards = index.n_shards
    rows = np.asarray(rows, dtype=np.int64)
    n, q = rows.shape[0], query_sk.shape[0]
    fl = query_sk.shape[1]
    sid = index._shard_ids_of_rows(rows)
    store, pad_store = _routed_mesh_store(index)

    per_shard = [np.nonzero(sid == s)[0] for s in range(n_shards)]
    max_items = max((len(ix) for ix in per_shard), default=0)
    pad_items = ops._bucket(max(max_items, 1), ops._FALLBACK_MIN_N)
    qb = ops._pow2_bucket(q, ops._FALLBACK_MIN_Q)

    rows_p = np.zeros(n_shards * pad_items, dtype=np.int32)
    seg_p = np.full(n_shards * pad_items, -1, dtype=np.int32)
    elig_p = np.zeros((n_shards * pad_items, qb), dtype=np.int8)
    for s, ix in enumerate(per_shard):
        if not len(ix):
            continue
        base = s * pad_items
        rows_p[base : base + len(ix)] = rows[ix] - index.shards[s].row_lo
        seg_p[base : base + len(ix)] = np.asarray(seg_ids)[ix]
        elig_p[base : base + len(ix), :q] = elig[ix]
    qry_p = np.full((qb, fl), 0xFFFFFFFF, dtype=np.uint32)
    qry_p[:q] = query_sk

    from repro.kernels import filter_kernel

    fused_capable = bk.fused or bk.gather
    want_fused = fused_capable and (
        max(-(-n_tables // 128) * 128, 128) <= filter_kernel.FUSED_MAX_TABLES
    )
    demoted = bool(index._mesh_filter_cache.get("__demoted__", False))
    impls = ["xla"] if (demoted or not want_fused) else ["fused", "xla"]
    sharding = NamedSharding(mesh, P(row_axes))
    args = (
        store,
        jax.device_put(rows_p, sharding),
        jax.device_put(seg_p, sharding),
        jax.device_put(elig_p, sharding),
        jnp.asarray(qry_p),
    )
    for impl in impls:
        key = (pad_store, pad_items, qb, q, fl, n_tables, impl)
        fn = index._mesh_filter_cache.get(key)
        if fn is None:
            fn = _routed_local_counts_fn(
                row_axes, n_shards, pad_store, pad_items, qb, q, fl,
                n_tables, impl,
            )(mesh)
            index._mesh_filter_cache[key] = fn
        try:
            counts = np.asarray(fn(*args))
            return counts, fused_capable and impl != "fused"
        except Exception:  # pragma: no cover - backend-dependent compile path
            if impl == "xla":
                raise
            _LOG.debug(
                "routed mesh filter: fused shard body failed to compile on"
                " %s — demoting to the XLA shard body",
                jax.default_backend(), exc_info=True,
            )
            index._mesh_filter_cache["__demoted__"] = True
            demoted = True
    raise AssertionError("unreachable")  # pragma: no cover


# ---------------------------------------------------------------------------
# Shard helpers shared by the online filter and the offline index build
# ---------------------------------------------------------------------------


def mesh_shard_count(mesh: Mesh, axes: tuple[str, ...]) -> int:
    """Number of shards a block-partition over ``axes`` produces."""
    return int(np.prod([mesh.shape[a] for a in axes]))


def shard_bounds(n: int, n_shards: int) -> np.ndarray:
    """int64[n_shards+1] contiguous balanced shard boundaries over ``n``
    items: shard ``i`` covers ``[bounds[i], bounds[i+1])``.

    Prefix shards take ``ceil(n / n_shards)`` items, trailing shards may be
    short or empty — the SAME contiguous-ascending layout a padded equal-size
    device partition induces, which is what makes the offline build's
    shard-merge order-preserving (shard outputs concatenate back into global
    row/value order).
    """
    size = -(-n // n_shards) if n else 0
    return np.minimum(
        np.arange(n_shards + 1, dtype=np.int64) * size, np.int64(n)
    )


def pad_rows_to_shards(x: np.ndarray, n_shards: int, value=0) -> np.ndarray:
    """Pad the leading dim up to an equal-shard multiple (≥ 1 row/shard)."""
    n = x.shape[0]
    target = max(-(-n // n_shards) * n_shards, n_shards)
    if target == n:
        return x
    pads = [(0, 0)] * x.ndim
    pads[0] = (0, target - n)
    return np.pad(x, pads, constant_values=value)


def shard_corpus_rows(
    superkeys: np.ndarray,
    row_tables: np.ndarray,
    mesh: Mesh,
    row_axes: tuple[str, ...],
):
    """Pad to shard multiple and device_put with the row sharding.

    Re-invoking with a different mesh is the elastic-scaling path: arrays are
    repartitioned from the host copy (or via d2d reshard when alive).
    """
    n_shards = mesh_shard_count(mesh, row_axes)
    sk = pad_rows_to_shards(np.asarray(superkeys, dtype=np.uint32), n_shards)
    rt = pad_rows_to_shards(
        np.asarray(row_tables, dtype=np.int32), n_shards, value=-1
    )
    sharding = NamedSharding(mesh, P(row_axes))
    return (
        jax.device_put(sk, sharding),
        jax.device_put(rt, sharding),
    )
