"""Unified MATE discovery surface: one frozen config, one session object.

MATE's pipeline (paper §4–6: super-key index → XASH filter → verification)
is one system, but three PRs of growth left four entry points
(``discover``, ``discover_batched``, ``discover_many``, ``DiscoveryEngine``)
each re-threading ``bits``/``k``/``batch_tables`` positionally and selecting
the filter backend through disjoint idioms.  This module collapses that to:

  * ``DiscoveryConfig`` — a FROZEN dataclass holding every knob of the
    online phase (hash width, default top-k, filter backend, init-column
    heuristic, batching, readback policy, serving window/deadline).  Being
    immutable and hashable it is exactly the thing a request loop holds and
    the thing launch caches key on.
  * ``MateSession`` — the facade owning the ``MateIndex``, the backend
    resolved ONCE through ``kernels.registry`` (explicit config > env var >
    platform default), and per-session aggregate stats.  ``build`` runs the
    offline phase; ``discover`` / ``discover_many`` run the online phase
    through the batched kernel engines with results bit-identical to the
    pre-session entry points (and to scalar Algorithm 1).

``serve.engine.DiscoveryEngine`` is rebuilt on top of a ``MateSession`` as
the async-capable serving loop (arrival-window batching, deadlines,
futures); this module stays synchronous and loop-free on purpose — a
session is safe to embed anywhere, including inside that loop.
"""

from __future__ import annotations

import dataclasses

from repro.core import batched as batched_lib
from repro.core import index as index_lib
from repro.core import xash
from repro.core.corpus import Corpus, Table
from repro.core.discovery import DiscoveryStats, TopKEntry
from repro.core.index import BuildStats, MateIndex
from repro.kernels import registry
from repro.kernels.registry import Backend

# super-key widths the kernels are exercised at (4/8/16 uint32 lanes)
VALID_BITS = (128, 256, 512)


@dataclasses.dataclass(frozen=True)
class DiscoveryConfig:
    """Every knob of a MATE deployment, in one immutable object.

    Offline phase:
      bits / hash_name / use_corpus_char_freq — index build parameters
        (``bits`` is the super-key width: 128/256/512 → 4/8/16 uint32 lanes).

    Online phase:
      k            — default top-k per request (per-request override allowed).
      backend      — filter backend name ('fused-gather' | 'fused' |
                     'pallas' | 'xla' | 'numpy' | 'auto') or None for
                     registry resolution (``MATE_FILTER_BACKEND``, then
                     platform default).  'fused-gather' DMA-gathers the
                     candidate rows from the device superkey store inside
                     the fused launch, demoting to 'fused' when the store
                     doesn't fit the device budget.
      init_mode    — §6.1 initial-column heuristic.
      batch_tables — tables per filter launch in ``discover``.
      fused_block_n — optional row-block override for the fused kernel
                     (power of two ≥ 128; clamped to the VMEM budget).
      prefetch_frac — readback policy: below this fraction of batch items
                     surviving the entry bound, per-table hit-slice
                     readbacks beat one whole-batch transfer.
      rank         — result ordering: 'quality' (default) runs the
                     ``core.ranking`` scoring head over the filter counts
                     and orders by join quality; 'count' is the historical
                     exact-joinability order.  The verified top-k SET is
                     identical either way — rank only reorders/annotates.
      profile_gate — run the column-profile pre-filter (``core.profiles``)
                     in front of candidate gathering: tables whose profiles
                     PROVE joinability 0 are dropped before any filter
                     launch.  Pure pruning — results are set-identical with
                     the gate off.  (The raw ``core.batched`` functions
                     default BOTH knobs off for bit-stable legacy callers;
                     the session/serving surface defaults them on.)
      signals      — multi-signal ensemble for the FD workload
                     (``MateSession.discover_fds``): a tuple of
                     (name, weight) pairs over ``core.fd.SIGNAL_NAMES``
                     ('joinability' | 'uniqueness' | 'sketch' | 'name'),
                     kept as a tuple-of-tuples so the frozen config stays
                     hashable.  None (default) orders FD candidates by raw
                     support; the reported support/holds/violations facts
                     are identical either way — signals only score/reorder.

    Serving (consumed by ``serve.engine.DiscoveryEngine``):
      window       — max requests per shared filter launch (group size).
      flush_after  — seconds a queued request may wait for its group to
                     fill before the engine serves a partial group
                     (None: only full groups flush; ``flush()`` always
                     drains regardless).
      deadline_margin — seconds before a group's ``flush_after`` deadline
                     the engine launches it PARTIAL, so the group is served
                     by its deadline instead of merely started at it
                     (None: auto — an EWMA of observed group service times).
      max_queue    — bounded submit queue: beyond this many waiting
                     requests admission control kicks in (None: unbounded).
      pressure_policy — what admission control does at ``max_queue``:
                     'shed' rejects the request's future with
                     ``serve.engine.AdmissionError``; 'degrade' admits it
                     flagged for ``degrade_bits`` filtering (sheds anyway
                     at 2×``max_queue`` — degraded filtering relieves
                     filter bandwidth, not an unbounded backlog).
      degrade_bits — filter width for degraded requests (a lane-prefix
                     relaxation of the index width: results stay
                     bit-identical, filter precision drops).
      result_cache — capacity (entries) of the serving tier's query-result
                     cache; 0 disables.  Hits are bit-identical replays of
                     the cached top-k, invalidated on §5.4 mutations.
      bound_cache  — capacity (entries) of the hot-table bound cache
                     (cached ``PlanCounts``: hits skip gather_candidates +
                     the filter launch); 0 disables.
    """

    bits: int = 128
    k: int = 10
    backend: str | None = None
    init_mode: str = "cardinality"
    batch_tables: int = batched_lib.DEFAULT_BATCH_TABLES
    fused_block_n: int | None = None
    prefetch_frac: float = batched_lib._PREFETCH_FRAC
    rank: str = "quality"
    profile_gate: bool = True
    signals: tuple[tuple[str, float], ...] | None = None
    hash_name: str = "xash"
    use_corpus_char_freq: bool = True
    window: int = 8
    flush_after: float | None = None
    deadline_margin: float | None = 0.0
    max_queue: int | None = None
    pressure_policy: str = "shed"
    degrade_bits: int = 128
    result_cache: int = 0
    bound_cache: int = 0

    def __post_init__(self):
        if self.bits not in VALID_BITS:
            raise ValueError(f"bits must be one of {VALID_BITS}, got {self.bits}")
        if self.backend is not None:
            registry.resolve_backend(self.backend)  # raises on unknown names
        if self.fused_block_n is not None and (
            self.fused_block_n < 128
            or self.fused_block_n & (self.fused_block_n - 1)
        ):
            raise ValueError(
                f"fused_block_n must be a power of two >= 128, got {self.fused_block_n}"
            )
        if self.rank not in ("quality", "count"):
            raise ValueError(
                f"rank must be 'quality' or 'count', got {self.rank!r}"
            )
        if self.signals is not None:
            from repro.core import fd as fd_lib

            if not isinstance(self.signals, tuple):
                raise ValueError(
                    "signals must be a tuple of (name, weight) pairs or None "
                    f"(got {type(self.signals).__name__} — dicts/lists are "
                    "unhashable, which would break the frozen config)"
                )
            for pair in self.signals:
                if not (isinstance(pair, tuple) and len(pair) == 2):
                    raise ValueError(
                        f"each signal must be a (name, weight) pair, got {pair!r}"
                    )
                name, weight = pair
                if name not in fd_lib.SIGNAL_NAMES:
                    raise ValueError(
                        f"unknown signal {name!r}; valid: {fd_lib.SIGNAL_NAMES}"
                    )
                if not weight > 0:
                    raise ValueError(
                        f"signal weight must be > 0, got {name}={weight!r}"
                    )
        if not 0.0 <= self.prefetch_frac <= 1.0:
            raise ValueError(f"prefetch_frac must be in [0, 1], got {self.prefetch_frac}")
        if self.batch_tables < 1:
            raise ValueError(f"batch_tables must be >= 1, got {self.batch_tables}")
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if self.flush_after is not None and self.flush_after < 0:
            raise ValueError(f"flush_after must be >= 0, got {self.flush_after}")
        if self.deadline_margin is not None and self.deadline_margin < 0:
            raise ValueError(
                f"deadline_margin must be >= 0 or None (auto), got {self.deadline_margin}"
            )
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(f"max_queue must be >= 1 or None, got {self.max_queue}")
        if self.pressure_policy not in ("shed", "degrade"):
            raise ValueError(
                f"pressure_policy must be 'shed' or 'degrade', got {self.pressure_policy!r}"
            )
        if self.degrade_bits not in VALID_BITS:
            raise ValueError(
                f"degrade_bits must be one of {VALID_BITS}, got {self.degrade_bits}"
            )
        if self.result_cache < 0:
            raise ValueError(f"result_cache must be >= 0, got {self.result_cache}")
        if self.bound_cache < 0:
            raise ValueError(f"bound_cache must be >= 0, got {self.bound_cache}")

    def resolve_backend(self) -> Backend:
        """The backend this config selects, under the registry precedence."""
        return registry.resolve_backend(self.backend)


# DiscoveryStats counters ``SessionStats.absorb`` does NOT aggregate:
# per-request plan shape (meaningless summed across requests) and the
# per-launch lane width.  Every OTHER DiscoveryStats field is absorbed by
# name — so adding a counter to DiscoveryStats without either mirroring it
# on SessionStats or listing it here raises AttributeError on the first
# absorb, instead of silently dropping it from session accounting (the
# hand-patched-aggregation failure mode of PRs 7–8).
_NOT_AGGREGATED = frozenset({
    "tables_fetched",
    "tables_evaluated",
    "tables_pruned_rule1",
    "tables_pruned_rule2",
    "pl_items_total",
    "pl_items_checked",
    "filter_lanes",
})
_ABSORBED = tuple(
    f.name
    for f in dataclasses.fields(DiscoveryStats)
    if f.name not in _NOT_AGGREGATED
)


@dataclasses.dataclass
class SessionStats:
    """Aggregate accounting across every request a session served."""

    requests: int = 0
    filter_checks: int = 0
    filter_passed: int = 0
    verified_tp: int = 0
    verified_fp: int = 0
    filter_matrix_bytes: int = 0
    filter_readback_bytes: int = 0
    filter_fused_launches: int = 0
    gather_bytes_saved: int = 0
    # routed-index counters (``core.routing.ShardedMateIndex`` sessions):
    shard_launches: int = 0  # shard-local filter launches routed to the data
    route_bytes_merged: int = 0  # cross-shard count-merge bytes (the ONLY
    # bytes that cross a shard boundary on the routed filter path)
    shard_gather_demotions: int = 0  # shard launches demoted off gather-fused
    # ranking-subsystem counters (``core.profiles`` / ``core.ranking``):
    tables_gated: int = 0  # candidate tables the profile gate dropped
    gate_bytes_saved: int = 0  # superkey bytes the gate kept out of filters
    ranking_launches: int = 0  # quality-scoring launches
    # FD-workload counters (``core.fd.discover_fds``):
    fd_candidates: int = 0  # candidate tables entering FD workloads
    fd_validated: int = 0  # tables surviving the count prune into validation
    fd_bytes_verified: int = 0  # superkey bytes validation re-gathered
    # serving-tier counters (bumped by ``serve.engine.DiscoveryEngine``):
    cache_hits: int = 0  # requests answered from the query-result cache
    bound_hits: int = 0  # requests scored from cached PlanCounts (skipped
    # gather_candidates + the filter launch)
    shed: int = 0  # requests rejected by admission control (queue full)
    degraded: int = 0  # requests admitted at degrade_bits filter width

    def absorb(self, stats: DiscoveryStats) -> None:
        self.requests += 1
        for name in _ABSORBED:
            setattr(self, name, getattr(self, name) + getattr(stats, name))

    @property
    def precision(self) -> float:
        denom = self.verified_tp + self.verified_fp
        return self.verified_tp / denom if denom else 1.0


class MateSession:
    """One indexed lake + one resolved backend + one config = one session.

    ``build`` runs the offline phase from a corpus; the constructor wraps an
    already-built ``MateIndex`` (the config's ``bits``/``hash_name`` are
    adopted from the index, which is the ground truth for what was built).
    The backend is resolved exactly once, at construction — a session never
    re-reads the environment, so a long-lived serving process cannot change
    dispatch mid-flight.
    """

    def __init__(self, index: MateIndex, config: DiscoveryConfig | None = None):
        config = config or DiscoveryConfig()
        # the index is ground truth for offline-phase knobs; keep the frozen
        # config consistent with it so session.config never lies.
        config = dataclasses.replace(
            config, bits=index.bits, hash_name=index.hash_name
        )
        self.index = index
        self.config = config
        self.backend = config.resolve_backend()
        self.stats = SessionStats()
        # set by ``build``; None when wrapping an externally built index
        self.build_stats: BuildStats | None = None

    @classmethod
    def build(
        cls,
        corpus: Corpus,
        config: DiscoveryConfig | None = None,
        *,
        mesh=None,
        row_axes: tuple[str, ...] | None = None,
        n_shards: int | None = None,
        distributed: bool = False,
    ) -> "MateSession":
        """Offline phase (§4/§5): hash + index ``corpus`` per ``config``.

        ``mesh`` shards the build the way the online filter already shards
        (``core.index.build_index``): unique-value hashing under
        ``shard_map`` over ``row_axes`` (default: all mesh axes), super keys
        and posting lists per row shard with a deterministic host-side merge
        — byte-identical artifacts to the single-host build at any device
        count.  One device (or no mesh) falls back to the single-host pass;
        ``n_shards`` optionally splits the host passes without a mesh.
        Accounting lands in ``session.build_stats`` (a ``BuildStats``).

        ``distributed=True`` skips the merge entirely and keeps the index
        ROUTED (``core.routing.ShardedMateIndex``): each shard's postings
        and superkeys stay resident where they were built (per-shard
        epoch-pinned device stores), the online filter runs shard-locally
        and only per-table counts cross shards — same top-k, bit-identical,
        with ``SessionStats.route_bytes_merged``/``shard_launches`` proving
        the traffic shape.  §5.4 mutations through this session then apply
        shard-locally too (one shard's epoch bumps, one store refreshes).
        """
        config = config or DiscoveryConfig()
        if distributed:
            from repro.core import routing

            index, build_stats = routing.build_routed_index(
                corpus,
                cfg=xash.XashConfig(bits=config.bits),
                hash_name=config.hash_name,
                use_corpus_char_freq=config.use_corpus_char_freq,
                mesh=mesh,
                row_axes=row_axes,
                n_shards=n_shards,
            )
        else:
            index, build_stats = index_lib.build_index(
                corpus,
                cfg=xash.XashConfig(bits=config.bits),
                hash_name=config.hash_name,
                use_corpus_char_freq=config.use_corpus_char_freq,
                mesh=mesh,
                row_axes=row_axes,
                n_shards=n_shards,
            )
        session = cls(index, config)
        session.build_stats = build_stats
        return session

    @property
    def bits(self) -> int:
        return self.index.bits

    def discover(
        self, query: Table, q_cols: list[int], k: int | None = None
    ) -> tuple[list[TopKEntry], DiscoveryStats]:
        """Top-k n-ary join discovery for one query (batched Algorithm 1)."""
        entries, stats = batched_lib.discover_batched(
            self.index,
            query,
            q_cols,
            k=self.config.k if k is None else k,
            batch_tables=self.config.batch_tables,
            init_mode=self.config.init_mode,
            backend=self.backend,
            prefetch_frac=self.config.prefetch_frac,
            fused_block_n=self.config.fused_block_n,
            rank=self.config.rank,
            profile_gate=self.config.profile_gate,
        )
        self.stats.absorb(stats)
        return entries, stats

    def discover_many(
        self,
        queries: list[tuple[Table, list[int]]],
        k: int | list[int] | None = None,
    ) -> list[tuple[list[TopKEntry], DiscoveryStats]]:
        """Multi-query discovery sharing ONE filter launch (group batching)."""
        out = batched_lib.discover_many(
            self.index,
            queries,
            k=self.config.k if k is None else k,
            init_mode=self.config.init_mode,
            backend=self.backend,
            prefetch_frac=self.config.prefetch_frac,
            fused_block_n=self.config.fused_block_n,
            rank=self.config.rank,
            profile_gate=self.config.profile_gate,
        )
        for _, stats in out:
            self.stats.absorb(stats)
        return out

    def plan_and_count(
        self,
        queries: list[tuple[Table, list[int]]],
        *,
        filter_lanes: int | None = None,
    ) -> list["batched_lib.PlanCounts"]:
        """Phase A of group discovery: the shared filter launch, demuxed per
        request (``core.batched.plan_and_count`` under this session's
        backend/config).  No stats are absorbed here — a request only counts
        when its PlanCounts is scored.  ``filter_lanes`` runs the launch at
        a lane prefix (the serving tier's pressure-degrade path)."""
        return batched_lib.plan_and_count(
            self.index,
            queries,
            self.backend,
            init_mode=self.config.init_mode,
            filter_lanes=filter_lanes,
            fused_block_n=self.config.fused_block_n,
            profile_gate=self.config.profile_gate,
        )

    def score_from_counts(
        self,
        pc: "batched_lib.PlanCounts",
        k: int | None = None,
        *,
        from_cache: bool = False,
    ) -> tuple[list[TopKEntry], DiscoveryStats]:
        """Phase B: score one ``PlanCounts`` (rule-1/2 pruning + exact
        verification + top-k heap) and absorb the request into session
        stats.  Safe to call repeatedly on the same PlanCounts — the
        bound-cache replay path (``from_cache=True`` skips launch-transfer
        accounting; the filter was paid for by an earlier request)."""
        entries, stats = batched_lib.score_from_counts(
            self.index,
            pc,
            self.config.k if k is None else k,
            prefetch_frac=self.config.prefetch_frac,
            from_cache=from_cache,
            rank=self.config.rank,
        )
        self.stats.absorb(stats)
        return entries, stats

    def discover_fds(
        self,
        query: Table,
        determinant_cols: list[int],
        dependent_col: int,
        *,
        min_support: int = 1,
    ) -> tuple[list["fd_module.FDCandidate"], DiscoveryStats]:
        """FD workload (``core.fd``): which lake tables preserve the candidate
        FD ``determinant_cols → dependent_col`` on the (never materialized)
        join with ``query``?  The session's backend/gate/init knobs apply
        unchanged; ``config.signals`` switches on the multi-signal ensemble
        ordering.  Stats are absorbed like any other request."""
        from repro.core import fd as fd_module

        fds, stats = fd_module.discover_fds(
            self.index,
            query,
            determinant_cols,
            dependent_col,
            min_support=min_support,
            backend=self.backend,
            init_mode=self.config.init_mode,
            profile_gate=self.config.profile_gate,
            signals=self.config.signals,
            fused_block_n=self.config.fused_block_n,
        )
        self.stats.absorb(stats)
        return fds, stats

    # index mutation passes through (§5.4): the session stays valid because
    # MateIndex updates are in-place and the backend/config hold no arrays.
    def insert_table(self, cells: list[list[str]], name: str = "") -> int:
        return self.index.insert_table(cells, name)

    def delete_table(self, table_id: int) -> None:
        self.index.delete_table(table_id)

    def update_cell(self, table_id: int, row: int, col: int, value: str) -> None:
        self.index.update_cell(table_id, row, col, value)

    def __repr__(self) -> str:
        return (
            f"MateSession(tables={len(self.index.corpus.tables)}, "
            f"bits={self.bits}, hash={self.index.hash_name}, "
            f"backend={self.backend.name}[{self.backend.source}], "
            f"served={self.stats.requests})"
        )
