"""XASH — the paper's hash function (§5), plus a pure-Python oracle.

Layout of the ``bits``-wide hash (bit index 0 is the LEFTMOST bit, the
paper's convention; we store the array as ``bits//32`` uint32 lanes with
bit ``b`` living in lane ``b // 32`` at in-lane offset ``b % 32``):

    [ length segment : L bits ][ character region : 37*c bits ]

* ``c``   = max c with 37*c < bits            (Eq. 6; c=3 for 128 bits)
* ``L``   = bits - 37*c                       (17 for 128 bits)
* ``ones``= argmin_i C(bits, i) > n_unique    (Eq. 5; 6 for 128b / 700M)
  → 1 length bit + (ones-1) character bits.

Per value v (length l_v = #characters):
  1. pick the ``ones-1`` least-frequent DISTINCT characters of v —
     "least frequent" is the within-value occurrence count (the paper's
     "Adam Sandler"/"Nick Adams" example calls the count-1 'm' THE least
     frequent character), ties broken by the global character-frequency
     prior, then by char id.  Count-1 characters also carry an exact (not
     averaged) position, maximising the location feature's discrimination;
  2. for each, average occurrence position (1-based) -> segment-local bit
     x = ceil(avg * c / l_v)                  (Eq. 7, exact integer math)
     region position p = char_id * c + (x-1);
  3. rotate the character region LEFT by l_v: p' = (p - l_v) mod (37*c)
     (§5.3.5 — couples length and characters without extra 1-bits);
  4. set length bit (l_v mod L) in the leftmost segment (§5.3.4).

The paper's Figure 3 narration ("84th → 47th most-left bit") implies a
particular segment ordering; any fixed, deterministic layout preserves every
property that matters (bounded popcount, no false negatives, rotation
coupling), and we use the layout above on both index and query sides.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding


@dataclasses.dataclass(frozen=True)
class XashConfig:
    bits: int = 128
    n_unique: int = 700_000_000  # DWTC-scale default (paper §5.3.1)
    n_ones: int | None = None  # override Eq. 5 if set
    char_freq: tuple | None = None  # corpus char frequencies (37,)
    max_len: int = encoding.MAX_LEN
    # component ablation switches (paper Fig. 6): full XASH = all True
    use_location: bool = True  # character-location bit within segment
    use_length: bool = True  # length segment bit
    use_rotation: bool = True  # rotate char region by l_v

    @property
    def lanes(self) -> int:
        assert self.bits % 32 == 0
        return self.bits // 32

    @property
    def c(self) -> int:
        """Bits per character segment (Eq. 6)."""
        return (self.bits - 1) // encoding.ALPHABET_SIZE

    @property
    def char_region(self) -> int:
        return encoding.ALPHABET_SIZE * self.c

    @property
    def len_segment(self) -> int:
        return self.bits - self.char_region

    @property
    def ones(self) -> int:
        """Total 1-bits per hash (Eq. 5): 1 length bit + (ones-1) char bits."""
        if self.n_ones is not None:
            return self.n_ones
        i = 1
        while math.comb(self.bits, i) <= self.n_unique:
            i += 1
        return i

    @property
    def n_char_bits(self) -> int:
        return self.ones - 1

    def freq_rank(self) -> np.ndarray:
        f = None if self.char_freq is None else np.asarray(self.char_freq)
        return encoding.freq_rank(f)


DEFAULT_CONFIG = XashConfig()


# ---------------------------------------------------------------------------
# Pure-Python oracle (operates on raw strings; ground truth for tests)
# ---------------------------------------------------------------------------

def xash_oracle(value: str, cfg: XashConfig = DEFAULT_CONFIG) -> int:
    """Reference XASH of one string as an arbitrary-precision Python int.

    Bit b of the conceptual layout (0 = leftmost) is represented as
    ``1 << b`` so that lane packing can be checked exactly.
    """
    enc = encoding.encode_value(value, cfg.max_len)
    return xash_oracle_encoded(enc, cfg)


def xash_oracle_encoded(enc: np.ndarray, cfg: XashConfig = DEFAULT_CONFIG) -> int:
    codes = [int(x) for x in enc if x != encoding.PAD]
    l_v = len(codes)
    if l_v == 0:
        return 0
    rank = cfg.freq_rank()
    # occurrence stats per char id
    occ: dict[int, list[int]] = {}
    for pos, code in enumerate(codes, start=1):
        occ.setdefault(code - 1, []).append(pos)
    present = sorted(occ, key=lambda cid: (len(occ[cid]), int(rank[cid]), cid))
    chosen = present[: cfg.n_char_bits]

    h = 0
    c, region, lseg = cfg.c, cfg.char_region, cfg.len_segment
    for cid in chosen:
        positions = occ[cid]
        sum_pos, count = sum(positions), len(positions)
        if cfg.use_location:
            # x = ceil(avg * c / l_v) with avg = sum_pos / count, exact:
            x = -((-sum_pos * c) // (count * l_v))
            x = min(max(x, 1), c)
        else:
            x = 1
        p = cid * c + (x - 1)
        p_rot = (p - l_v) % region if cfg.use_rotation else p
        h |= 1 << (lseg + p_rot)
    if cfg.use_length:
        h |= 1 << (l_v % lseg)
    return h


def int_to_lanes(h: int, cfg: XashConfig = DEFAULT_CONFIG) -> np.ndarray:
    """Pack an oracle hash int into uint32 lanes (bit b -> lane b//32, bit b%32)."""
    out = np.zeros(cfg.lanes, dtype=np.uint32)
    for lane in range(cfg.lanes):
        acc = 0
        for j in range(32):
            if (h >> (lane * 32 + j)) & 1:
                acc |= 1 << j
        out[lane] = acc
    return out


def lanes_to_int(lanes: np.ndarray) -> int:
    h = 0
    for i, lane in enumerate(np.asarray(lanes, dtype=np.uint64)):
        h |= int(lane) << (32 * i)
    return h


# ---------------------------------------------------------------------------
# Vectorised JAX implementation
# ---------------------------------------------------------------------------

def _bit_positions(enc: jnp.ndarray, cfg: XashConfig, rank: jnp.ndarray):
    """Per-value bit positions to set.

    Args:
      enc: uint8[..., max_len] encoded values.
      rank: int32[37] ascending-frequency rank of each char id.
    Returns:
      (positions int32[..., ones], valid bool[..., ones]) —
      global bit indices per value (length bit last).
    """
    a = encoding.ALPHABET_SIZE
    max_len = enc.shape[-1]
    c, region, lseg = cfg.c, cfg.char_region, cfg.len_segment

    codes = enc.astype(jnp.int32)
    is_char = codes > 0
    l_v = jnp.sum(is_char, axis=-1)  # [...,]

    # one-hot over char ids: [..., max_len, 37]
    onehot = (codes[..., None] == (jnp.arange(a, dtype=jnp.int32) + 1)) & is_char[..., None]
    count = jnp.sum(onehot, axis=-2)  # [..., 37]
    pos_idx = jnp.arange(1, max_len + 1, dtype=jnp.int32)
    sum_pos = jnp.sum(onehot * pos_idx[..., :, None], axis=-2)  # [..., 37]

    present = count > 0
    # rarest (n_char_bits) present chars: least within-value count first,
    # then global-frequency rank (ties by char id via stable top_k order).
    BIG = jnp.int32(1 << 24)
    score = jnp.where(present, count * 64 + rank, BIG)  # [..., 37]
    # top_k on negated score → k smallest
    k = cfg.n_char_bits
    neg, chosen_ids = jax.lax.top_k(-score, k)  # [..., k]
    chosen_valid = (-neg) < BIG

    ch_count = jnp.take_along_axis(count, chosen_ids, axis=-1)
    ch_sum = jnp.take_along_axis(sum_pos, chosen_ids, axis=-1)

    if cfg.use_location:
        # x = ceil(sum_pos * c / (count * l_v)) exactly, in int32
        denom = jnp.maximum(ch_count * l_v[..., None], 1)
        x = -((-ch_sum * c) // denom)
        x = jnp.clip(x, 1, c)
    else:
        x = jnp.ones_like(chosen_ids)

    p = chosen_ids * c + (x - 1)
    p_rot = jnp.mod(p - l_v[..., None], region) if cfg.use_rotation else p
    char_bits = lseg + p_rot  # [..., k]

    len_bit = jnp.mod(l_v, lseg)[..., None]  # [..., 1]
    len_valid = (l_v > 0)[..., None] & cfg.use_length

    positions = jnp.concatenate([char_bits, len_bit], axis=-1)
    valid = jnp.concatenate([chosen_valid, len_valid], axis=-1)
    # empty value (l_v==0) → nothing set
    valid = valid & (l_v[..., None] > 0)
    return positions, valid


def _pack(positions: jnp.ndarray, valid: jnp.ndarray, cfg: XashConfig) -> jnp.ndarray:
    """OR the one-hot of each bit position into uint32 lanes [..., lanes]."""
    bits = cfg.bits
    onehot = (positions[..., None] == jnp.arange(bits, dtype=jnp.int32)) & valid[..., None]
    anyset = jnp.any(onehot, axis=-2)  # [..., bits]
    lanes = anyset.reshape(*anyset.shape[:-1], cfg.lanes, 32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(jnp.where(lanes, weights, jnp.uint32(0)), axis=-1, dtype=jnp.uint32)


@partial(jax.jit, static_argnames=("cfg",))
def xash(enc: jnp.ndarray, cfg: XashConfig = DEFAULT_CONFIG) -> jnp.ndarray:
    """XASH of encoded values.

    Args:
      enc: uint8[..., max_len] encoded values (see encoding.py).
    Returns:
      uint32[..., lanes] hash lanes.
    """
    rank = jnp.asarray(cfg.freq_rank())
    positions, valid = _bit_positions(enc, cfg, rank)
    return _pack(positions, valid, cfg)


@partial(jax.jit, static_argnames=("cfg",))
def superkey(enc_row: jnp.ndarray, cfg: XashConfig = DEFAULT_CONFIG) -> jnp.ndarray:
    """Super key of rows: OR-aggregation of per-cell XASH (§5 'super key').

    Args:
      enc_row: uint8[..., n_cols, max_len] — all cells of each row.
    Returns:
      uint32[..., lanes].
    """
    hashes = xash(enc_row, cfg)  # [..., n_cols, lanes]
    return jax.lax.reduce(
        hashes,
        jnp.uint32(0),
        jnp.bitwise_or,
        dimensions=(hashes.ndim - 2,),
    )


@jax.jit
def subsumes(query_sk: jnp.ndarray, row_sk: jnp.ndarray) -> jnp.ndarray:
    """Row-filter predicate (§6.3): True iff query_sk ⊆ row_sk lane-wise.

    Broadcasts: query uint32[..., lanes] against rows uint32[..., lanes].
    """
    return jnp.all((query_sk & ~row_sk) == 0, axis=-1)
