"""Corpus model: tables, fixed-width encoded cell arena.

A ``Corpus`` stores every table's cells twice:
  * raw strings (host-side, for posting lists and exact verification), and
  * a fixed-width ``uint8`` arena ``enc[total_rows, max_cols, max_len]``
    (device-side, for vectorised hashing / verification).

Tables are concatenated row-wise; ``row_base[t]`` is the first global row id
of table ``t`` (``row_base[n_tables] == total_rows``).  Missing cells (table
narrower than ``max_cols``) encode as all-PAD and contribute nothing to the
row's super key.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import encoding


@dataclasses.dataclass
class Table:
    table_id: int
    cells: list[list[str]]  # [n_rows][n_cols]
    name: str = ""

    @property
    def n_rows(self) -> int:
        return len(self.cells)

    @property
    def n_cols(self) -> int:
        return len(self.cells[0]) if self.cells else 0

    def column(self, c: int) -> list[str]:
        return [row[c] for row in self.cells]


class Corpus:
    def __init__(self, tables: list[Table], max_len: int = encoding.MAX_LEN):
        self.tables = tables
        self.max_len = max_len
        self.max_cols = max((t.n_cols for t in tables), default=1)
        self.row_base = np.zeros(len(tables) + 1, dtype=np.int64)
        for i, t in enumerate(tables):
            self.row_base[i + 1] = self.row_base[i] + t.n_rows
        self.total_rows = int(self.row_base[-1])
        self.n_cols = np.array([t.n_cols for t in tables], dtype=np.int32)

        # Encode per UNIQUE value once; the arena holds value ids, the encoded
        # unique-value matrix is shared (big memory + hash-time win).
        self.value_of: dict[str, int] = {}
        uniques: list[str] = []
        self.cell_value_ids = np.full(
            (self.total_rows, self.max_cols), -1, dtype=np.int32
        )
        for t in tables:
            base = int(self.row_base[t.table_id])
            for r, row in enumerate(t.cells):
                for c, v in enumerate(row):
                    vid = self.value_of.get(v)
                    if vid is None:
                        vid = len(uniques)
                        self.value_of[v] = vid
                        uniques.append(v)
                    self.cell_value_ids[base + r, c] = vid
        self.unique_values = uniques
        self.unique_enc = encoding.encode_values(uniques, max_len)

    # -- lookups ------------------------------------------------------------

    def table_of_row(self, global_row: np.ndarray | int) -> np.ndarray | int:
        idx = np.searchsorted(self.row_base, global_row, side="right") - 1
        return idx

    def row_values(self, global_row: int) -> list[str]:
        t = int(self.table_of_row(global_row))
        r = global_row - int(self.row_base[t])
        return self.tables[t].cells[r]

    def avg_row_width(self) -> float:
        total_cells = sum(t.n_rows * t.n_cols for t in self.tables)
        return total_cells / max(self.total_rows, 1)

    def char_frequencies(self) -> np.ndarray:
        """Corpus-level character frequencies over unique values (§5.2.1)."""
        counts = np.zeros(encoding.ALPHABET_SIZE + 1, dtype=np.int64)
        np.add.at(counts, self.unique_enc.reshape(-1), 1)
        freq = counts[1:].astype(np.float64)
        total = freq.sum()
        return freq / total if total > 0 else freq + 1.0
