"""Batched kernel-backed discovery engine — the beyond-paper fast path.

The faithful Algorithm 1 (discovery.py) is a branchy per-row scan: ideal on a
CPU, hostile to a vector unit.  This engine restructures the online phase into
contiguous blocks fed straight to the §6.3 filter kernel:

  * query-side key hashing is ONE batched ``xash.superkey`` call
    (``MateIndex.superkey_of_keys``), not per-value host hashing;
  * candidate posting lists are gathered into a CSR block per query
    (``MateIndex.gather_candidates``): rows, value indices and table
    boundaries as three contiguous arrays — no per-row dict lookups;
  * the row filter runs as one subsumption launch per table batch through
    ``kernels.ops.filter_match_auto`` (Pallas ``filter_kernel`` on TPU,
    vectorised XLA fallback on CPU); value/key eligibility is a precomputed
    boolean gather, so match extraction is ``np.nonzero`` — no Python loop
    over posting-list items;
  * tables are visited in the same descending posting-list order as
    Algorithm 1; rule 1 (global cutoff) applies BETWEEN batches — identical
    pruning guarantee, since the bound only improves as the scan proceeds;
  * rule 2 becomes a *stronger* bound: the exact filtered-candidate count per
    table (free from the batch filter) replaces the paper's incremental
    ``L_t - r_checked + r_match`` bound, so strictly more tables are skipped
    before verification;
  * only filter-surviving pairs are verified on the host (same exact
    ``calculateJ`` as the faithful engine).

``discover_many`` extends this to multi-query batching: all requests' rows
and keys concatenate into ONE filter launch, then demux per request — the
shape ``serve.engine.DiscoveryEngine`` uses for concurrent traffic.

Top-k results are BIT-IDENTICAL to Algorithm 1 (ids, joinability scores and
mappings): both engines visit tables in the same order with the same
replace-only-if-strictly-greater heap, and every pruned table provably cannot
enter a full heap (its joinability is bounded by the pruning threshold).
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict

import numpy as np

from repro.core import discovery as seq
from repro.core.corpus import Table
from repro.core.discovery import DiscoveryStats, TopKEntry
from repro.core.index import CandidateBlock, MateIndex
from repro.kernels import ops

DEFAULT_BATCH_TABLES = 256


@dataclasses.dataclass
class QueryPlan:
    """Precomputed per-query state feeding the batched filter."""

    query: Table
    q_cols: list[int]
    distinct_keys: list[tuple]
    q_sk: np.ndarray  # uint32[K, lanes] batched query-key super keys
    block: CandidateBlock  # CSR candidate rows grouped per table
    elig: np.ndarray  # bool[N_items, K] init-value eligibility per item
    stats: DiscoveryStats


def plan_query(
    index: MateIndex, query: Table, q_cols: list[int],
    init_mode: str = "cardinality",
) -> QueryPlan:
    """Initialization phase (§6.1) in columnar form: one hash launch, one
    posting-list gather, one eligibility matrix."""
    stats = DiscoveryStats()
    init_col = seq.init_column_selection(query, q_cols, init_mode, index)
    init_idx = q_cols.index(init_col)
    keys = [tuple(row[c] for c in q_cols) for row in query.cells]
    distinct_keys = list(dict.fromkeys(keys))
    q_sk = index.superkey_of_keys(distinct_keys)

    values = list(dict.fromkeys(query.column(init_col)))
    value_id = {v: i for i, v in enumerate(values)}
    # bool[n_values, K]: key kid is probed against items of value v only if
    # the key's init-column entry IS v (Alg. 1 matches per posting list).
    elig_value = np.zeros((len(values), len(distinct_keys)), dtype=bool)
    for kid, key in enumerate(distinct_keys):
        elig_value[value_id[key[init_idx]], kid] = True

    block = index.gather_candidates(values)
    stats.pl_items_total = block.n_items
    stats.tables_fetched = block.n_tables
    elig = (
        elig_value[block.value_idx]
        if block.n_items
        else np.zeros((0, len(distinct_keys)), dtype=bool)
    )
    return QueryPlan(query, q_cols, distinct_keys, q_sk, block, elig, stats)


def _filter(row_sk: np.ndarray, q_sk: np.ndarray, use_kernel: bool) -> np.ndarray:
    if use_kernel:
        return ops.filter_match_auto(row_sk, q_sk)
    return ops.subsume_np(row_sk, q_sk)


def _calculate_j(
    index: MateIndex,
    plan: QueryPlan,
    rows: np.ndarray,
    hits: np.ndarray,
) -> tuple[int, tuple[int, ...] | None]:
    """Exact verification (Alg. 1 line 21) over filter-surviving pairs."""
    corpus = index.corpus
    stats = plan.stats
    rows_per_mapping: dict[tuple[int, ...], set] = defaultdict(set)
    rs, ks = np.nonzero(hits)
    for r, kid in zip(rs.tolist(), ks.tolist()):
        key = plan.distinct_keys[kid]
        mappings = seq._verify_pair(key, corpus.row_values(int(rows[r])))
        if mappings:
            stats.verified_tp += 1
            for m in mappings:
                rows_per_mapping[m].add(key)
        else:
            stats.verified_fp += 1
    if not rows_per_mapping:
        return 0, None
    mapping, keyset = max(
        rows_per_mapping.items(), key=lambda kv: (len(kv[1]), kv[0])
    )
    return len(keyset), mapping


class _TopK:
    """Algorithm 1's heap: push while filling, replace only if strictly
    greater — the tie semantics both engines share (bit-identical results)."""

    def __init__(self, k: int):
        self.k = k
        self.heap: list[tuple[int, int]] = []  # (J, -table_id) min-heap
        self.mapping: dict[int, tuple[int, ...] | None] = {}

    def bound(self) -> int:
        return self.heap[0][0] if len(self.heap) >= self.k else 0

    @property
    def full(self) -> bool:
        return len(self.heap) >= self.k

    def offer(self, tid: int, joinability: int, mapping) -> None:
        self.mapping[tid] = mapping
        if joinability <= 0:
            return
        if len(self.heap) < self.k:
            heapq.heappush(self.heap, (joinability, -tid))
        elif joinability > self.heap[0][0]:
            heapq.heapreplace(self.heap, (joinability, -tid))

    def entries(self) -> list[TopKEntry]:
        out = [
            TopKEntry(table_id=-neg, joinability=j, mapping=self.mapping.get(-neg))
            for j, neg in self.heap
        ]
        out.sort(key=lambda e: (-e.joinability, e.table_id))
        return out


def _score_tables(
    index: MateIndex,
    plan: QueryPlan,
    topk: _TopK,
    hits: np.ndarray,
    rows: np.ndarray,
    t_start: int,
    t_stop: int,
    base: int,
) -> None:
    """Verify (or rule-2-prune) tables [t_start, t_stop) of the plan's block,
    whose items live at ``block`` offsets ``base:`` covered by hits/rows."""
    block, stats = plan.block, plan.stats
    ptr = block.table_ptr
    for t in range(t_start, t_stop):
        stats.tables_evaluated += 1
        tid = int(block.table_ids[t])
        lo, hi = int(ptr[t]) - base, int(ptr[t + 1]) - base
        sub = hits[lo:hi]
        # strengthened rule 2: exact filtered-candidate count bound
        if topk.full and int(sub.sum()) <= topk.bound():
            stats.tables_pruned_rule2 += 1
            continue
        joinability, mapping = _calculate_j(index, plan, rows[lo:hi], sub)
        topk.offer(tid, joinability, mapping)


def discover_batched(
    index: MateIndex,
    query: Table,
    q_cols: list[int],
    k: int = 10,
    batch_tables: int = DEFAULT_BATCH_TABLES,
    init_mode: str = "cardinality",
    use_kernel: bool = True,
) -> tuple[list[TopKEntry], DiscoveryStats]:
    """Batched Algorithm 1: one filter launch per ``batch_tables`` tables."""
    plan = plan_query(index, query, q_cols, init_mode)
    stats, block = plan.stats, plan.block
    topk = _TopK(k)
    n_tables = block.n_tables
    for start in range(0, n_tables, batch_tables):
        stop = min(start + batch_tables, n_tables)
        # rule 1 between batches: tables are PL-desc sorted, so if the FIRST
        # table of the batch is at/below the bound, everything after is too.
        first_count = int(block.table_ptr[start + 1] - block.table_ptr[start])
        if topk.full and first_count <= topk.bound():
            stats.tables_pruned_rule1 += n_tables - start
            break
        lo, hi = int(block.table_ptr[start]), int(block.table_ptr[stop])
        rows = block.rows[lo:hi]
        row_sk = index.superkey_of_rows(rows)
        elig = plan.elig[lo:hi]
        hits = _filter(row_sk, plan.q_sk, use_kernel) & elig
        stats.pl_items_checked += int(rows.shape[0])
        stats.filter_checks += int(elig.sum())
        stats.filter_passed += int(hits.sum())
        _score_tables(index, plan, topk, hits, rows, start, stop, lo)
    return topk.entries(), stats


def discover_many(
    index: MateIndex,
    queries: list[tuple[Table, list[int]]],
    k: int | list[int] = 10,
    init_mode: str = "cardinality",
    use_kernel: bool = True,
) -> list[tuple[list[TopKEntry], DiscoveryStats]]:
    """Multi-query discovery sharing ONE filter launch.

    All requests' candidate rows and query keys concatenate into a single
    subsumption launch; the match matrix is then demuxed per request and
    scored with the same rule-1/rule-2 + heap semantics, so each request's
    top-k is bit-identical to its solo ``discover``/``discover_batched`` run.

    Cost note: the shared launch computes the full (Σ rows × Σ keys) cross
    product — only the block diagonal is consumed, so filter work grows
    ~linearly with group size beyond the useful probes.  That trade buys one
    kernel dispatch for the whole group, which wins while dispatch latency
    dominates (small/medium groups, accelerator backends); keep serving
    groups bounded (``DiscoveryEngine(batch=...)``, default 8) rather than
    fusing unbounded request sets.
    """
    ks = [k] * len(queries) if isinstance(k, int) else list(k)
    assert len(ks) == len(queries)
    plans = [plan_query(index, q, q_cols, init_mode) for q, q_cols in queries]
    if plans:
        rows_all = np.concatenate([p.block.rows for p in plans])
        q_all = np.concatenate([p.q_sk for p in plans])
        match = _filter(index.superkey_of_rows(rows_all), q_all, use_kernel)
    out: list[tuple[list[TopKEntry], DiscoveryStats]] = []
    r_off = k_off = 0
    for plan, k_i in zip(plans, ks):
        n_items, n_keys = plan.block.n_items, plan.q_sk.shape[0]
        sub = match[r_off : r_off + n_items, k_off : k_off + n_keys]
        r_off += n_items
        k_off += n_keys
        hits = sub & plan.elig
        stats, block = plan.stats, plan.block
        stats.pl_items_checked = n_items
        stats.filter_checks = int(plan.elig.sum())
        stats.filter_passed = int(hits.sum())
        topk = _TopK(k_i)
        for t in range(block.n_tables):
            # rule 1: tables PL-desc sorted → bound prunes the whole suffix
            # (verification work only; the filter already ran batched).
            count = int(block.table_ptr[t + 1] - block.table_ptr[t])
            if topk.full and count <= topk.bound():
                stats.tables_pruned_rule1 += block.n_tables - t
                break
            _score_tables(index, plan, topk, hits, block.rows, t, t + 1, 0)
        out.append((topk.entries(), stats))
    return out
