"""Batched (TPU-style) discovery engine — the beyond-paper optimisation.

The faithful Algorithm 1 (discovery.py) is a branchy per-row scan: ideal on a
CPU, hostile to a vector unit.  This engine restructures the online phase into
fixed-shape batches:

  * tables are still visited in descending posting-list order, but in batches;
    rule 1 (global cutoff) applies BETWEEN batches — identical pruning
    guarantee, since the bound only improves as the scan proceeds;
  * the row filter runs as ONE vectorised subsumption test per batch
    (the Pallas filter kernel on TPU, jnp on CPU) instead of per-row probes;
  * rule 2 becomes a *stronger* bound: the exact filtered-candidate count per
    table (available for free from the batch filter) replaces the paper's
    incremental ``L_t - r_checked + r_match`` bound, so strictly more tables
    are skipped before verification;
  * only filter-surviving pairs are verified on the host (same exact
    `calculateJ` as the faithful engine).

Top-k results are identical to Algorithm 1 up to equal-score tie ordering
(tests assert score-multiset equality against the brute-force oracle).
"""

from __future__ import annotations

from collections import defaultdict

import numpy as np

from repro.core import discovery as seq
from repro.core.discovery import DiscoveryStats, TopKEntry
from repro.core.index import MateIndex
from repro.core.corpus import Table
from repro.kernels import ops


def discover_batched(
    index: MateIndex,
    query: Table,
    q_cols: list[int],
    k: int = 10,
    batch_tables: int = 128,
    init_mode: str = "cardinality",
    use_kernel: bool = True,
) -> tuple[list[TopKEntry], DiscoveryStats]:
    stats = DiscoveryStats()
    corpus = index.corpus

    init_col = seq.init_column_selection(query, q_cols, init_mode, index)
    keys, sk_of_key = seq.build_query_superkeys(index, query, q_cols)
    init_idx = q_cols.index(init_col)
    distinct_keys = list(dict.fromkeys(keys))
    key_id = {key: i for i, key in enumerate(distinct_keys)}
    q_sk = np.stack([sk_of_key[key] for key in distinct_keys])  # [K, lanes]
    keys_of_value: dict[str, list[int]] = defaultdict(list)
    for key in distinct_keys:
        keys_of_value[key[init_idx]].append(key_id[key])

    # fetch + group by table
    by_table: dict[int, list[tuple[int, str]]] = defaultdict(list)
    for value in dict.fromkeys(query.column(init_col)):
        pl = index.fetch_postings(value)
        stats.pl_items_total += len(pl)
        if len(pl) == 0:
            continue
        tids = corpus.table_of_row(pl[:, 0])
        for (grow, _col), tid in zip(pl.tolist(), np.atleast_1d(tids).tolist()):
            by_table[int(tid)].append((int(grow), value))
    order = sorted(by_table, key=lambda t: (-len(by_table[t]), t))
    stats.tables_fetched = len(order)

    top: list[tuple[int, int]] = []  # (J, table_id) sorted asc by J

    def j_k() -> int:
        return top[0][0] if len(top) >= k else 0

    results: dict[int, tuple[int, tuple | None]] = {}
    for start in range(0, len(order), batch_tables):
        batch = order[start : start + batch_tables]
        # rule 1 between batches: the batch is PL-desc sorted, so if the
        # FIRST table of the batch is below the bound, everything after is.
        if len(top) >= k and len(by_table[batch[0]]) <= j_k():
            stats.tables_pruned_rule1 += len(order) - start
            break

        rows, row_key_lists, row_tid = [], [], []
        for tid in batch:
            for grow, value in by_table[tid]:
                rows.append(grow)
                row_key_lists.append(keys_of_value[value])
                row_tid.append(tid)
        rows_np = np.asarray(rows, dtype=np.int64)
        row_sk = index.superkeys[rows_np]  # [R, lanes]
        match = np.asarray(ops.filter_match(row_sk, q_sk)) if use_kernel else (
            np.all((q_sk[None, :, :] & ~row_sk[:, None, :]) == 0, axis=-1)
        )  # [R, K]

        # restrict matches to keys sharing the row's init value
        pair_rows: dict[int, list[tuple[int, int]]] = defaultdict(list)
        for r, (grow, kl, tid) in enumerate(zip(rows, row_key_lists, row_tid)):
            stats.pl_items_checked += 1
            stats.filter_checks += len(kl)
            for kid in kl:
                if match[r, kid]:
                    stats.filter_passed += 1
                    pair_rows[tid].append((kid, grow))

        for tid in batch:
            stats.tables_evaluated += 1
            pairs = pair_rows.get(tid, [])
            # strengthened rule 2: exact filtered candidate count bound
            if len(top) >= k and len(pairs) <= j_k():
                stats.tables_pruned_rule2 += 1
                continue
            rows_per_mapping: dict[tuple[int, ...], set] = defaultdict(set)
            for kid, grow in pairs:
                mappings = seq._verify_pair(
                    distinct_keys[kid], corpus.row_values(grow)
                )
                if mappings:
                    stats.verified_tp += 1
                    for m in mappings:
                        rows_per_mapping[m].add(kid)
                else:
                    stats.verified_fp += 1
            if rows_per_mapping:
                mapping, rowset = max(
                    rows_per_mapping.items(), key=lambda kv: (len(kv[1]), kv[0])
                )
                joinability = len(rowset)
            else:
                mapping, joinability = None, 0
            results[tid] = (joinability, mapping)
            if joinability > 0:
                import heapq

                if len(top) < k:
                    heapq.heappush(top, (joinability, -tid))
                elif joinability > top[0][0]:
                    heapq.heapreplace(top, (joinability, -tid))

    entries = [
        TopKEntry(table_id=-neg, joinability=j, mapping=results[-neg][1])
        for j, neg in top
    ]
    entries.sort(key=lambda e: (-e.joinability, e.table_id))
    return entries, stats
