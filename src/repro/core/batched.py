"""Batched kernel-backed discovery engine — the beyond-paper fast path.

The faithful Algorithm 1 (discovery.py) is a branchy per-row scan: ideal on a
CPU, hostile to a vector unit.  This engine restructures the online phase into
contiguous blocks fed straight to the §6.3 filter kernel:

  * query-side key hashing is ONE batched ``xash.superkey`` call
    (``MateIndex.superkey_of_keys``), not per-value host hashing;
  * candidate posting lists are gathered into a CSR block per query
    (``MateIndex.gather_candidates``): rows, value indices and table
    boundaries as three contiguous arrays — no per-row dict lookups;
  * the row filter runs as one subsumption launch per table batch through
    ``kernels.ops.filter_hits_table_counts`` (Pallas ``filter_kernel`` on
    TPU, vectorised XLA fallback on CPU); value/key eligibility is a
    precomputed boolean gather fused into the launch, so match extraction is
    ``np.nonzero`` over per-table slices — no Python loop over PL items;
  * the rule-1/rule-2 joinability bound check is DEVICE-SIDE in
    ``discover_batched``: each launch also reduces the match matrix to
    per-table eligible-hit counts (a matvec row-reduction + segment-sum over
    the CSR table ids), and only that tiny int32 counts vector is read back
    per batch.  The full ``[rows × keys]`` match matrix is never transferred
    to the host — per surviving (un-pruned) table, just its row slice of the
    hit matrix is read back for exact verification (or one prefetch of the
    batch when the entry bound leaves most items alive anyway);
  * on the FUSED path (``backend='fused'`` — the TPU platform default, also
    selectable via ``MATE_FILTER_BACKEND=fused``; see ``kernels.registry``
    for the one precedence rule) the reduction happens INSIDE the filter kernel
    (``filter_kernel.filter_table_counts``): subsumption ∧ eligibility is
    row-summed and scatter-accumulated over the CSR table ids in VMEM, so
    the match matrix never exists even in HBM — counts-only readback,
    ``DiscoveryStats.filter_matrix_bytes == 0``, and surviving tables'
    slices are recomputed on demand for verification.  ``discover_many``
    uses the same fused group launch, so requests pruned by the evolving
    bounds never pay for their block of the cross-product matrix;
  * ``backend='fused-gather'`` (the TPU platform default) additionally
    fuses the CANDIDATE GATHER into that launch: the kernel scalar-prefetches
    the CSR posting-list row offsets and DMA-gathers each row block from the
    device-resident superkey store (``MateIndex.device_store()``, refreshed
    on §5.4 mutation epochs) straight into VMEM — the host never gathers the
    candidate superkeys and the gathered rows×lanes block never exists in
    HBM (``DiscoveryStats.gather_bytes_saved`` counts the traffic avoided).
    Demotes to 'fused' per launch when the store is over budget or the batch
    exceeds the scatter-tile table cap;
  * tables are visited in the same descending posting-list order as
    Algorithm 1; rule 1 (global cutoff) applies BETWEEN batches — identical
    pruning guarantee, since the bound only improves as the scan proceeds;
  * rule 2 becomes a *stronger* bound: the exact filtered-candidate count per
    table (the device-side counts vector) replaces the paper's incremental
    ``L_t - r_checked + r_match`` bound, so strictly more tables are skipped
    before verification;
  * only filter-surviving pairs are verified on the host (same exact
    ``calculateJ`` as the faithful engine).

Hash width is a first-class knob: every array here is ``lanes``-wide
(``XashConfig(bits=...)`` → 4/8/16 uint32 lanes for 128/256/512 bits), so the
same engine and kernels serve any width the index was built at — the paper's
Table 1/2 FP-rate vs filter-bandwidth tradeoff (see
``benchmarks/bench_fp_rate.py``).

``discover_many`` extends this to multi-query batching: all requests' rows
and keys concatenate into ONE filter launch, then demux per request — the
shape ``serve.engine.DiscoveryEngine`` uses for concurrent traffic.

Top-k results are BIT-IDENTICAL to Algorithm 1 (ids, joinability scores and
mappings): both engines visit tables in the same order with the same
replace-only-if-strictly-greater heap, and every pruned table provably cannot
enter a full heap (its joinability is bounded by the pruning threshold).
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict

import numpy as np

from repro.core import discovery as seq
from repro.core import ranking
from repro.core.corpus import Table
from repro.core.discovery import DiscoveryStats, TopKEntry
from repro.core.index import CandidateBlock, MateIndex
from repro.kernels import ops, registry
from repro.kernels.registry import Backend

DEFAULT_BATCH_TABLES = 256


@dataclasses.dataclass
class QueryPlan:
    """Precomputed per-query state feeding the batched filter."""

    query: Table
    q_cols: list[int]
    distinct_keys: list[tuple]
    q_sk: np.ndarray  # uint32[K, lanes] batched query-key super keys
    block: CandidateBlock  # CSR candidate rows grouped per table
    elig: np.ndarray  # bool[N_items, K] init-value eligibility per item
    stats: DiscoveryStats


def _gate_block(block: CandidateBlock, keep: np.ndarray) -> CandidateBlock:
    """Drop gated tables (and their items) from a CSR candidate block.

    ``keep`` is the profile gate's per-table mask; the surviving tables
    stay in PL-descending order (a subsequence of a sorted sequence), so
    the rule-1 prefix-cutoff argument downstream is unchanged."""
    lengths = np.diff(block.table_ptr)
    item_keep = np.repeat(keep, lengths)
    kept_lengths = lengths[keep]
    ptr = np.zeros(kept_lengths.shape[0] + 1, dtype=np.int64)
    np.cumsum(kept_lengths, out=ptr[1:])
    return CandidateBlock(
        rows=block.rows[item_keep],
        value_idx=block.value_idx[item_keep],
        table_ids=block.table_ids[keep],
        table_ptr=ptr,
    )


def plan_query(
    index: MateIndex, query: Table, q_cols: list[int],
    init_mode: str = "cardinality",
    *,
    profile_gate: bool = False,
) -> QueryPlan:
    """Initialization phase (§6.1) in columnar form: one hash launch, one
    posting-list gather, one eligibility matrix.

    ``profile_gate=True`` drops candidate tables whose column profiles
    PROVE joinability 0 (``MateIndex.gate_candidates`` — presence-mask /
    length-bucket / char-class / column-count necessary conditions) before
    any superkey is gathered or filtered: pure pruning, the verified top-k
    set is unchanged; ``stats.tables_gated`` / ``gate_bytes_saved`` count
    the work the filter launches never saw.  ``tables_fetched`` /
    ``pl_items_total`` stay PRE-gate (what the posting lists produced)."""
    stats = DiscoveryStats()
    init_col = seq.init_column_selection(query, q_cols, init_mode, index)
    init_idx = q_cols.index(init_col)
    keys = [tuple(row[c] for c in q_cols) for row in query.cells]
    distinct_keys = list(dict.fromkeys(keys))
    q_sk = index.superkey_of_keys(distinct_keys)

    values = list(dict.fromkeys(query.column(init_col)))
    value_id = {v: i for i, v in enumerate(values)}
    # bool[n_values, K]: key kid is probed against items of value v only if
    # the key's init-column entry IS v (Alg. 1 matches per posting list).
    elig_value = np.zeros((len(values), len(distinct_keys)), dtype=bool)
    for kid, key in enumerate(distinct_keys):
        elig_value[value_id[key[init_idx]], kid] = True

    block = index.gather_candidates(values)
    stats.pl_items_total = block.n_items
    stats.tables_fetched = block.n_tables
    if profile_gate and block.n_tables and distinct_keys:
        keep = index.gate_candidates(distinct_keys, block.table_ids)
        if not keep.all():
            stats.tables_gated = int((~keep).sum())
            n_before = block.n_items
            block = _gate_block(block, keep)
            # superkey lanes the filter launches now never gather/compare
            stats.gate_bytes_saved = (
                (n_before - block.n_items) * q_sk.shape[1] * 4
            )
    elig = (
        elig_value[block.value_idx]
        if block.n_items
        else np.zeros((0, len(distinct_keys)), dtype=bool)
    )
    return QueryPlan(query, q_cols, distinct_keys, q_sk, block, elig, stats)


def _segment_ids(table_ptr: np.ndarray, t_start: int, t_stop: int) -> np.ndarray:
    """int32 per-item table index (relative to t_start) for a CSR range."""
    lengths = np.diff(table_ptr[t_start : t_stop + 1])
    return np.repeat(
        np.arange(t_stop - t_start, dtype=np.int32), lengths
    )


def _hits_counts_host(row_sk, q_sk, elig, seg, n_tables, backend: Backend):
    """Host-side hits + per-table counts: one filter launch, full readback.

    The right call when the top-k bound cannot prune yet (heap not full) —
    every hit block is about to be verified anyway, so fusing the count
    reduction into the launch would add device work without saving a byte.
    """
    if not backend.device:
        return ops.filter_hits_table_counts(
            row_sk, q_sk, elig, seg, n_tables, backend="numpy"
        )
    hits = ops.filter_match_auto(row_sk, q_sk, backend=backend) & elig
    counts = np.bincount(
        seg, weights=hits.sum(axis=1), minlength=max(n_tables, 1)
    ).astype(np.int32)
    return hits, counts[:n_tables]


def _calculate_j(
    index: MateIndex,
    plan: QueryPlan,
    rows: np.ndarray,
    hits: np.ndarray,
) -> tuple[int, tuple[int, ...] | None]:
    """Exact verification (Alg. 1 line 21) over filter-surviving pairs."""
    corpus = index.corpus
    stats = plan.stats
    rows_per_mapping: dict[tuple[int, ...], set] = defaultdict(set)
    rs, ks = np.nonzero(hits)
    for r, kid in zip(rs.tolist(), ks.tolist()):
        key = plan.distinct_keys[kid]
        mappings = seq._verify_pair(key, corpus.row_values(int(rows[r])))
        if mappings:
            stats.verified_tp += 1
            for m in mappings:
                rows_per_mapping[m].add(key)
        else:
            stats.verified_fp += 1
    if not rows_per_mapping:
        return 0, None
    mapping, keyset = max(
        rows_per_mapping.items(), key=lambda kv: (len(kv[1]), kv[0])
    )
    return len(keyset), mapping


class _TopK:
    """Algorithm 1's heap: push while filling, replace only if strictly
    greater — the tie semantics both engines share (bit-identical results)."""

    def __init__(self, k: int):
        self.k = k
        self.heap: list[tuple[int, int]] = []  # (J, -table_id) min-heap
        self.mapping: dict[int, tuple[int, ...] | None] = {}

    def bound(self) -> int:
        return self.heap[0][0] if len(self.heap) >= self.k else 0

    @property
    def full(self) -> bool:
        return len(self.heap) >= self.k

    def offer(self, tid: int, joinability: int, mapping) -> None:
        self.mapping[tid] = mapping
        if joinability <= 0:
            return
        if len(self.heap) < self.k:
            heapq.heappush(self.heap, (joinability, -tid))
        elif joinability > self.heap[0][0]:
            heapq.heapreplace(self.heap, (joinability, -tid))

    def entries(self) -> list[TopKEntry]:
        out = [
            TopKEntry(table_id=-neg, joinability=j, mapping=self.mapping.get(-neg))
            for j, neg in self.heap
        ]
        out.sort(key=lambda e: (-e.joinability, e.table_id))
        return out


def _ranked_entries(
    topk: _TopK, rank: str, scores: dict[int, float]
) -> list[TopKEntry]:
    """Order the heap's entries for the requested rank mode.

    ``rank='count'`` is the historical (-joinability, table_id) order;
    ``rank='quality'`` annotates each entry with its scoring-head value and
    sorts (-quality, -joinability, table_id).  Either way the entries come
    from the SAME heap — rank never changes set membership."""
    entries = topk.entries()
    if rank != "quality":
        return entries
    entries = [
        dataclasses.replace(e, quality=float(scores.get(e.table_id, 0.0)))
        for e in entries
    ]
    entries.sort(key=lambda e: (-e.quality, -e.joinability, e.table_id))
    return entries


# below this fraction of batch items surviving the entry bound, per-table
# hit-slice readbacks beat one whole-batch transfer (dispatch vs bytes)
_PREFETCH_FRAC = 0.25


def _score_tables(
    index: MateIndex,
    plan: QueryPlan,
    topk: _TopK,
    hits,
    counts: np.ndarray,
    rows: np.ndarray,
    t_start: int,
    t_stop: int,
    base: int,
    rule1: bool = False,
    row_sk: np.ndarray | None = None,
    elig: np.ndarray | None = None,
    prefetch_frac: float = _PREFETCH_FRAC,
) -> None:
    """Verify (or rule-2-prune) tables [t_start, t_stop) of the plan's block,
    whose items live at ``block`` offsets ``base:`` covered by hits/rows.

    ``hits`` may be device-resident (jnp) and is only read back as needed:
    the rule-2 bound is checked against ``counts`` (the device-computed
    per-table eligible-hit counts, indexed relative to ``t_start``), so
    pruned tables never transfer their slice.  When the bound at entry
    leaves most items alive anyway, the whole range is prefetched in ONE
    transfer instead of per-table dispatches; counts are exact, so the
    evolving-bound pruning decisions below are identical either way.

    ``hits`` may also be None — the FUSED counts-only launch, where the
    match matrix was never produced at all.  Surviving tables' hit slices
    are then recomputed on demand from ``row_sk``/``elig`` (same subsumption
    predicate → bit-identical verification inputs); pruned tables cost
    nothing beyond their 4 count bytes.  On the GATHER-fused path even
    ``row_sk`` is None — the host never gathered the candidate superkeys —
    and surviving tables gather just their own slice from the index store
    (the same ``superkeys`` array every other path reads: bit-identical).

    ``rule1=True`` additionally applies the paper's rule 1 inside the range
    (tables are PL-desc sorted → the first at/below the bound prunes the
    whole suffix) — the ``discover_many`` path, where the filter already ran
    for every table and only verification work remains to be skipped.
    """
    block, stats = plan.block, plan.stats
    ptr = block.table_ptr
    lazy = hits is None
    if lazy:
        assert elig is not None
    device_hits = (not lazy) and not isinstance(hits, np.ndarray)
    if device_hits:
        bound0 = topk.bound() if topk.full else -1
        alive = counts[: t_stop - t_start] > bound0
        n_alive = int(
            (alive * np.diff(ptr[t_start : t_stop + 1])).sum()
        )
        total = int(ptr[t_stop] - ptr[t_start])
        if total and n_alive >= prefetch_frac * total:
            hits = np.asarray(hits)
            stats.filter_readback_bytes += hits.size
            device_hits = False
    for t in range(t_start, t_stop):
        if rule1 and topk.full and int(ptr[t + 1] - ptr[t]) <= topk.bound():
            stats.tables_pruned_rule1 += t_stop - t
            break
        stats.tables_evaluated += 1
        tid = int(block.table_ids[t])
        lo, hi = int(ptr[t]) - base, int(ptr[t + 1]) - base
        # strengthened rule 2: exact filtered-candidate count bound, from the
        # device-side counts — no match-matrix transfer for pruned tables.
        if topk.full and int(counts[t - t_start]) <= topk.bound():
            stats.tables_pruned_rule2 += 1
            continue
        if lazy:
            rsk = (
                row_sk[lo:hi]
                if row_sk is not None
                else index.superkey_of_rows(rows[lo:hi])
            )
            sub = ops.subsume_np(rsk, plan.q_sk) & elig[lo:hi]
            stats.filter_readback_bytes += sub.size
        else:
            sub = np.asarray(hits[lo:hi])
            if device_hits:
                stats.filter_readback_bytes += sub.size
        joinability, mapping = _calculate_j(index, plan, rows[lo:hi], sub)
        topk.offer(tid, joinability, mapping)


def discover_batched(
    index: MateIndex,
    query: Table,
    q_cols: list[int],
    k: int = 10,
    batch_tables: int = DEFAULT_BATCH_TABLES,
    init_mode: str = "cardinality",
    backend: Backend | str | None = None,
    *,
    prefetch_frac: float = _PREFETCH_FRAC,
    fused_block_n: int | None = None,
    filter_lanes: int | None = None,
    rank: str = "count",
    profile_gate: bool = False,
) -> tuple[list[TopKEntry], DiscoveryStats]:
    """Batched Algorithm 1: one filter launch per ``batch_tables`` tables.

    ``profile_gate=True`` pre-filters the candidate block against the
    column-profile store (see ``plan_query``) — pure pruning, set-identical.
    ``rank='quality'`` runs the ``core.ranking`` scoring head over each
    batch's counts vector (one extra launch per batch) and reorders the
    returned entries by join quality; the heap — and therefore the verified
    top-k SET — is untouched.  The raw engines default to the historical
    ``rank='count'``/gate-off behaviour; ``DiscoveryConfig`` flips both
    defaults at the session layer.

    Per batch, the device computes the subsumption matrix ∧ eligibility AND
    reduces it to per-table hit counts; only that counts vector (4 bytes per
    table) is read back for the rule-1/rule-2 bound checks.  Hit-matrix
    slices are transferred solely for tables that survive pruning and need
    exact verification.

    ``backend`` selects the §6.3 filter implementation (a resolved
    ``kernels.registry.Backend`` or a registered name); None follows the
    registry precedence: ``MATE_FILTER_BACKEND`` env var, then the platform
    default (fused on TPU, size-based auto split elsewhere).  On 'fused' the
    match matrix is never materialised — not even in HBM — so
    ``stats.filter_matrix_bytes`` stays 0 and surviving tables' slices are
    recomputed on demand.  The pre-registry ``use_kernel=``/``fused=`` shims
    were removed after their one-release deprecation window (PR 4): passing
    them raises TypeError; pin the path with ``backend=`` instead
    (``use_kernel=False`` -> 'numpy', ``fused=True`` -> 'fused',
    ``fused=False`` -> 'pallas').

    ``filter_lanes`` runs the filter launches over only the first N uint32
    lanes of the super keys (the serving tier's pressure-degrade path:
    ``filter_lanes=4`` ≙ 128-bit filtering on a wider index).  A lane-prefix
    subsumption test is a pure relaxation of the full-width test — zero
    false negatives — so after exact verification the top-k is BIT-IDENTICAL
    to the full-width run; only filter precision (and the rule-2 bound
    tightness) degrades.
    """
    bk = registry.resolve_backend(backend)
    plan = plan_query(index, query, q_cols, init_mode, profile_gate=profile_gate)
    stats, block = plan.stats, plan.block
    q_sketch = (
        ranking.query_sketch(index, plan.distinct_keys)
        if rank == "quality"
        else None
    )
    scores: dict[int, float] = {}
    full_lanes = plan.q_sk.shape[1]
    fl = full_lanes if filter_lanes is None else max(1, min(int(filter_lanes), full_lanes))
    stats.filter_lanes = fl
    q_f = plan.q_sk if fl == full_lanes else plan.q_sk[:, :fl]
    # routed index (core.routing.ShardedMateIndex): there IS no global
    # superkey array or single device store — the filter diverts to
    # shard-local counts-only launches and only count vectors cross shards.
    routed = getattr(index, "routed", False)
    # gather-fused: the engine decides per batch whether the device store
    # carries the gather (store fits + the batch is under the scatter-tile
    # cap), because only then may the host skip its own superkey gather.
    store = (
        index.device_store()
        if not routed and bk.gather and ops.gather_store_fits(index.superkeys)
        else None
    )
    topk = _TopK(k)
    n_tables = block.n_tables
    for start in range(0, n_tables, batch_tables):
        stop = min(start + batch_tables, n_tables)
        # rule 1 between batches: tables are PL-desc sorted, so if the FIRST
        # table of the batch is at/below the bound, everything after is too.
        # (PL lengths are CSR metadata the host already owns — no transfer.)
        first_count = int(block.table_ptr[start + 1] - block.table_ptr[start])
        if topk.full and first_count <= topk.bound():
            stats.tables_pruned_rule1 += n_tables - start
            break
        lo, hi = int(block.table_ptr[start]), int(block.table_ptr[stop])
        rows = block.rows[lo:hi]
        use_gather = store is not None and (stop - start) <= ops._FUSED_MAX_TABLES
        # the gather-fused contract: the host NEVER touches the candidate
        # superkeys — the kernel DMA-gathers them from the device store.
        # The routed contract is stricter still: the host never gathers a
        # WHOLE batch at all; surviving tables re-gather from their owning
        # shard in _score_tables (index.superkey_of_rows routes per shard).
        row_sk = (
            None if (use_gather or routed) else index.superkey_of_rows(rows)
        )
        row_f = (
            None if row_sk is None
            else row_sk if fl == full_lanes else row_sk[:, :fl]
        )
        elig = plan.elig[lo:hi]
        seg = _segment_ids(block.table_ptr, start, stop)
        stats.pl_items_checked += int(rows.shape[0])
        stats.filter_checks += int(elig.sum())
        if routed:
            # shard-local counts-only launches, count-merge across shards:
            # the only cross-shard bytes are stats.route_bytes_merged.
            hits = None
            counts = index.routed_counts(
                rows, q_f, elig, seg, stop - start,
                backend=bk, fused_block_n=fused_block_n, stats=stats,
            )
        elif use_gather:
            # one launch from posting-list offsets to counts: n×4 offset
            # bytes go to the device instead of n×lanes×4 gathered key bytes
            # (and the gathered block never exists in HBM either).
            hits, counts = ops.filter_hits_table_counts(
                None, q_f, elig, seg, stop - start, backend=bk,
                fused_block_n=fused_block_n, store=store, rows=rows,
            )
            stats.filter_fused_launches += 1
            stats.gather_bytes_saved += int(rows.shape[0]) * (fl * 4 - 4)
        elif bk.fused:
            # fused filter+segment-count launch: the match matrix is never
            # produced (zero filter_matrix_bytes), only the counts vector
            # comes back; surviving tables' slices are recomputed on demand
            # in _score_tables.  (ops falls back to the composed path above
            # its table cap — hits non-None — and stats must follow suit.)
            hits, counts = ops.filter_hits_table_counts(
                row_f, q_f, elig, seg, stop - start, backend=bk,
                fused_block_n=fused_block_n,
            )
            if hits is None:
                stats.filter_fused_launches += 1
            else:
                stats.filter_matrix_bytes += int(elig.size)
        elif bk.device and topk.full and topk.bound() > 0:
            # bound can prune → composed device launch: hits stay on device,
            # only the per-table counts vector is read back; surviving
            # tables' slices transfer lazily in _score_tables.
            stats.filter_matrix_bytes += int(elig.size)
            hits, counts = ops.filter_hits_table_counts(
                row_f, q_f, elig, seg, stop - start, backend=bk,
            )
        else:
            # heap not full (bound 0): nothing can be pruned, every hit
            # block is about to be verified — single-transfer path.
            stats.filter_matrix_bytes += int(elig.size)
            hits, counts = _hits_counts_host(
                row_f, q_f, elig, seg, stop - start, bk
            )
        # readback = match-matrix bytes materialised host-side: the whole
        # matrix when any path produced host hits (size-based numpy
        # dispatch included), else the counts vector now + surviving
        # slices lazily in _score_tables.
        if isinstance(hits, np.ndarray):
            stats.filter_readback_bytes += hits.size
        else:
            stats.filter_readback_bytes += counts.nbytes
        stats.filter_passed += int(counts.sum())
        if rank == "quality":
            batch_ids = block.table_ids[start:stop]
            sc = ranking.quality_scores(
                index, batch_ids, np.asarray(counts),
                len(plan.distinct_keys), q_sketch, stats=stats,
            )
            scores.update(zip(batch_ids.tolist(), sc.tolist()))
        _score_tables(
            index, plan, topk, hits, counts, rows, start, stop, lo,
            row_sk=row_sk, elig=elig, prefetch_frac=prefetch_frac,
        )
    return _ranked_entries(topk, rank, scores), stats


@dataclasses.dataclass
class PlanCounts:
    """Phase-A artifact of the two-phase group engine: one request's plan
    plus everything the shared filter launch produced for it — the seam the
    serving tier's hot-table bound cache stores (``serve.cache.BoundCache``).

    ``counts`` is the per-table eligible-hit count vector driving rule-1/2
    pruning; ``hits`` is this plan's slice of the group match matrix (None
    on the fused counts-only path, and always None once cached — see
    ``cacheable``); ``row_sk`` keeps the FULL-width row super keys so a
    dropped/absent matrix is recomputed lazily during scoring,
    bit-identically.  On the GATHER-fused launch ``row_sk`` is None too —
    the host never gathered the superkeys — and scoring gathers surviving
    tables' slices from the index store instead, which is why ``epoch``
    matters doubly there: the store read at scoring time must be the store
    the launch filtered against.  ``epoch`` pins ``MateIndex.mutation_epoch``
    at launch time: a PlanCounts is replayable only while the index is
    unchanged.
    """

    plan: QueryPlan
    row_sk: np.ndarray | None  # uint32[n_items, lanes] full-width row super
    # keys (None: gather-fused launch — scoring reads the index store)
    counts: np.ndarray  # int32[n_tables] per-table eligible-hit counts
    hits: object = None  # np/jnp [n_items, group_keys] slice, or None
    group_keys: int = 0  # key count of the SHARED launch (accounting)
    hits_host: bool = False  # group matrix came back host-side (np)
    fused: bool = False  # counts-only fused launch (no matrix anywhere)
    filter_lanes: int = 0  # lanes the launch probed (< index width: degraded)
    epoch: int = 0  # index.mutation_epoch at launch time
    gather_saved: int = 0  # HBM bytes the gather-fused launch never moved
    route_launches: int = 0  # routed index: shard launches this request's
    # items spanned (distinct owning shards — whole-table ownership means
    # each of its candidate tables was counted on exactly one of them)
    route_bytes: int = 0  # routed index: this request's share of the
    # cross-shard count-merge bytes (its counts vector × shards touched)

    def cacheable(self) -> "PlanCounts":
        """A copy safe to hold in a cache: the (possibly device-resident)
        match-matrix slice is dropped; scoring recomputes surviving tables'
        slices from ``row_sk`` on demand — same subsumption predicate, so
        verification inputs (and the top-k) are bit-identical."""
        return dataclasses.replace(self, hits=None)


def plan_and_count(
    index: MateIndex,
    queries: list[tuple[Table, list[int]]],
    backend: Backend | str | None = None,
    *,
    init_mode: str = "cardinality",
    filter_lanes: int | None = None,
    fused_block_n: int | None = None,
    profile_gate: bool = False,
) -> list[PlanCounts]:
    """Phase A of ``discover_many``: plan every request, then run the ONE
    shared filter launch and demux it into per-request ``PlanCounts``.

    ``profile_gate=True`` applies the column-profile gate per plan (see
    ``plan_query``) before the shared launch is assembled, so gated tables
    never contribute rows to the group matrix at all.

    Everything up to (and including) ``gather_candidates`` + the §6.3
    filter lives here; ``score_from_counts`` is phase B (pruning, exact
    verification, the heap).  The split is the serving tier's bound-cache
    seam: a hot query's ``PlanCounts`` can be stored and re-scored later —
    at a different ``k`` even — without touching the index or the device.

    ``filter_lanes`` restricts the launch to a lane prefix of the super
    keys (the pressure-degrade path, see ``discover_batched``): a pure
    relaxation — zero false negatives — so downstream verification still
    yields bit-identical top-k.
    """
    bk = registry.resolve_backend(backend)
    plans = [
        plan_query(index, q, q_cols, init_mode, profile_gate=profile_gate)
        for q, q_cols in queries
    ]
    if not plans:
        return []
    rows_all = np.concatenate([p.block.rows for p in plans])
    q_all = np.concatenate([p.q_sk for p in plans])
    # block-diagonal eligibility (a request's keys only probe its own
    # candidate rows) + a global per-item table index for the one-pass
    # per-table rule-1/2 count reduction.
    elig_all = np.zeros((rows_all.shape[0], q_all.shape[0]), dtype=bool)
    seg_all = np.zeros(rows_all.shape[0], dtype=np.int32)
    r_off = k_off = 0
    n_tables_all = 0
    for p in plans:
        ni, ki, ti = p.block.n_items, p.q_sk.shape[0], p.block.n_tables
        elig_all[r_off : r_off + ni, k_off : k_off + ki] = p.elig
        if ni:
            seg_all[r_off : r_off + ni] = n_tables_all + _segment_ids(
                p.block.table_ptr, 0, ti
            )
        r_off += ni
        k_off += ki
        n_tables_all += ti
    full_lanes = index.cfg.lanes
    fl = full_lanes if filter_lanes is None else max(1, min(int(filter_lanes), full_lanes))
    q_f = q_all if fl == full_lanes else q_all[:, :fl]
    routed = getattr(index, "routed", False)
    use_gather = (
        not routed
        and bk.gather
        and ops.gather_store_fits(index.superkeys)
        and n_tables_all <= ops._FUSED_MAX_TABLES
    )
    # gather-fused group launch: no host superkey gather at all — the kernel
    # pulls every request's candidate rows from the device store, and phase B
    # re-gathers only surviving tables' slices (bit-identical: same array).
    # The routed group launch shares that contract (row_sk stays None) and
    # scoring re-gathers from the OWNING shard only.
    row_sk_all = (
        None if (use_gather or routed) else index.superkey_of_rows(rows_all)
    )
    row_f = (
        None if row_sk_all is None
        else row_sk_all if fl == full_lanes else row_sk_all[:, :fl]
    )
    if routed:
        # shard-local counts-only launches for the whole group; per-request
        # routing accounting is attributed below from each plan's own items.
        hits_all = None
        counts_all = index.routed_counts(
            rows_all, q_f, elig_all, seg_all, n_tables_all,
            backend=bk, fused_block_n=fused_block_n,
        )
    elif use_gather:
        hits_all, counts_all = ops.filter_hits_table_counts(
            None, q_f, elig_all, seg_all, n_tables_all,
            backend=bk, fused_block_n=fused_block_n,
            store=index.device_store(), rows=rows_all,
        )
    elif bk.fused:
        # ONE fused filter+segment-count launch for the whole group: the
        # (Σ rows × Σ keys) matrix is never materialised; only the group
        # counts vector is read back.  Surviving tables recompute their
        # own-keys hit slices lazily in _score_tables (bit-identical to
        # slicing the block-diagonal of the full matrix, since elig
        # already restricts each row to its own request's keys).
        hits_all, counts_all = ops.filter_hits_table_counts(
            row_f, q_f, elig_all, seg_all, n_tables_all,
            backend=bk, fused_block_n=fused_block_n,
        )
    else:
        # ONE subsumption launch for the whole group.  Unlike
        # ``discover_batched`` (whose later batches are often pruned
        # without any matrix transfer), every request here starts with an
        # empty heap (entry bound 0), so most plans' hit blocks are
        # needed for verification — the matrix comes back to the host in
        # one transfer and the per-table rule-1/2 counts are a cheap
        # host reduction over it.
        hits_all, counts_all = _hits_counts_host(
            row_f, q_f, elig_all, seg_all, n_tables_all, bk,
        )
    epoch = index.mutation_epoch
    out: list[PlanCounts] = []
    r_off = k_off = t_off = 0
    for p in plans:
        ni, ki, ti = p.block.n_items, p.q_sk.shape[0], p.block.n_tables
        # routed attribution: the shards THIS request's items spanned — its
        # solo cost, and (by whole-table ownership) exactly the shards that
        # produced its slice of the group counts vector.
        n_sh = (
            len(np.unique(index._shard_ids_of_rows(p.block.rows)))
            if routed and ni
            else 0
        )
        out.append(
            PlanCounts(
                plan=p,
                row_sk=(
                    None if row_sk_all is None
                    else row_sk_all[r_off : r_off + ni]
                ),
                counts=counts_all[t_off : t_off + ti],
                hits=None if hits_all is None
                else hits_all[r_off : r_off + ni, k_off : k_off + ki],
                group_keys=0 if hits_all is None else int(hits_all.shape[1]),
                hits_host=isinstance(hits_all, np.ndarray),
                fused=hits_all is None,
                filter_lanes=fl,
                epoch=epoch,
                gather_saved=ni * (fl * 4 - 4) if use_gather else 0,
                route_launches=n_sh,
                route_bytes=n_sh * ti * 4,
            )
        )
        r_off += ni
        k_off += ki
        t_off += ti
    return out


def score_from_counts(
    index: MateIndex,
    pc: PlanCounts,
    k: int = 10,
    *,
    prefetch_frac: float = _PREFETCH_FRAC,
    from_cache: bool = False,
    rank: str = "count",
) -> tuple[list[TopKEntry], DiscoveryStats]:
    """Phase B of ``discover_many``: rule-1/2 pruning + exact verification
    + the top-k heap over one request's ``PlanCounts``.

    ``rank='quality'`` runs ONE scoring launch over the plan's full counts
    vector (phase A already produced it — no extra filter work) and orders
    the returned entries by join quality; the heap itself is untouched, so
    cached replays at either rank verify the same set.

    Re-runnable: stats land on a FRESH copy of the plan's, so the same
    PlanCounts (a bound-cache hit) can be scored any number of times — at
    any ``k``.  ``from_cache=True`` skips the launch-transfer accounting
    (an earlier request already paid for the filter) and forces the
    lazy-recompute path, since cached entries hold no matrix slice.
    """
    plan = dataclasses.replace(pc.plan, stats=dataclasses.replace(pc.plan.stats))
    stats, block = plan.stats, plan.block
    n_items = block.n_items
    stats.pl_items_checked = n_items
    stats.filter_checks = int(plan.elig.sum())
    stats.filter_passed = int(pc.counts.sum())
    stats.filter_lanes = pc.filter_lanes
    hits = pc.hits
    if from_cache:
        hits = None
    elif pc.fused:  # fused counts-only group launch succeeded
        stats.filter_fused_launches += 1
        stats.filter_readback_bytes += pc.counts.nbytes
        stats.gather_bytes_saved += pc.gather_saved
        stats.shard_launches += pc.route_launches
        stats.route_bytes_merged += pc.route_bytes
    else:
        # the shared launch computes (and reads back) this plan's rows
        # against the GROUP's keys — the documented cross-product trade.
        # (device-resident hits — the fused→composed table-cap fallback —
        # transfer lazily in _score_tables, which does its own readback
        # accounting.)
        stats.filter_matrix_bytes += n_items * pc.group_keys
        if pc.hits_host:
            stats.filter_readback_bytes += n_items * pc.group_keys
    scores: dict[int, float] = {}
    if rank == "quality" and block.n_tables:
        q_sketch = ranking.query_sketch(index, plan.distinct_keys)
        sc = ranking.quality_scores(
            index, block.table_ids, np.asarray(pc.counts),
            len(plan.distinct_keys), q_sketch, stats=stats,
        )
        scores = dict(zip(block.table_ids.tolist(), sc.tolist()))
    topk = _TopK(k)
    # rule 1 (PL-desc suffix pruning) applies inside the range: the filter
    # already ran batched for every table, only verification work and
    # hit-slice readbacks (or fused recomputes) remain to be skipped.
    _score_tables(
        index, plan, topk, hits, pc.counts, block.rows, 0, block.n_tables, 0,
        rule1=True, row_sk=pc.row_sk, elig=plan.elig,
        prefetch_frac=prefetch_frac,
    )
    return _ranked_entries(topk, rank, scores), stats


def discover_many(
    index: MateIndex,
    queries: list[tuple[Table, list[int]]],
    k: int | list[int] = 10,
    init_mode: str = "cardinality",
    backend: Backend | str | None = None,
    *,
    prefetch_frac: float = _PREFETCH_FRAC,
    fused_block_n: int | None = None,
    filter_lanes: int | None = None,
    rank: str = "count",
    profile_gate: bool = False,
) -> list[tuple[list[TopKEntry], DiscoveryStats]]:
    """Multi-query discovery sharing ONE filter launch.

    ``rank``/``profile_gate`` thread through both phases (see
    ``plan_and_count`` and ``score_from_counts``): the gate shrinks each
    request's candidate block before the shared launch, quality ranking
    adds one scoring launch per request — neither changes the verified set.

    All requests' candidate rows and query keys concatenate into a single
    subsumption launch; the match matrix is then demuxed per request and
    scored with the same rule-1/rule-2 + heap semantics, so each request's
    top-k is bit-identical to its solo ``discover``/``discover_batched`` run.
    Internally this is ``plan_and_count`` (phase A: the shared launch)
    composed with ``score_from_counts`` (phase B: per-request scoring) —
    the seam the serving tier's caches plug into.

    ``backend`` resolves exactly as in ``discover_batched`` (and the removed
    ``use_kernel=``/``fused=`` kwargs raise TypeError here too).  A 'fused'
    backend swaps the group launch for the fused filter+segment-count kernel: the
    (Σ rows × Σ keys) match matrix — the expensive part of the cross-product
    trade below — is never materialised; only the group counts vector comes
    back, and each request's surviving tables recompute their (own-keys-only)
    hit slices on demand during scoring.  Requests pruned by the evolving
    rule-1/2 bounds never pay for their matrix block at all.

    Cost note: the shared launch computes the full (Σ rows × Σ keys) cross
    product — only the block diagonal is consumed, so filter work grows
    ~linearly with group size beyond the useful probes.  That trade buys one
    kernel dispatch for the whole group, which wins while dispatch latency
    dominates (small/medium groups, accelerator backends); keep serving
    groups bounded (``DiscoveryEngine(batch=...)``, default 8) rather than
    fusing unbounded request sets.
    """
    ks = [k] * len(queries) if isinstance(k, int) else list(k)
    assert len(ks) == len(queries)
    pcs = plan_and_count(
        index, queries, backend,
        init_mode=init_mode, filter_lanes=filter_lanes,
        fused_block_n=fused_block_n, profile_gate=profile_gate,
    )
    return [
        score_from_counts(
            index, pc, k_i, prefetch_frac=prefetch_frac, rank=rank
        )
        for pc, k_i in zip(pcs, ks)
    ]


def filter_outcomes(
    index: MateIndex,
    query: Table,
    q_cols: list[int],
    init_mode: str = "cardinality",
    check_false_negatives: bool = False,
) -> dict[str, int]:
    """Unpruned §6.3 filter quality for one query — the paper's Table 1/2
    false-positive measurement at whatever hash width the index was built at.

    Every eligible (candidate row, query key) pair is probed through the
    super-key filter and every surviving pair is verified exactly; no top-k
    pruning interferes, so counts are a property of the hash alone.

    Returns counts: ``checks`` (eligible probes), ``passed`` (filter
    survivors), ``tp`` / ``fp`` (survivors that pass / fail exact key
    comparison), and — when ``check_false_negatives`` — ``fn``: eligible
    pairs that verify exactly but were REJECTED by the filter (always 0 for
    any OR-aggregated hash; the §6.3 no-false-negative lemma).
    """
    plan = plan_query(index, query, q_cols, init_mode)
    out = {
        "checks": int(plan.elig.sum()),
        "passed": 0,
        "tp": 0,
        "fp": 0,
        "fn": 0,
        "items": plan.block.n_items,
        "keys": len(plan.distinct_keys),
    }
    if plan.block.n_items == 0 or not plan.distinct_keys:
        return out
    row_sk = index.superkey_of_rows(plan.block.rows)
    hits = ops.subsume_np(row_sk, plan.q_sk) & plan.elig
    out["passed"] = int(hits.sum())
    corpus = index.corpus
    row_values_cache: dict[int, list[str]] = {}

    def _matches(r: int, kid: int) -> bool:
        grow = int(plan.block.rows[r])
        vals = row_values_cache.get(grow)
        if vals is None:
            vals = row_values_cache[grow] = corpus.row_values(grow)
        return bool(seq._verify_pair(plan.distinct_keys[kid], vals))

    for r, kid in zip(*np.nonzero(hits)):
        if _matches(int(r), int(kid)):
            out["tp"] += 1
        else:
            out["fp"] += 1
    if check_false_negatives:
        for r, kid in zip(*np.nonzero(plan.elig & ~hits)):
            if _matches(int(r), int(kid)):
                out["fn"] += 1
    return out
