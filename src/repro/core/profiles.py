"""Per-column profiles for ranked discovery (ROADMAP item 3).

Offline, every table gets a compact profile computed straight from the
corpus arenas and the already-hashed unique-value lanes:

  * **presence masks** — a Bloom-style bitmask over the table's distinct
    value hashes plus occupied value-length-bucket / char-class bitmasks.
    A bitmask can prove *absence* (no false negatives): if a query value's
    probe bits are not all set, that value appears nowhere in the table.
    That is what makes the pre-index gate sound (pure pruning).
  * **cardinality** — distinct-value count per column and the per-table
    max, the cheap join-quality signal of "Measuring and Predicting the
    Quality of a Join": a candidate column whose cardinality approaches
    its row count joins key-like (low multiplicity).
  * **min-hash sketch** — ``SKETCH_K`` minima of salted value hashes over
    the table's distinct values; matching positions against a query-side
    sketch estimate value-set Jaccard for the scoring head.

Profiles are built per contiguous table range so the sharded offline
build produces byte-identical stores to the single-host pass (same
contract as the postings merge), and ``ShardedMateIndex`` keeps one
store per shard, epoch-pinned like the device superkey store.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.corpus import Corpus

LEN_BUCKETS = 16  # value length, clipped into bucket min(len, 15)
N_CLASSES = 4  # 0=digits-only 1=alpha-only 2=other-alnum 3=mixed/other
SKETCH_K = 16  # min-hash lanes per sketch
MASK_WORDS = 8  # 256-bit per-table value-presence mask
MASK_BITS = MASK_WORDS * 32
N_PROBES = 2  # Bloom probes per value

# Deterministic salt streams for the sketch lanes (odd multipliers so the
# maps are bijections on uint32 — minima stay uniformly distributed).
_SKETCH_MULT = (
    np.uint32(2654435761) * (2 * np.arange(SKETCH_K, dtype=np.uint32) + 1)
)
_SKETCH_ADD = np.uint32(0x9E3779B9) * np.arange(SKETCH_K, dtype=np.uint32)
_EMPTY_SKETCH = np.uint32(0xFFFFFFFF)


@dataclasses.dataclass
class ProfileStore:
    """Column/table profiles for tables ``[table_lo, table_hi)``.

    Per-column arrays are CSR-packed by ``col_ptr`` (one entry per table
    column, tables in id order) so shard stores concatenate into exactly
    the single-host store.
    """

    table_lo: int
    table_hi: int
    epoch: int  # mutation epoch the store was built at
    # per-table
    mask: np.ndarray  # uint32[n_tables, MASK_WORDS] value-presence Bloom
    len_mask: np.ndarray  # uint32[n_tables] occupied length buckets
    class_mask: np.ndarray  # uint32[n_tables] occupied char classes
    n_rows: np.ndarray  # int32[n_tables]
    n_cols: np.ndarray  # int32[n_tables]
    card_max: np.ndarray  # int32[n_tables] max column cardinality
    sketch: np.ndarray  # uint32[n_tables, SKETCH_K] min-hash over values
    # per-column (CSR by col_ptr)
    col_ptr: np.ndarray  # int64[n_tables + 1]
    col_cardinality: np.ndarray  # int32[total_cols]
    col_len_hist: np.ndarray  # int32[total_cols, LEN_BUCKETS]
    col_class_hist: np.ndarray  # int32[total_cols, N_CLASSES]

    @property
    def n_tables(self) -> int:
        return self.table_hi - self.table_lo

    @property
    def nbytes(self) -> int:
        return sum(
            getattr(self, f.name).nbytes
            for f in dataclasses.fields(self)
            if isinstance(getattr(self, f.name), np.ndarray)
        )


def value_class(value: str) -> int:
    """Char-class bucket of a value (necessary-condition signature: a value
    present in a table must have its class bit set in the table mask)."""
    if value.isdigit():
        return 0
    if value.isalpha():
        return 1
    if value.isalnum():
        return 2
    return 3


def value_signatures(
    values: list[str], lanes: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-value (probe bit positions, length bucket, char class).

    ``lanes`` must come from the SAME hash function that produced the
    store's ``value_lanes`` (``MateIndex.hash_values``) — equal strings
    then probe exactly the bits the build set, which is the no-false-
    negative property the gate's soundness rests on.
    """
    n = len(values)
    len_bucket = np.fromiter(
        (min(len(v), LEN_BUCKETS - 1) for v in values), dtype=np.int64, count=n
    )
    vclass = np.fromiter(
        (value_class(v) for v in values), dtype=np.int64, count=n
    )
    probe = _probe_positions(lanes)
    return probe, len_bucket, vclass


def _probe_positions(lanes: np.ndarray) -> np.ndarray:
    """Double-hashed Bloom probe positions: int64[n_values, N_PROBES]."""
    h1 = lanes[:, 0].astype(np.uint32)
    h2 = lanes[:, 1].astype(np.uint32) | np.uint32(1)
    k = np.arange(N_PROBES, dtype=np.uint32)
    return ((h1[:, None] + k[None, :] * h2[:, None]) % MASK_BITS).astype(
        np.int64
    )


def value_sketch(lane0: np.ndarray) -> np.ndarray:
    """Min-hash sketch of a value set from its lane-0 hashes: uint32[K]."""
    if lane0.shape[0] == 0:
        return np.full(SKETCH_K, _EMPTY_SKETCH, dtype=np.uint32)
    h = lane0.astype(np.uint32)[:, None] * _SKETCH_MULT[None, :]
    h = h + _SKETCH_ADD[None, :]
    return h.min(axis=0)


def build_profiles(
    corpus: Corpus,
    value_lanes: np.ndarray,
    table_lo: int = 0,
    table_hi: int | None = None,
    epoch: int = 0,
) -> ProfileStore:
    """Profile tables ``[table_lo, table_hi)`` from the corpus arenas.

    Everything derives from per-unique-value metadata (length bucket,
    char class, probe bits, sketch salts) gathered through
    ``cell_value_ids`` — no per-table Python loops, and no dependence on
    how the caller shards the table range (concatenating shard stores is
    byte-identical to one full-range build).
    """
    rb = corpus.row_base
    n_total = len(rb) - 1
    if table_hi is None:
        table_hi = n_total
    nt = table_hi - table_lo
    max_cols = corpus.max_cols

    # -- per-unique-value metadata (shared by every table range) ------------
    vals = corpus.unique_values
    nv = len(vals)
    len_bucket = np.fromiter(
        (min(len(v), LEN_BUCKETS - 1) for v in vals), dtype=np.int64, count=nv
    )
    vclass = np.fromiter(
        (value_class(v) for v in vals), dtype=np.int64, count=nv
    )
    probe = _probe_positions(value_lanes) if nv else np.zeros(
        (0, N_PROBES), dtype=np.int64
    )

    n_cols = corpus.n_cols[table_lo:table_hi].astype(np.int32)
    n_rows = (rb[table_lo + 1 : table_hi + 1] - rb[table_lo:table_hi]).astype(
        np.int32
    )
    col_ptr = np.zeros(nt + 1, dtype=np.int64)
    np.cumsum(n_cols, out=col_ptr[1:])
    total_cols = int(col_ptr[-1])

    mask = np.zeros((nt, MASK_WORDS), dtype=np.uint32)
    len_mask = np.zeros(nt, dtype=np.uint32)
    class_mask = np.zeros(nt, dtype=np.uint32)
    card_max = np.zeros(nt, dtype=np.int32)
    sketch = np.full((nt, SKETCH_K), _EMPTY_SKETCH, dtype=np.uint32)
    col_cardinality = np.zeros(total_cols, dtype=np.int32)
    col_len_hist = np.zeros((total_cols, LEN_BUCKETS), dtype=np.int32)
    col_class_hist = np.zeros((total_cols, N_CLASSES), dtype=np.int32)

    row_lo, row_hi = int(rb[table_lo]), int(rb[table_hi])
    ids = corpus.cell_value_ids[row_lo:row_hi]
    rel_rows, cols = np.nonzero(ids >= 0)
    if rel_rows.shape[0]:
        vids = ids[rel_rows, cols].astype(np.int64)
        tids = (
            np.searchsorted(rb, rel_rows + row_lo, side="right") - 1 - table_lo
        )

        # distinct (table, column, value) triples -> per-column stats
        colkey = (tids * max_cols + cols).astype(np.int64)
        upair = np.unique((colkey << 32) | vids)
        p_vid = upair & np.int64(0xFFFFFFFF)
        p_col = upair >> 32
        p_tid = p_col // max_cols
        col_idx = col_ptr[p_tid] + (p_col % max_cols)
        np.add.at(col_cardinality, col_idx, 1)
        np.add.at(col_len_hist, (col_idx, len_bucket[p_vid]), 1)
        np.add.at(col_class_hist, (col_idx, vclass[p_vid]), 1)
        np.maximum.at(card_max, p_tid, col_cardinality[col_idx])

        # distinct (table, value) pairs -> presence masks + sketch
        utv = np.unique((tids << 32) | vids)
        t_vid = utv & np.int64(0xFFFFFFFF)
        t_tid = utv >> 32
        one = np.uint32(1)
        for p in range(N_PROBES):
            pos = probe[t_vid, p]
            np.bitwise_or.at(
                mask,
                (t_tid, pos // 32),
                np.left_shift(one, (pos % 32).astype(np.uint32)),
            )
        np.bitwise_or.at(
            len_mask, t_tid, np.left_shift(one, len_bucket[t_vid].astype(np.uint32))
        )
        np.bitwise_or.at(
            class_mask, t_tid, np.left_shift(one, vclass[t_vid].astype(np.uint32))
        )
        h1 = value_lanes[t_vid, 0].astype(np.uint32)
        for k in range(SKETCH_K):
            np.minimum.at(
                sketch[:, k], t_tid, h1 * _SKETCH_MULT[k] + _SKETCH_ADD[k]
            )

    return ProfileStore(
        table_lo=table_lo,
        table_hi=table_hi,
        epoch=epoch,
        mask=mask,
        len_mask=len_mask,
        class_mask=class_mask,
        n_rows=n_rows,
        n_cols=n_cols,
        card_max=card_max,
        sketch=sketch,
        col_ptr=col_ptr,
        col_cardinality=col_cardinality,
        col_len_hist=col_len_hist,
        col_class_hist=col_class_hist,
    )


def merge_profiles(parts: list[ProfileStore], epoch: int = 0) -> ProfileStore:
    """Concatenate contiguous shard stores into one full-range store.

    Deterministic by construction — every array is per-table or CSR over
    tables, so this is pure concatenation (the sharded-build analogue of
    ``merge_shard_postings``).
    """
    assert parts, "merge_profiles needs at least one shard store"
    for a, b in zip(parts, parts[1:]):
        assert a.table_hi == b.table_lo, "shard stores must be contiguous"
    col_ptr = parts[0].col_ptr
    for p in parts[1:]:
        col_ptr = np.concatenate([col_ptr, p.col_ptr[1:] + col_ptr[-1]])
    cat = lambda name: np.concatenate([getattr(p, name) for p in parts])
    return ProfileStore(
        table_lo=parts[0].table_lo,
        table_hi=parts[-1].table_hi,
        epoch=epoch,
        mask=cat("mask"),
        len_mask=cat("len_mask"),
        class_mask=cat("class_mask"),
        n_rows=cat("n_rows"),
        n_cols=cat("n_cols"),
        card_max=cat("card_max"),
        sketch=cat("sketch"),
        col_ptr=col_ptr,
        col_cardinality=cat("col_cardinality"),
        col_len_hist=cat("col_len_hist"),
        col_class_hist=cat("col_class_hist"),
    )


def profiles_equal(a: ProfileStore, b: ProfileStore) -> bool:
    """Byte-level store equality (the determinism contract's definition)."""
    return all(
        np.array_equal(getattr(a, f.name), getattr(b, f.name))
        and getattr(a, f.name).dtype == getattr(b, f.name).dtype
        for f in dataclasses.fields(a)
        if isinstance(getattr(a, f.name), np.ndarray)
    ) and (a.table_lo, a.table_hi) == (b.table_lo, b.table_hi)


def gate_tables(
    store: ProfileStore,
    local_ids: np.ndarray,
    key_value_idx: np.ndarray,
    probe: np.ndarray,
    len_bucket: np.ndarray,
    vclass: np.ndarray,
    width: int,
) -> np.ndarray:
    """bool[n] — False iff the table PROVABLY cannot join any query key.

    A key (v_1..v_w) matching a row of table T requires every v_i to be
    present in T in one of w distinct columns, so three necessary
    conditions gate T: (1) T has >= w columns; (2) every v_i's Bloom
    probe bits are set in T's presence mask; (3) every v_i's length
    bucket and char class are occupied somewhere in T.  Each is exact on
    the negative side (the build set every bit for every present value),
    so a False here means joinability 0 — dropping the table cannot
    change the verified top-k (pure pruning).  ``local_ids`` are
    store-relative (``table_id - store.table_lo``).
    """
    if local_ids.shape[0] == 0:
        return np.zeros(0, dtype=bool)
    m = store.mask[local_ids]  # [T, MASK_WORDS]
    # [T, V, P]: probe bit p of value v present in table t
    present = (m[:, probe // 32] >> (probe % 32).astype(np.uint32)) & 1
    ok_value = present.all(axis=2).astype(bool)
    ok_value &= (
        (store.len_mask[local_ids][:, None] >> len_bucket[None, :]) & 1
    ).astype(bool)
    ok_value &= (
        (store.class_mask[local_ids][:, None] >> vclass[None, :]) & 1
    ).astype(bool)
    keep = ok_value[:, key_value_idx].all(axis=2).any(axis=1)
    keep &= store.n_cols[local_ids] >= width
    return keep


def query_gate_inputs(
    distinct_keys: list[tuple[str, ...]], hash_fn
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Precompute the query side of ``gate_tables`` once per plan.

    Returns ``(key_value_idx[int64, n_keys, width], probe, len_bucket,
    vclass)`` over the deduplicated key-value vocabulary; ``hash_fn`` is
    the owning index's ``hash_values``.
    """
    uniq = list(dict.fromkeys(v for key in distinct_keys for v in key))
    probe, len_bucket, vclass = value_signatures(uniq, hash_fn(uniq))
    vidx = {v: i for i, v in enumerate(uniq)}
    key_value_idx = np.array(
        [[vidx[v] for v in key] for key in distinct_keys], dtype=np.int64
    )
    return key_value_idx, probe, len_bucket, vclass
