"""Multi-table FD discovery on the shared super-key index (ROADMAP item 4).

The workload: given a query relation Q and a candidate functional dependency
``determinant_cols → dependent_col`` over Q's columns, report — for every
lake table T that joins Q on the determinant key set — whether the FD also
holds on the (never materialized) join Q ⋈ T.  A determinant group breaks
the FD in the join exactly when (a) it maps to more than one dependent value
among Q's rows AND (b) the group's key actually matches a row of T; so the
per-table verdict needs only Q's group→dependent-values map (host-side,
tiny) plus the set of determinant keys matched in T — which is precisely
what the existing §6.3 machinery computes.

Two phases, both reused from ``core.batched``:

  A. ``plan_and_count`` runs the ONE fused gather-filter launch for the
     determinant key set and returns per-table eligible-hit counts.  The
     filter has no false negatives (§6.3 lemma), so the count is an UPPER
     bound on true matched pairs: ``counts < min_support`` proves true
     support is below the bar — counts-as-refutation, exact on the negative
     side.  Refuted tables are pruned before any superkey byte moves.
  B. Survivors re-gather their candidate rows' super keys (epoch-pinned;
     on the routed lake ``ShardedMateIndex.superkey_of_rows`` pulls each
     row from its OWNING shard) and every filter-surviving (row, key) pair
     is verified exactly (``discovery._verify_pair``), yielding the matched
     determinant-key set, the support, and the violation count.

No join is ever materialized: the only per-table state is a counts scalar
(phase A) and the matched-key set (phase B).

Multi-signal mode (PAPERS.md: "Measuring and Predicting the Quality of a
Join for Data Discovery"; SNIPPETS.md snippet 1): XASH joinability becomes
one signal in a weighted ensemble with the PR 9 profile features —
uniqueness (card_max/n_rows), min-hash sketch similarity, and table-name
token overlap.  Signals only SCORE and reorder candidates; the reported
support/holds/violations facts are identical with signals off.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import batched as batched_lib
from repro.core import discovery as seq
from repro.core import profiles, ranking
from repro.core.corpus import Table
from repro.core.discovery import DiscoveryStats
from repro.kernels import ops, registry
from repro.kernels.registry import Backend

# the multi-signal ensemble's vocabulary (DiscoveryConfig(signals=...) and
# the --fd-signals launch flag validate against this):
#   joinability — matched determinant keys / distinct query keys (the XASH
#                 instance-level signal, from phase B's exact support)
#   uniqueness  — max column cardinality / rows (profile store): high means
#                 the matched column looks like a key on the lake side too
#   sketch      — min-hash sketch positions shared with the query's key
#                 values / SKETCH_K (containment beyond the matched keys)
#   name        — token Jaccard of the lowercased table names (the schema-
#                 level signal of SNIPPETS.md snippet 1)
SIGNAL_NAMES = ("joinability", "uniqueness", "sketch", "name")

# launch-facing default: joinability dominates, profile signals break ties
DEFAULT_SIGNALS = (
    ("joinability", 0.5),
    ("uniqueness", 0.2),
    ("sketch", 0.2),
    ("name", 0.1),
)


@dataclasses.dataclass
class FDCandidate:
    """Per-table verdict for one candidate FD on the virtual join Q ⋈ T."""

    table_id: int
    support: int  # distinct determinant keys exactly matched in the table
    holds: bool  # every matched determinant group maps to ONE dependent value
    violations: int  # matched groups with >1 dependent value among Q's rows
    score: float | None = None  # multi-signal ensemble score (signals mode
    # only; never changes support/holds — ordering/annotation, like
    # TopKEntry.quality)


def dependent_groups(
    query: Table, determinant_cols: list[int], dependent_col: int
) -> dict[tuple, set]:
    """Determinant key → set of dependent values among the query's rows.

    Duplicate rows collapse naturally (sets); a group holding the FD on Q
    itself has a singleton value set, and a table preserves the FD on the
    join iff none of its MATCHED groups has a larger one.
    """
    out: dict[tuple, set] = {}
    for row in query.cells:
        key = tuple(row[c] for c in determinant_cols)
        out.setdefault(key, set()).add(row[dependent_col])
    return out


def discover_fds(
    index,
    query: Table,
    determinant_cols: list[int],
    dependent_col: int,
    *,
    min_support: int = 1,
    backend: Backend | str | None = None,
    init_mode: str = "cardinality",
    profile_gate: bool = False,
    signals: tuple[tuple[str, float], ...] | None = None,
    fused_block_n: int | None = None,
) -> tuple[list[FDCandidate], DiscoveryStats]:
    """Phase A + phase B in one call (the session/launch entry point).

    Returns the per-table FD verdicts for tables with exact support ≥
    ``min_support`` (default order: -support, table_id; ``signals`` reorders
    by ensemble score) plus a ``DiscoveryStats`` whose ``fd_candidates`` /
    ``fd_validated`` / ``fd_bytes_verified`` counters prove the prune.
    """
    if dependent_col in determinant_cols:
        raise ValueError(
            f"dependent_col {dependent_col} is one of the determinant "
            f"columns {determinant_cols} — the FD would be trivial"
        )
    bk = registry.resolve_backend(backend)
    [pc] = batched_lib.plan_and_count(
        index,
        [(query, list(determinant_cols))],
        bk,
        init_mode=init_mode,
        fused_block_n=fused_block_n,
        profile_gate=profile_gate,
    )
    return fds_from_counts(
        index,
        pc,
        dependent_col,
        min_support=min_support,
        signals=signals,
    )


def fds_from_counts(
    index,
    pc: "batched_lib.PlanCounts",
    dependent_col: int,
    *,
    min_support: int = 1,
    signals: tuple[tuple[str, float], ...] | None = None,
) -> tuple[list[FDCandidate], DiscoveryStats]:
    """Phase B: count-prune + exact validation over one ``PlanCounts``.

    Split out (mirroring ``score_from_counts``) so the launch can be shared
    or cached upstream.  Stats land on a FRESH copy of the plan's, with the
    same launch-transfer attribution as joinability scoring.  The re-gather
    is epoch-pinned: an index mutated since the launch raises instead of
    validating against rows the filter never saw.
    """
    plan = dataclasses.replace(pc.plan, stats=dataclasses.replace(pc.plan.stats))
    stats, block = plan.stats, plan.block
    query, det_cols = plan.query, plan.q_cols
    n_items = block.n_items
    stats.pl_items_checked = n_items
    stats.filter_checks = int(plan.elig.sum())
    stats.filter_passed = int(pc.counts.sum())
    stats.filter_lanes = pc.filter_lanes
    if pc.fused:
        stats.filter_fused_launches += 1
        stats.filter_readback_bytes += pc.counts.nbytes
        stats.gather_bytes_saved += pc.gather_saved
        stats.shard_launches += pc.route_launches
        stats.route_bytes_merged += pc.route_bytes
    else:
        stats.filter_matrix_bytes += n_items * pc.group_keys
        if pc.hits_host:
            stats.filter_readback_bytes += n_items * pc.group_keys
    stats.fd_candidates = block.n_tables
    if pc.epoch != index.mutation_epoch:
        raise ValueError(
            f"stale PlanCounts: index mutated since the filter launch "
            f"(epoch {pc.epoch} -> {index.mutation_epoch}) — the validation "
            f"re-gather would read rows the filter never probed"
        )
    dep_of_key = dependent_groups(query, det_cols, dependent_col)
    corpus = index.corpus
    counts = np.asarray(pc.counts)
    ptr = block.table_ptr
    out: list[FDCandidate] = []
    for t in range(block.n_tables):
        # counts-as-refutation: the filter count upper-bounds true matched
        # pairs (≥ distinct matched keys), so a count below min_support
        # PROVES the table's support is too — pruned without any re-gather.
        if int(counts[t]) < min_support:
            continue
        stats.fd_validated += 1
        lo, hi = int(ptr[t]), int(ptr[t + 1])
        rows = block.rows[lo:hi]
        # full-width re-gather (row_sk keeps full width even on degraded
        # launches); gather-fused/routed launches left row_sk None — pull
        # the slices from the index store / owning shard, epoch-pinned above.
        rsk = (
            pc.row_sk[lo:hi]
            if pc.row_sk is not None
            else index.superkey_of_rows(rows)
        )
        stats.fd_bytes_verified += int(rsk.nbytes)
        sub = ops.subsume_np(rsk, plan.q_sk) & plan.elig[lo:hi]
        matched: set = set()
        for r, kid in zip(*np.nonzero(sub)):
            key = plan.distinct_keys[int(kid)]
            if key in matched:
                continue
            if seq._verify_pair(key, corpus.row_values(int(rows[int(r)]))):
                stats.verified_tp += 1
                matched.add(key)
            else:
                stats.verified_fp += 1
        support = len(matched)
        if support < min_support:
            continue
        violations = sum(1 for key in matched if len(dep_of_key[key]) > 1)
        out.append(
            FDCandidate(
                table_id=int(block.table_ids[t]),
                support=support,
                holds=violations == 0,
                violations=violations,
            )
        )
    if signals is not None and out:
        _ensemble_scores(index, plan, out, signals)
        out.sort(key=lambda c: (-c.score, -c.support, c.table_id))
    else:
        out.sort(key=lambda c: (-c.support, c.table_id))
    return out, stats


def _name_tokens(name: str) -> frozenset:
    return frozenset(
        tok for tok in "".join(
            ch if ch.isalnum() else " " for ch in name.lower()
        ).split() if tok
    )


def _token_jaccard(a: frozenset, b: frozenset) -> float:
    if not a or not b:
        return 0.0
    return len(a & b) / len(a | b)


def _ensemble_scores(
    index,
    plan: "batched_lib.QueryPlan",
    fds: list[FDCandidate],
    signals: tuple[tuple[str, float], ...],
) -> None:
    """Annotate each candidate with its weighted multi-signal score.

    Pure host arithmetic over the exact support (phase B) and the profile
    store's features — deterministic and backend-independent, so the
    conformance suite can assert scored orderings bit-identical too.
    """
    w = dict(signals)
    unknown = set(w) - set(SIGNAL_NAMES)
    if unknown:
        raise ValueError(f"unknown signals {sorted(unknown)}; valid: {SIGNAL_NAMES}")
    n_keys = max(len(plan.distinct_keys), 1)
    tids = np.asarray([c.table_id for c in fds], dtype=np.int64)
    card_max, n_rows, sketch = index.profile_features(tids)
    q_sketch = ranking.query_sketch(index, plan.distinct_keys)
    sketch_sim = (
        (sketch == q_sketch[None, :]).sum(axis=1).astype(np.float64)
        / profiles.SKETCH_K
    )
    uniqueness = card_max.astype(np.float64) / np.maximum(n_rows, 1)
    q_tokens = _name_tokens(plan.query.name)
    tables = index.corpus.tables
    for i, cand in enumerate(fds):
        score = (
            w.get("joinability", 0.0) * (cand.support / n_keys)
            + w.get("uniqueness", 0.0) * float(uniqueness[i])
            + w.get("sketch", 0.0) * float(sketch_sim[i])
            + w.get("name", 0.0)
            * _token_jaccard(q_tokens, _name_tokens(tables[cand.table_id].name))
        )
        cand.score = float(score)
