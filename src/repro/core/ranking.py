"""Join-quality scoring head for ranked discovery (ROADMAP item 3).

MATE's engines return the verified top-k by exact joinability — how many
distinct query keys a table matches.  That says nothing about how USEFUL
the join is: a table matching every key once per key through a key-like
column beats one matching the same keys through a low-cardinality column
that would fan every query row out into dozens of join partners.  The
scoring head turns signals the pipeline already owns into a per-table
join-quality score:

  * ``containment`` — the per-table eligible-hit count from the §6.3
    filter launch (``filter_table_counts`` / the gather-fused variant),
    clipped to the distinct-key count and normalised: the fraction of
    query keys with a filter-surviving candidate row;
  * ``uniqueness`` — max column cardinality over table rows from the
    ``ProfileStore``: ~1.0 means the best candidate column is key-like
    (low join multiplicity), the join-quality predictor of "Measuring
    and Predicting the Quality of a Join for Data Discovery";
  * ``similarity`` — matching min-hash sketch positions between the
    query key values and the table's value set (profile distance).

``score = containment · (W_BASE + W_UNIQ·uniqueness + W_SIM·similarity)``
— monotone in containment, boosted by key-likeness and value overlap.
All arithmetic is float32 elementwise; the device path is one jitted XLA
launch per table batch (shape-bucketed like every ``kernels.ops`` entry
point) with ``score_np`` as its numpy oracle.

The score NEVER drives heap membership: selection stays exact-joinability
(rule 1/2 + verification are untouched), so the verified top-k SET is
bit-identical between ``rank='quality'`` and ``rank='count'`` — quality
only reorders and annotates the returned entries.
"""

from __future__ import annotations

import numpy as np

from repro.core import profiles

W_BASE = np.float32(0.25)
W_UNIQ = np.float32(0.55)
W_SIM = np.float32(0.20)

_jitted = None


def _score_fn():
    """The jitted scoring launch, built on first use (keeps jax out of the
    import path, mirroring ``MateIndex.device_store``)."""
    global _jitted
    if _jitted is None:
        import jax
        import jax.numpy as jnp

        @jax.jit
        def fn(counts, n_keys, card_max, n_rows, t_sketch, q_sketch):
            c = jnp.minimum(counts, n_keys) / jnp.maximum(n_keys, 1.0)
            u = card_max / jnp.maximum(n_rows, 1.0)
            eq = (t_sketch == q_sketch[None, :]).astype(jnp.float32)
            s = eq.sum(axis=1) / np.float32(profiles.SKETCH_K)
            return c * (W_BASE + W_UNIQ * u + W_SIM * s)

        _jitted = fn
    return _jitted


def score_np(
    counts: np.ndarray,
    n_keys: int,
    card_max: np.ndarray,
    n_rows: np.ndarray,
    sketch_eq: np.ndarray,
) -> np.ndarray:
    """Numpy oracle for the scoring launch — same float32 op order."""
    nk = np.float32(n_keys)
    c = np.minimum(counts.astype(np.float32), nk) / np.maximum(
        nk, np.float32(1.0)
    )
    u = card_max.astype(np.float32) / np.maximum(
        n_rows.astype(np.float32), np.float32(1.0)
    )
    s = sketch_eq.astype(np.float32) / np.float32(profiles.SKETCH_K)
    return (c * (W_BASE + W_UNIQ * u + W_SIM * s)).astype(np.float32)


def query_sketch(index, distinct_keys: list[tuple]) -> np.ndarray:
    """Min-hash sketch of the query's key-value set (one per plan)."""
    uniq = list(dict.fromkeys(v for key in distinct_keys for v in key))
    if not uniq:
        return profiles.value_sketch(np.zeros(0, dtype=np.uint32))
    lanes = index.hash_values(uniq)
    return profiles.value_sketch(lanes[:, 0])


def quality_scores(
    index,
    table_ids: np.ndarray,
    counts: np.ndarray,
    n_keys: int,
    q_sketch: np.ndarray,
    stats=None,
) -> np.ndarray:
    """float32[n] join-quality scores for one batch of candidate tables.

    Gathers the tables' profile features (shard-local under a routed
    index — ``profile_features`` reads each owning shard's store) and runs
    ONE scoring launch over the batch.  Deterministic given the index.
    """
    n = int(np.asarray(table_ids).shape[0])
    if n == 0:
        return np.zeros(0, dtype=np.float32)
    from repro.kernels import ops

    card_max, n_rows, sketch = index.profile_features(table_ids)
    nb = ops._bucket(n, 16)
    counts_f = np.zeros(nb, dtype=np.float32)
    counts_f[:n] = np.asarray(counts, dtype=np.float32)[:n]
    card_f = np.zeros(nb, dtype=np.float32)
    card_f[:n] = card_max.astype(np.float32)
    rows_f = np.ones(nb, dtype=np.float32)
    rows_f[:n] = n_rows.astype(np.float32)
    sk = np.zeros((nb, profiles.SKETCH_K), dtype=np.uint32)
    sk[:n] = sketch
    out = np.asarray(
        _score_fn()(
            counts_f, np.float32(n_keys), card_f, rows_f, sk, q_sketch
        )
    )[:n]
    if stats is not None:
        stats.ranking_launches += 1
    return out.astype(np.float32)
