"""Baseline hash functions MATE is compared against (paper §7.2).

Every function maps ``str -> int`` bitmask of ``bits`` width; super keys are
built by OR-aggregating per-cell hashes exactly like XASH, so the comparison
isolates the hash function (as in the paper, "all the competing hash
functions benefit from all of MATE's optimizations and only differ in the
applied hash function during row filtering").

Implementations are deterministic and dependency-free:
  * murmur128 — MurmurHash3 x64 128-bit (faithful port).
  * md5       — hashlib MD5 truncated/extended to ``bits``.
  * city128   — CityHash-class uniform 128-bit mix (FNV/murmur finalizer
                construction; the paper's point is only that such hashes
                set ~50% of bits uniformly).
  * simhash   — Charikar simhash over character 2-grams.
  * ht        — hash table: ONE bit per value (murmur mod bits).
  * bf        — bloom filter with ``n_hash`` bits per value (murmur, seeds),
                n_hash fixed from the corpus' average row width (§7.2: BF
                "calculates the number of hash functions based on the average
                number of columns in the corpus tables").
"""

from __future__ import annotations

import hashlib
import math

MASK64 = (1 << 64) - 1


def _rotl64(x: int, r: int) -> int:
    return ((x << r) | (x >> (64 - r))) & MASK64


def _fmix64(k: int) -> int:
    k ^= k >> 33
    k = (k * 0xFF51AFD7ED558CCD) & MASK64
    k ^= k >> 33
    k = (k * 0xC4CEB9FE1A85EC53) & MASK64
    k ^= k >> 33
    return k


def murmur3_x64_128(data: bytes, seed: int = 0) -> int:
    """Faithful MurmurHash3 x64 128-bit."""
    c1, c2 = 0x87C37B91114253D5, 0x4CF5AD432745937F
    h1 = h2 = seed & MASK64
    length = len(data)
    nblocks = length // 16
    for i in range(nblocks):
        k1 = int.from_bytes(data[i * 16 : i * 16 + 8], "little")
        k2 = int.from_bytes(data[i * 16 + 8 : i * 16 + 16], "little")
        k1 = (k1 * c1) & MASK64
        k1 = _rotl64(k1, 31)
        k1 = (k1 * c2) & MASK64
        h1 ^= k1
        h1 = _rotl64(h1, 27)
        h1 = (h1 + h2) & MASK64
        h1 = (h1 * 5 + 0x52DCE729) & MASK64
        k2 = (k2 * c2) & MASK64
        k2 = _rotl64(k2, 33)
        k2 = (k2 * c1) & MASK64
        h2 ^= k2
        h2 = _rotl64(h2, 31)
        h2 = (h2 + h1) & MASK64
        h2 = (h2 * 5 + 0x38495AB5) & MASK64
    tail = data[nblocks * 16 :]
    k1 = k2 = 0
    if len(tail) > 8:
        k2 = int.from_bytes(tail[8:].ljust(8, b"\0"), "little")
        k2 = (k2 * c2) & MASK64
        k2 = _rotl64(k2, 33)
        k2 = (k2 * c1) & MASK64
        h2 ^= k2
    if len(tail) > 0:
        k1 = int.from_bytes(tail[:8].ljust(8, b"\0"), "little")
        k1 = (k1 * c1) & MASK64
        k1 = _rotl64(k1, 31)
        k1 = (k1 * c2) & MASK64
        h1 ^= k1
    h1 ^= length
    h2 ^= length
    h1 = (h1 + h2) & MASK64
    h2 = (h2 + h1) & MASK64
    h1 = _fmix64(h1)
    h2 = _fmix64(h2)
    h1 = (h1 + h2) & MASK64
    h2 = (h2 + h1) & MASK64
    return h1 | (h2 << 64)


def _extend_to_bits(h128: int, bits: int) -> int:
    """Extend/truncate a 128-bit value to ``bits`` by chained remixing."""
    if bits <= 128:
        return h128 & ((1 << bits) - 1)
    out, acc, got = 0, h128, 0
    while got < bits:
        out |= (acc & ((1 << 128) - 1)) << got
        got += 128
        acc = _fmix64(acc & MASK64) | (_fmix64((acc >> 64) ^ 0x9E3779B97F4A7C15) << 64)
    return out & ((1 << bits) - 1)


def hash_murmur(value: str, bits: int = 128) -> int:
    return _extend_to_bits(murmur3_x64_128(value.encode("utf-8")), bits)


def hash_md5(value: str, bits: int = 128) -> int:
    d = hashlib.md5(value.encode("utf-8")).digest()
    h = int.from_bytes(d, "little")
    return _extend_to_bits(h, bits)


def hash_city(value: str, bits: int = 128) -> int:
    """CityHash-class uniform mix (two seeded 64-bit FNV-1a + murmur finalize)."""
    data = value.encode("utf-8")
    h1, h2 = 0xCBF29CE484222325, 0x100000001B3 ^ 0x9E3779B97F4A7C15
    for b in data:
        h1 = ((h1 ^ b) * 0x100000001B3) & MASK64
        h2 = ((h2 ^ (b + 0x9E)) * 0x100000001B3) & MASK64
    h1, h2 = _fmix64(h1 ^ len(data)), _fmix64(h2 + len(data))
    return _extend_to_bits(h1 | (h2 << 64), bits)


def hash_simhash(value: str, bits: int = 128) -> int:
    """Charikar simhash over character 2-grams."""
    data = value.encode("utf-8")
    grams = [data[i : i + 2] for i in range(max(len(data) - 1, 1))]
    counts = [0] * bits
    for g in grams:
        gh = _extend_to_bits(murmur3_x64_128(g, seed=7), bits)
        for i in range(bits):
            counts[i] += 1 if (gh >> i) & 1 else -1
    out = 0
    for i in range(bits):
        if counts[i] >= 0:
            out |= 1 << i
    return out


def hash_ht(value: str, bits: int = 128) -> int:
    """Hash table: a single bit per value."""
    return 1 << (murmur3_x64_128(value.encode("utf-8")) % bits)


def make_bloom(n_hash: int):
    def hash_bf(value: str, bits: int = 128) -> int:
        data = value.encode("utf-8")
        out = 0
        for s in range(n_hash):
            out |= 1 << (murmur3_x64_128(data, seed=0xB10F + s) % bits)
        return out

    hash_bf.__name__ = f"hash_bf{n_hash}"
    return hash_bf


def optimal_bloom_hashes(bits: int, avg_row_width: float) -> int:
    """k = (m/n) ln 2 with n = average #values OR-ed into one super key."""
    return max(1, round(bits / max(avg_row_width, 1.0) * math.log(2)))


# Registry used by the index/benchmarks. 'xash' is handled natively by
# repro.core.xash; entries here are ``fn(value, bits) -> int``.
BASELINE_HASHES = {
    "murmur": hash_murmur,
    "md5": hash_md5,
    "city": hash_city,
    "simhash": hash_simhash,
    "ht": hash_ht,
}
