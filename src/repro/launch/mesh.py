"""Production meshes + sharding rules for every (arch × shape) cell.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; 'pod' is the outer
data-parallel axis (DCN-connected), so batch shards over ('pod','data').

Importing this module never touches jax device state — meshes are built by
FUNCTIONS only (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

from typing import Any

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import params as P_
from repro.models.config import ModelConfig

# explicit Auto axis types appeared after jax 0.4.x; older Meshes are Auto-only
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def _axis_kw(n_axes: int) -> dict:
    if _AXIS_TYPE is None:
        return {}
    return {"axis_types": (_AXIS_TYPE.Auto,) * n_axes}


V5E = {
    "peak_flops_bf16": 197e12,  # per chip
    "hbm_bw": 819e9,  # bytes/s per chip
    "ici_bw": 50e9,  # bytes/s per link
    "hbm_bytes": 16e9,
}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))
    # subset mesh (e.g. single-pod 256 of 512 host devices, or CPU tests)
    assert len(devices) >= n, f"need {n} devices, have {len(devices)}"
    dev_array = np.asarray(devices[:n]).reshape(shape)
    return Mesh(dev_array, axes, **_axis_kw(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    n = int(np.prod(shape))
    dev_array = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(dev_array, axes, **_axis_kw(len(axes)))


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def rules_for(mesh: Mesh, fsdp: bool = True) -> dict[str, Any]:
    """Logical-axis → mesh-axis rules (params)."""
    rules = dict(P_.DEFAULT_RULES)
    rules["embed"] = batch_axes(mesh) if fsdp else None
    return rules


def param_shardings(specs, mesh: Mesh, fsdp: bool = True):
    """NamedShardings for a spec tree with divisibility fallback."""
    pspecs = P_.validate_divisibility(specs, mesh, rules_for(mesh, fsdp))
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs)


def data_sharding(mesh: Mesh):
    return NamedSharding(mesh, P(batch_axes(mesh)))


def _dim_ok(mesh: Mesh, axes, dim: int) -> bool:
    size = int(np.prod([mesh.shape[a] for a in (axes if isinstance(axes, tuple) else (axes,))]))
    return dim % size == 0


def _greedy_pspec(shape: tuple[int, ...], prefs: list[tuple[int, list]], mesh: Mesh) -> P:
    """Assign mesh axes to dims greedily.

    prefs: [(dim, [axis-or-axistuple candidates in priority order]), ...].
    Each mesh axis is used at most once; a candidate applies only if the dim
    is divisible by the candidate's total size.
    """
    used: set[str] = set()
    out: list[Any] = [None] * len(shape)
    for dim, candidates in prefs:
        for cand in candidates:
            axes = cand if isinstance(cand, tuple) else (cand,)
            if not axes or any(a in used or a not in mesh.axis_names for a in axes):
                continue
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if size > 1 and shape[dim] % size == 0:
                out[dim] = cand
                used.update(axes)
                break
    return P(*out)


def cache_pspec_for(path_key: str, shape: tuple[int, ...], mesh: Mesh) -> P:
    """KV-cache / SSM-state sharding by leaf name (leading dim = scan layers,
    replicated).

    Preferences encode the serving layouts:
      * batch over ('pod','data') when divisible (decode_32k);
      * KV heads over 'model' when divisible, else cache SEQUENCE over
        'model' (GQA with few KV heads: qwen3/danube/jamba);
      * batch=1 long-context (long_500k): sequence shards over ALL axes —
        sequence-parallel decode, GSPMD turns the attention reduction into
        psums over the sharded length.
    """
    ba = batch_axes(mesh)
    all_ax = tuple(mesh.axis_names)
    if path_key in ("k", "v"):  # [L, B, slots, kv, hd]
        return _greedy_pspec(
            shape,
            [(1, [ba]), (3, ["model"]), (2, [all_ax, ("data", "model"), "model", ba])],
            mesh,
        )
    if path_key in ("ckv", "kr"):  # [L, B, S, r]
        return _greedy_pspec(
            shape, [(1, [ba]), (2, [all_ax, ("data", "model"), "model", ba])], mesh
        )
    if path_key == "h":  # [L, B, nh, ds, hd]
        return _greedy_pspec(shape, [(1, [ba]), (2, ["model"])], mesh)
    if path_key == "conv":  # [L, B, K-1, conv_dim]
        return _greedy_pspec(shape, [(1, [ba]), (3, ["model"])], mesh)
    if path_key == "pos":  # [L, B]
        return _greedy_pspec(shape, [(1, [ba])], mesh)
    if path_key == "slot_pos":  # [L, B, slots]
        return _greedy_pspec(
            shape, [(1, [ba]), (2, [all_ax, ("data", "model"), "model", ba])], mesh
        )
    return P(*([None] * len(shape)))


def cache_shardings(cache_sds, mesh: Mesh):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_sds)
    out = []
    for path, leaf in flat:
        key = str(path[-1].key) if hasattr(path[-1], "key") else ""
        out.append(NamedSharding(mesh, cache_pspec_for(key, leaf.shape, mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)
