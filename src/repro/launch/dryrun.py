import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces results/dryrun/<arch>__<shape>__<mesh>[__<variant>].json
with memory analysis, cost analysis (FLOPs / bytes), and the collective
schedule (bytes per collective kind parsed from the post-SPMD HLO) — the
inputs to the §Roofline table.

MUST be run as a module: ``PYTHONPATH=src python -m repro.launch.dryrun
[--arch a] [--shape s] [--multi-pod] [--variant name --set k=v ...]``.
The XLA_FLAGS line above executes before any jax import (jax locks the
device count on first init).
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.shapes import SHAPES, applicable
from repro.launch import mesh as meshlib
from repro.models import params as params_lib, transformer
from repro.models.config import ModelConfig
from repro.serve.engine import make_serve_step
from repro.train import optimizer as opt, step as train_step_lib

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}
_COLL_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes(type_str: str, tuple_max: bool) -> int:
    """Bytes of an HLO result type string; tuples either summed or max'd."""
    sizes = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        sizes.append(n * _DTYPE_BYTES[dt])
    if not sizes:
        return 0
    return max(sizes) if tuple_max else sum(sizes)


_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)


def parse_collectives(hlo_text: str) -> dict:
    """Per-kind {count, bytes} from post-SPMD HLO (per-device program).

    Async '-start' ops carry (input, output) tuples — we take the max element
    (the transferred buffer); '-done' ops are skipped to avoid double counts.
    """
    out = {k: {"count": 0, "bytes": 0} for k in _COLL_KINDS}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind, is_start = m.group(1), m.group(2), m.group(3)
        out[kind]["count"] += 1
        out[kind]["bytes"] += _shape_bytes(type_str, tuple_max=bool(is_start))
    out["total_bytes"] = sum(v["bytes"] for v in out.values() if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for v in out.values() if isinstance(v, dict))
    return out


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Variant:
    name: str = "baseline"
    fsdp: bool = True
    remat: bool = True
    ce_chunk: int = 1024
    state_dtype: str = "bf16"
    mla_absorb: bool = False  # paper-faithful DeepSeek decode is naive
    flash_threshold: int = 8192
    moe_impl: str = "scatter"  # baseline; 'einsum' = grouped-dispatch opt
    moe_group: int = 256
    seq_shard: bool = False  # Megatron-SP residual stream
    remat_policy: str = "full"  # 'full' | 'dots' | 'none'

    @staticmethod
    def parse(name: str, sets: list[str]) -> "Variant":
        v = Variant(name=name)
        for kv in sets:
            k, val = kv.split("=", 1)
            cur = getattr(v, k)
            if isinstance(cur, bool):
                val = val.lower() in ("1", "true", "yes")
            elif isinstance(cur, int):
                val = int(val)
            setattr(v, k, val)
        return v


def _abstract_with_sharding(specs, mesh, fsdp: bool):
    sds = params_lib.abstract(specs)
    sh = meshlib.param_shardings(specs, mesh, fsdp)
    return (
        jax.tree.map(
            lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h), sds, sh
        ),
        sh,
    )


def _extra_input_sds(cfg: ModelConfig, batch: int, mesh):
    extras = {}
    bsh = meshlib.data_sharding(mesh)
    if cfg.encoder is not None:
        extras["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, P(meshlib.batch_axes(mesh), None, None)),
        )
    if cfg.vision is not None:
        extras["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.vision.n_tokens, cfg.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, P(meshlib.batch_axes(mesh), None, None)),
        )
    return extras


def lower_cell(arch: str, shape_name: str, multi_pod: bool, variant: Variant):
    cfg = configs.get_config(arch)
    cfg = dataclasses.replace(cfg, mla_absorb=variant.mla_absorb)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    if not ok:
        return {"skipped": True, "reason": reason}

    import repro.models.layers as L
    import repro.models.moe as moe_mod

    import repro.models.transformer as T_

    L.FLASH_THRESHOLD = variant.flash_threshold
    L.SEQ_SHARD = variant.seq_shard
    T_.REMAT_POLICY = variant.remat_policy
    moe_mod.MOE_IMPL = variant.moe_impl
    moe_mod.MOE_GROUP_SIZE = variant.moe_group

    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    L.enable_activation_sharding(mesh)
    n_chips = mesh.size
    specs = transformer.model_specs(cfg)
    param_sds, param_sh = _abstract_with_sharding(specs, mesh, variant.fsdp)
    b, s = shape.global_batch, shape.seq_len
    bsp = P(meshlib.batch_axes(mesh))
    tok_sh = NamedSharding(mesh, P(meshlib.batch_axes(mesh), None))

    t0 = time.time()
    if shape.kind == "train":
        tcfg = train_step_lib.TrainConfig(
            adamw=opt.AdamWConfig(state_dtype=variant.state_dtype),
            remat=variant.remat,
            ce_chunk=variant.ce_chunk,
        )
        opt_sds = jax.eval_shape(lambda p: opt.init_state(p, tcfg.adamw), param_sds)
        # optimizer states shard like their parameters (int8 states replicate)
        def opt_shard(path, leaf):
            return NamedSharding(mesh, P(*([None] * len(leaf.shape))))
        if variant.state_dtype in ("f32", "bf16"):
            opt_sh = {
                "step": NamedSharding(mesh, P()),
                "m": param_sh,
                "v": param_sh,
            }
        else:
            flat, tdef = jax.tree_util.tree_flatten_with_path(opt_sds)
            opt_sh = jax.tree_util.tree_unflatten(
                tdef, [opt_shard(p, l) for p, l in flat]
            )
        opt_sds = jax.tree.map(
            lambda s, h: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=h),
            opt_sds, opt_sh,
        )
        batch_sds = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=tok_sh),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=tok_sh),
        }
        batch_sds.update(_extra_input_sds(cfg, b, mesh))
        fn = train_step_lib.make_train_step(cfg, tcfg)
        with mesh:
            lowered = jax.jit(
                fn,
                donate_argnums=(0, 1),
                out_shardings=(param_sh, opt_sh, None),
            ).lower(param_sds, opt_sds, batch_sds)
            compiled = lowered.compile()
    elif shape.kind == "prefill":
        max_seq = s + 64

        def fn(params, tokens, **kw):
            return transformer.prefill(params, cfg, tokens, max_seq, **kw)

        tok_sds = jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=tok_sh)
        extra = _extra_input_sds(cfg, b, mesh)
        with mesh:
            lowered = jax.jit(fn).lower(param_sds, tok_sds, **extra)
            compiled = lowered.compile()
    else:  # decode
        max_seq = s

        def make_cache():
            return transformer.init_cache(cfg, b, max_seq, enc_len=(
                cfg.encoder.n_frames if cfg.encoder is not None else (
                    cfg.vision.n_tokens if cfg.vision is not None else 0
                )
            ))

        cache_sds = jax.eval_shape(make_cache)
        cache_sh = meshlib.cache_shardings(cache_sds, mesh)
        cache_sds = jax.tree.map(
            lambda sd, h: jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=h),
            cache_sds, cache_sh,
        )
        tok_sds = jax.ShapeDtypeStruct(
            (b,), jnp.int32, sharding=NamedSharding(mesh, bsp if b % (
                int(np.prod([mesh.shape[a] for a in meshlib.batch_axes(mesh)]))
            ) == 0 else P(None))
        )

        def fn(params, cache, token):
            logits, new_cache = transformer.decode_step(params, cfg, token, cache)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), new_cache

        with mesh:
            lowered = jax.jit(
                fn, donate_argnums=(1,), out_shardings=(None, cache_sh)
            ).lower(param_sds, cache_sds, tok_sds)
            compiled = lowered.compile()

    compile_s = time.time() - t0
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes",
            )
            if hasattr(mem, k)
        } if mem is not None else None
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        cost = dict(cost) if cost else None
        if cost:
            cost = {
                k: float(v)
                for k, v in cost.items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "transcendentals", "optimal_seconds")
                    or k.startswith("bytes accessed")
                )
            }
    except Exception as e:
        cost = {"error": str(e)}
    hlo_text = compiled.as_text()
    colls = parse_collectives(hlo_text)
    from repro.launch import hlo_cost

    try:
        corrected = hlo_cost.analyze(hlo_text)
    except Exception as e:
        corrected = {"error": str(e)}

    # analytic per-device param bytes (2 bytes bf16 / sharded)
    pbytes = 0
    flat = jax.tree_util.tree_flatten_with_path(
        params_lib.abstract(specs)
    )[0]
    sh_flat = jax.tree_util.tree_flatten_with_path(param_sh)[0]
    for (pth, sds_), (_, sh_) in zip(flat, sh_flat):
        n = int(np.prod(sds_.shape)) * sds_.dtype.itemsize
        spec = sh_.spec
        denom = 1
        for entry in spec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            denom *= int(np.prod([mesh.shape[a] for a in axes]))
        pbytes += n // denom

    pc = cfg.params_count()
    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "variant": dataclasses.asdict(variant),
        "compile_seconds": round(compile_s, 1),
        "memory_analysis": mem_info,
        "cost_analysis": cost,
        "collectives": colls,
        "hlo_cost": corrected,  # trip-count-aware flops + collective bytes
        "param_bytes_per_device": pbytes,
        "params_total": pc["total"],
        "params_active": pc["active"],
        "kind": shape.kind,
        "global_batch": b,
        "seq_len": s,
    }


def cell_filename(arch, shape, multi_pod, variant_name):
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    suffix = "" if variant_name == "baseline" else f"__{variant_name}"
    return f"{arch}__{shape}__{mesh_tag}{suffix}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--set", action="append", default=[], dest="sets")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out-dir", default=None)
    args = ap.parse_args()

    out_dir = args.out_dir or os.path.abspath(RESULTS_DIR)
    os.makedirs(out_dir, exist_ok=True)
    variant = Variant.parse(args.variant, args.sets)

    archs = [args.arch] if args.arch else list(configs.ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                fname = cell_filename(arch, shape, mp, variant.name)
                path = os.path.join(out_dir, fname)
                if os.path.exists(path) and not args.force:
                    print(f"[skip cached] {fname}")
                    continue
                print(f"[lower] {fname} ...", flush=True)
                t0 = time.time()
                try:
                    rec = lower_cell(arch, shape, mp, variant)
                except Exception:
                    rec = {"error": traceback.format_exc()}
                rec["wall_seconds"] = round(time.time() - t0, 1)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = (
                    "SKIP(" + rec.get("reason", "")[:40] + ")"
                    if rec.get("skipped")
                    else ("ERROR" if "error" in rec else "ok")
                )
                print(f"  -> {status} in {rec['wall_seconds']}s", flush=True)
                if "error" in rec:
                    print(rec["error"].splitlines()[-1], flush=True)
                if rec.get("memory_analysis"):
                    print(f"  mem: {rec['memory_analysis']}", flush=True)
                if rec.get("cost_analysis"):
                    fl = rec["cost_analysis"].get("flops")
                    print(f"  flops/device: {fl}", flush=True)
                coll = rec.get("collectives")
                if coll:
                    print(
                        f"  collectives: {coll['total_count']} ops, "
                        f"{coll['total_bytes']/1e6:.1f} MB", flush=True
                    )


if __name__ == "__main__":
    main()
