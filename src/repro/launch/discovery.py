"""MATE discovery service driver:
``python -m repro.launch.discovery [--n-tables 400] [--queries 5] [--hash xash]
[--bits 128|256|512] [--backend fused|pallas|xla|numpy|auto]``

End-to-end run of the paper's system on a synthetic lake through the unified
``MateSession`` surface: build the session (offline phase), run top-k n-ary
join discovery (online phase) with both the faithful Algorithm 1 engine and
the session's batched engine, and report the paper's metrics (precision, FP
counts, filtering power, runtimes).

``--backend`` pins the §6.3 filter backend through ``DiscoveryConfig`` — the
highest-precedence level of the registry (config > ``MATE_FILTER_BACKEND`` >
platform default); omitted, the session resolves it per that rule.

``--mesh dxm`` additionally runs the shard_map-distributed filter to show
the corpus-sharded layout (1x1 on CPU; 16x16 on a real pod).

``--build-mesh N`` shards the OFFLINE phase the same way: the session builds
over an N-device mesh (``MateSession.build(..., mesh=...)`` — unique-value
hashing under shard_map, host-side posting merge), forcing N virtual CPU
devices for a dry run when the host has fewer.  The build is byte-identical
to the single-host pass; the driver prints the ``BuildStats`` breakdown.

``--route-shards N`` builds a ROUTED lake on top: a ``ShardedMateIndex``
(``MateSession.build(..., distributed=True, n_shards=N)``) that keeps each
shard's postings, superkeys, and device store resident where the shard was
built and routes every query to the data — only int32 per-table count
vectors cross a shard boundary.  The driver replays the same queries
through the routed session, asserts bit-identical top-k against the
single-host engines, and prints the cross-shard traffic
(``route_bytes_merged``) next to the superkey bytes a host-gather path
would have shipped.
"""

from __future__ import annotations

import argparse
import os
import time

import numpy as np

import jax

from repro.core import discovery
from repro.core import fd as fd_lib
from repro.core.corpus import Table
from repro.core.session import DiscoveryConfig, MateSession
from repro.core import distributed
from repro.data import synthetic
from repro.kernels import registry
from repro.launch import mesh as meshlib
from repro.serve.engine import DiscoveryEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-tables", type=int, default=400)
    ap.add_argument("--queries", type=int, default=5)
    ap.add_argument("--rows", type=int, default=25)
    ap.add_argument("--key-width", type=int, default=2)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--hash", default="xash",
                    choices=["xash", "bf", "ht", "murmur", "md5", "city", "simhash"])
    ap.add_argument("--bits", type=int, default=128, choices=[128, 256, 512],
                    help="superkey hash width (uint32 lanes = bits/32)")
    ap.add_argument("--backend", default=None, choices=registry.backend_names(),
                    help="filter backend (config-level pin; default: "
                         "MATE_FILTER_BACKEND, then platform default)")
    ap.add_argument("--rank", default="quality", choices=["quality", "count"],
                    help="result ordering: join-quality scoring head "
                         "(default) or exact-joinability count order; the "
                         "verified top-k SET is identical either way")
    ap.add_argument("--no-profile-gate", action="store_true",
                    help="disable the column-profile candidate gate "
                         "(pure pruning; results are set-identical with it "
                         "on or off)")
    ap.add_argument("--flush-after", type=float, default=None,
                    help="serving deadline (s) for partial DiscoveryEngine groups")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded submit queue: admission control kicks in at "
                         "this many waiting requests (default: unbounded)")
    ap.add_argument("--pressure-policy", default="shed",
                    choices=["shed", "degrade"],
                    help="at max_queue: reject with AdmissionError, or admit "
                         "at degraded 128-bit filtering (still bit-identical)")
    ap.add_argument("--fds", action="store_true",
                    help="also run the FD workload (core.fd): test a "
                         "candidate functional dependency det-cols -> "
                         "dependent against every joining lake table, no "
                         "join materialized")
    ap.add_argument("--fd-signals", action="store_true",
                    help="order FD candidates by the multi-signal ensemble "
                         "(joinability + uniqueness + sketch + name) instead "
                         "of raw support")
    ap.add_argument("--result-cache", type=int, default=0,
                    help="query-result cache capacity (0: off) — repeated "
                         "queries answer at submit, invalidated on mutations")
    ap.add_argument("--bound-cache", type=int, default=0,
                    help="hot-table bound cache capacity (0: off) — warm "
                         "queries skip gather+filter at any k")
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--build-mesh", type=int, default=1, metavar="N",
                    help="shard the offline index build over an N-device mesh "
                         "(forces N virtual CPU devices when the host has "
                         "fewer and jax is not yet initialised)")
    ap.add_argument("--route-shards", type=int, default=0, metavar="N",
                    help="also build an N-shard routed lake "
                         "(ShardedMateIndex) and replay the queries through "
                         "it: shard-local filter launches, count-only merge, "
                         "bit-identical top-k asserted against single-host")
    ap.add_argument("--seed", type=int, default=3)
    args = ap.parse_args(argv)

    if args.build_mesh > 1 or args.route_shards > 1:
        # must win the race with the first jax backend init; harmless if the
        # backend is already up — the mesh is clamped to visible devices below
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            n_force = max(args.build_mesh, args.route_shards)
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{n_force}"
            ).strip()

    print(f"[mate] building corpus ({args.n_tables} tables) ...")
    corpus = synthetic.make_corpus(
        synthetic.SyntheticSpec(n_tables=args.n_tables, seed=args.seed)
    )
    config = DiscoveryConfig(
        bits=args.bits, k=args.k, backend=args.backend, hash_name=args.hash,
        rank=args.rank, profile_gate=not args.no_profile_gate,
        flush_after=args.flush_after, max_queue=args.max_queue,
        pressure_policy=args.pressure_policy, result_cache=args.result_cache,
        bound_cache=args.bound_cache,
        signals=fd_lib.DEFAULT_SIGNALS if args.fd_signals else None,
    )
    build_mesh = None
    if args.build_mesh > 1:
        n_dev = min(args.build_mesh, len(jax.devices()))
        if n_dev < args.build_mesh:
            print(
                f"[mate] --build-mesh {args.build_mesh}: only "
                f"{len(jax.devices())} devices visible (jax already "
                f"initialised?), building on {n_dev}"
            )
        build_mesh = meshlib.make_mesh((n_dev,), ("data",))
    t0 = time.time()
    session = MateSession.build(corpus, config, mesh=build_mesh)
    index = session.index
    print(
        f"[mate] offline phase: indexed {corpus.total_rows} rows, "
        f"{len(corpus.unique_values)} unique values in {time.time()-t0:.2f}s "
        f"(hash={args.hash}, bits={session.bits}, lanes={index.cfg.lanes}, "
        f"backend={session.backend.name}[{session.backend.source}])"
    )
    bs = session.build_stats
    print(
        f"[mate] build stats: shards={bs.n_shards}"
        f"{'' if bs.mesh_shape is None else f' mesh={bs.mesh_shape}'} "
        f"hash={bs.hash_seconds:.2f}s superkeys={bs.superkey_seconds:.2f}s "
        f"postings={bs.postings_seconds:.2f}s merge={bs.merge_seconds:.3f}s "
        f"({bs.bytes_hashed} bytes hashed over "
        f"{bs.values_total} unique values)"
    )

    queries = synthetic.make_mixed_queries(
        corpus, args.queries, args.rows, args.key_width, seed=args.seed + 2
    )
    agg = {"tp": 0, "fp": 0, "checks": 0, "t_seq": 0.0, "t_batched": 0.0,
           "mat_bytes": 0, "rb_bytes": 0}
    for qi, (q, q_cols) in enumerate(queries):
        t0 = time.time()
        topk_seq, st = discovery.discover(index, q, q_cols, k=args.k)
        agg["t_seq"] += time.time() - t0
        t0 = time.time()
        topk_bat, stb = session.discover(q, q_cols)
        agg["t_batched"] += time.time() - t0
        agg["tp"] += st.verified_tp
        agg["fp"] += st.verified_fp
        agg["checks"] += st.filter_checks
        agg["mat_bytes"] += stb.filter_matrix_bytes
        agg["rb_bytes"] += stb.filter_readback_bytes
        # quality rank reorders the session's entries by the scoring head;
        # the scalar engine is count-ordered — the invariant across rank
        # modes is the verified SET, so compare sorted under 'quality'.
        key_seq = [(e.table_id, e.joinability) for e in topk_seq]
        key_bat = [(e.table_id, e.joinability) for e in topk_bat]
        match = (
            sorted(key_seq) == sorted(key_bat)
            if config.rank == "quality"
            else key_seq == key_bat
        )
        label = (
            "engines_set_identical" if config.rank == "quality"
            else "engines_bit_identical"
        )
        print(
            f"[mate] query {qi}: top-{args.k} "
            f"{[(e.table_id, e.joinability) for e in topk_seq[:5]]}... "
            f"precision={st.precision:.3f} {label}={match}"
        )
    prec = agg["tp"] / max(agg["tp"] + agg["fp"], 1)
    if agg["mat_bytes"]:
        readback = (
            f"match_readback={agg['rb_bytes']}/{agg['mat_bytes']}B "
            f"({agg['rb_bytes'] / agg['mat_bytes']:.1%} of full matrix)"
        )
    else:  # fused counts-only path: no match matrix was ever produced
        readback = f"match_readback={agg['rb_bytes']}B (fused, matrix_bytes=0)"
    print(
        f"[mate] total: precision={prec:.3f} filter_checks={agg['checks']} "
        f"seq={agg['t_seq']:.2f}s batched={agg['t_batched']:.2f}s "
        f"speedup={agg['t_seq']/max(agg['t_batched'],1e-9):.1f}x " + readback
    )
    print(
        f"[mate] profile gate ({'on' if config.profile_gate else 'off'}, "
        f"rank={config.rank}): tables_gated={session.stats.tables_gated} "
        f"gate_bytes_saved={session.stats.gate_bytes_saved}B "
        f"ranking_launches={session.stats.ranking_launches}"
    )

    if args.fds and queries:
        # FD workload demo: extend the first query with a synthetic dependent
        # column (one value per determinant key, FD-clean), then duplicate
        # one key with a CONFLICTING dependent value so a violating group
        # exists — tables matching that key must come back holds=False.
        q0, qc0 = queries[0]
        dep_col = q0.n_cols
        cells = [list(row) + [f"dep{i}"] for i, row in enumerate(q0.cells)]
        cells.append(list(q0.cells[0]) + ["dep-conflict"])
        fd_query = Table(-1, cells, name="fd probe")
        t0 = time.time()
        fds, fstats = session.discover_fds(
            fd_query, list(qc0), dep_col, min_support=1
        )
        print(
            f"[mate] FD workload (det={list(qc0)} -> dep={dep_col}, "
            f"signals={'on' if config.signals else 'off'}): "
            f"candidates={fstats.fd_candidates} "
            f"validated={fstats.fd_validated} "
            f"pruned={fstats.fd_candidates - fstats.fd_validated} "
            f"bytes_verified={fstats.fd_bytes_verified}B "
            f"in {time.time()-t0:.3f}s"
        )
        for c in fds[:5]:
            score = "" if c.score is None else f" score={c.score:.3f}"
            print(
                f"[mate]   table {c.table_id}: support={c.support} "
                f"holds={c.holds} violations={c.violations}{score}"
            )

    # multi-query serving path: requests share filter launches in slot
    # groups (the shared launch costs O(rows x keys) of the whole group,
    # so it is bounded rather than fused across arbitrarily many queries).
    # The engine wraps the SAME session: one config, one resolved backend.
    engine = DiscoveryEngine(
        session=session, batch=min(max(len(queries), 1), 16),
        flush_after=args.flush_after,
    )
    reqs = [engine.submit(q, q_cols) for q, q_cols in queries]
    t0 = time.time()
    served = engine.flush()
    t_many = time.time() - t0
    agree = all(r.done and r.future.done() and r.stats is not None for r in reqs)
    print(
        f"[mate] DiscoveryEngine: {len(served)} requests in shared filter "
        f"launches of ≤{engine.batch} "
        f"({t_many:.2f}s, vs {agg['t_seq']:.2f}s sequential, all_served={agree})"
    )
    if args.result_cache or args.bound_cache:
        # replay the same traffic: repeats answer from the serving caches
        t0 = time.time()
        replay = [engine.discover(q, q_cols) for q, q_cols in queries]
        t_replay = time.time() - t0
        hot = all(r.from_cache for r in replay) if args.result_cache else True
        print(
            f"[mate] serving caches: replayed {len(replay)} requests in "
            f"{t_replay:.3f}s (cache_hits={session.stats.cache_hits}, "
            f"bound_hits={session.stats.bound_hits}, all_from_cache={hot}, "
            f"shed={session.stats.shed}, degraded={session.stats.degraded})"
        )
    print(f"[mate] session: {session}")

    if args.route_shards > 1:
        t0 = time.time()
        routed = MateSession.build(
            corpus, config, distributed=True, n_shards=args.route_shards
        )
        t_build = time.time() - t0
        lanes = routed.index.cfg.lanes
        identical = True
        items = 0
        t0 = time.time()
        for qi, (q, q_cols) in enumerate(queries):
            topk_ref, _ = session.discover(q, q_cols)
            topk_rt, st_rt = routed.discover(q, q_cols)
            items += st_rt.pl_items_checked
            # both sessions share the rank mode, so even the quality order
            # should agree (identical profiles shard-merged vs global); the
            # asserted invariant stays the exact entry sequence.
            identical &= [(e.table_id, e.joinability) for e in topk_ref] == [
                (e.table_id, e.joinability) for e in topk_rt
            ]
        t_routed = time.time() - t0
        host_gather_bytes = items * lanes * 4  # superkeys a host-gather ships
        rs = routed.stats
        print(
            f"[mate] routed lake ({routed.index.n_shards} shards, built in "
            f"{t_build:.2f}s): {len(queries)} queries in {t_routed:.2f}s, "
            f"bit_identical={identical}, shard_launches={rs.shard_launches}, "
            f"gather_demotions={rs.shard_gather_demotions}"
        )
        print(
            f"[mate] routed traffic: route_bytes_merged="
            f"{rs.route_bytes_merged}B crossed shard boundaries vs "
            f"{host_gather_bytes}B of superkeys a host-gather path ships "
            f"({rs.route_bytes_merged / max(host_gather_bytes, 1):.1%}); "
            f"superkey rows crossing shards: 0 (by construction)"
        )
        if not identical:
            raise SystemExit("[mate] routed top-k diverged from single-host")

    if not queries:
        return
    dp, tp_ = (int(x) for x in args.mesh.split("x"))
    mesh = meshlib.make_mesh((dp, tp_), ("data", "model"))
    row_tables = np.asarray(
        corpus.table_of_row(np.arange(corpus.total_rows)), dtype=np.int32
    )
    sk, rt = distributed.shard_corpus_rows(
        index.superkeys, row_tables, mesh, ("data",)
    )
    q, q_cols = queries[0]
    _keys, sk_of_key = discovery.build_query_superkeys(index, q, q_cols)
    qsk = np.stack(list(sk_of_key.values()))
    # the distributed filter resolves its per-shard impl from the same
    # registry precedence (a fused backend runs the fused shard launch)
    fn = distributed.make_distributed_filter(
        mesh, len(corpus.tables), ("data",), backend=session.backend
    )
    t0 = time.time()
    tc, kc = fn(sk, rt, qsk)
    tc.block_until_ready()
    print(
        f"[mate] distributed filter on mesh {args.mesh} "
        f"(impl={distributed.shard_impl_for(session.backend)}): "
        f"{int(np.asarray(tc).sum())} candidate rows across "
        f"{int((np.asarray(tc) > 0).sum())} tables in {time.time()-t0:.3f}s"
    )


if __name__ == "__main__":
    main()
