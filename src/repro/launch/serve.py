"""Serving driver: ``python -m repro.launch.serve --arch <id> --smoke``

Loads (or randomly initialises) a model, runs the slot-batched serve engine
over a set of demo prompts, and reports decode throughput.  On TPU meshes
the same code path shards params via GSPMD; on CPU it demos the engine with
the reduced config.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import stub_inputs
from repro.launch import mesh as meshlib
from repro.models import params as params_lib, transformer
from repro.serve.engine import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--n-requests", type=int, default=8)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = configs.reduce_config(cfg)
    specs = transformer.model_specs(cfg)
    params = params_lib.materialize(specs, jax.random.PRNGKey(0))
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        step, restored = mgr.restore_latest({"params": params})
        if restored is not None:
            params = restored["params"]
            print(f"[serve] restored checkpoint step {step}")

    extra = stub_inputs(cfg, args.batch)
    engine = ServeEngine(
        params, cfg, batch=args.batch, max_seq=args.max_seq,
        temperature=args.temperature, extra_inputs=extra,
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(
            prompt=list(rng.integers(2, cfg.vocab_size, size=int(rng.integers(4, 16)))),
            max_new=args.max_new,
        )
        for _ in range(args.n_requests)
    ]
    t0 = time.time()
    done = engine.generate(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s)")
    for r in done[:3]:
        print(f"  prompt[:6]={r.prompt[:6]} -> out[:8]={r.out[:8]}")
    return done


if __name__ == "__main__":
    main()
