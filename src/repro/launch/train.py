"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke] ...``

Production path (TPU): builds the mesh, shards params/optimizer/batches via
GSPMD, checkpoints every --ckpt-every steps (atomic, keep-K), auto-resumes
from the latest checkpoint (including onto a DIFFERENT mesh shape — elastic
restart), and handles SIGTERM preemption by saving before exit.

CPU path (--smoke / this container): same code on a 1×1 mesh with the
reduced config — the end-to-end driver for deliverable (b).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import DataConfig, TokenPipeline, stub_inputs
from repro.launch import mesh as meshlib
from repro.models import layers, params as params_lib, transformer
from repro.train import optimizer as opt, step as step_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true", help="reduced config on CPU")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default="1x1", help="data×model, e.g. 16x16")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--state-dtype", default="f32")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.smoke:
        cfg = configs.reduce_config(cfg)
    dp, tp = (int(x) for x in args.mesh.split("x"))
    mesh = meshlib.make_mesh((dp, tp), ("data", "model"))
    if mesh.size > 1:
        layers.enable_activation_sharding(mesh)

    tcfg = step_lib.TrainConfig(
        adamw=opt.AdamWConfig(
            lr=args.lr, warmup_steps=min(20, args.steps // 10 + 1),
            total_steps=args.steps, state_dtype=args.state_dtype,
        ),
        ce_chunk=min(1024, args.seq_len),
    )
    specs = transformer.model_specs(cfg)
    param_sh = meshlib.param_shardings(specs, mesh)

    key = jax.random.PRNGKey(args.seed)
    with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
        params = params_lib.materialize(specs, key)
        params = jax.tree.map(jax.device_put, params, param_sh)
        opt_state = opt.init_state(params, tcfg.adamw)

    data = TokenPipeline(
        DataConfig(args.seq_len, args.global_batch, cfg.vocab_size, args.seed)
    )
    extra = stub_inputs(cfg, args.global_batch)

    mgr = None
    start_step = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        mgr.install_preemption_handler()
        latest = mgr.latest_step()
        if latest is not None:
            # elastic restore: reshard onto the CURRENT mesh
            state_like = {"params": params, "opt": opt_state}
            sh_like = {
                "params": param_sh,
                "opt": jax.tree.map(lambda _: None, opt_state),
            }
            restored = mgr.restore(latest, state_like)
            params = jax.tree.map(jax.device_put, restored["params"], param_sh)
            opt_state = restored["opt"]
            start_step = latest
            print(f"[train] resumed from step {latest}")

    train_step = jax.jit(
        step_lib.make_train_step(cfg, tcfg), donate_argnums=(0, 1)
    )

    t0 = time.time()
    losses = []
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        batch.update(extra)
        with mesh:
            params, opt_state, metrics = train_step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            tok_s = args.global_batch * args.seq_len * (step - start_step + 1) / max(dt, 1e-9)
            print(
                f"[train] step={step} loss={losses[-1]:.4f} "
                f"lr={float(metrics['lr']):.2e} gnorm={float(metrics['grad_norm']):.2f} "
                f"tok/s={tok_s:,.0f}"
            )
        if mgr and (step % args.ckpt_every == args.ckpt_every - 1 or mgr.preempted):
            mgr.save(step + 1, {"params": params, "opt": opt_state})
            if mgr.preempted:
                print("[train] preemption save complete; exiting")
                return losses
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt_state})
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
