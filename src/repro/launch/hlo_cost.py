"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts every ``while`` body ONCE, so a
scan-over-layers model under-reports FLOPs and collective bytes by ~the layer
count.  This module re-derives both from the post-optimisation HLO text:

  * parses computations, ``dot``/collective ops (shapes → flops/bytes),
    ``fusion``/``call``/``while`` edges;
  * extracts loop trip counts from the canonical XLA loop form
    (``compare(iota-like counter, constant(N))`` in the condition);
  * folds costs bottom-up: cost(while) = trip × cost(body).

Dot flops: 2 × prod(result dims) × prod(contracted dims of lhs).
Collective bytes: result-shape bytes (max tuple element for async -start).
This is a cost MODEL (batch dims of convs treated via result shape); it is
validated against analytic 6·N·D in tests/benchmarks.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _dims(shape_str: str) -> list[int]:
    if not shape_str:
        return []
    return [int(d) for d in shape_str.split(",") if d]


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None, []
    return m.group(1), _dims(m.group(2))


def _shape_bytes(type_str: str, tuple_max: bool = False) -> int:
    sizes = []
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in _dims(m.group(2)):
            n *= d
        sizes.append(n * _DTYPE_BYTES[dt])
    if not sizes:
        return 0
    return max(sizes) if tuple_max else sum(sizes)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    coll_bytes: dict | None = None
    coll_count: dict | None = None

    def __post_init__(self):
        self.coll_bytes = self.coll_bytes or {k: 0.0 for k in _COLL_KINDS}
        self.coll_count = self.coll_count or {k: 0.0 for k in _COLL_KINDS}

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        for k in _COLL_KINDS:
            self.coll_bytes[k] += other.coll_bytes[k] * times
            self.coll_count[k] += other.coll_count[k] * times


_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CALL_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_NAME_RE = re.compile(r"%[\w.\-]+")


def split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for line in hlo.splitlines():
        s = line.rstrip()
        st = s.strip()
        if st.endswith("{") and ("->" in st or st.startswith("ENTRY")):
            # header like: %name (params) -> type {   /  ENTRY %name ...
            name = st.split("(")[0].strip()
            name = name.replace("ENTRY", "").strip().lstrip("%").strip()
            cur = name
            comps[cur] = []
        elif st == "}":
            cur = None
        elif cur is not None:
            comps[cur].append(st)
    return comps


def _parse_line(line: str):
    """(lhs_name, result_type_str, op, args_str) or None."""
    if "=" not in line:
        return None
    lhs, rhs = line.split("=", 1)
    lhs_name = lhs.strip()
    if lhs_name.startswith("ROOT "):
        lhs_name = lhs_name[5:]
    lhs_name = lhs_name.lstrip("%").strip()
    rhs = rhs.strip()
    m = re.search(r"([\w\-]+)\(", rhs)
    if not m:
        return None
    op = m.group(1)
    type_str = rhs[: m.start()]
    args = rhs[m.end():]
    depth = 1
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args = args[:i]
                break
    return lhs_name, type_str, op, args


def _dot_flops(type_str: str, args: str, line: str, symtab: dict) -> float:
    _, out_dims = _first_shape(type_str)
    out_prod = 1
    for d in out_dims:
        out_prod *= d
    names = _NAME_RE.findall(args)
    lhs_dims: list[int] = []
    if names:
        lhs_type = symtab.get(names[0].lstrip("%"), "")
        _, lhs_dims = _first_shape(lhs_type)
    cm = _CONTRACT_RE.search(line)
    contracted = 1
    if cm and lhs_dims:
        for idx in _dims(cm.group(1)):
            if idx < len(lhs_dims):
                contracted *= lhs_dims[idx]
    elif lhs_dims:
        contracted = lhs_dims[-1]
    return 2.0 * out_prod * max(contracted, 1)


def trip_count(cond_lines: list[str]) -> int:
    """Largest integer constant in the condition computation (canonical XLA
    counted loops compare the induction var against that constant)."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def analyze(hlo: str) -> dict:
    comps = split_computations(hlo)
    memo: dict[str, Cost] = {}

    symtabs: dict[str, dict] = {}

    def symtab_of(name: str) -> dict:
        if name not in symtabs:
            st = {}
            for line in comps.get(name, []):
                parsed = _parse_line(line)
                if parsed:
                    st[parsed[0]] = parsed[1]
            symtabs[name] = st
        return symtabs[name]

    def cost_of(name: str, stack=()) -> Cost:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return Cost()
        total = Cost()
        symtab = symtab_of(name)
        for line in comps[name]:
            parsed = _parse_line(line)
            if not parsed:
                continue
            _lhs, type_str, op, args = parsed
            if op == "dot":
                total.flops += _dot_flops(type_str, args, line, symtab)
            elif op in ("fusion", "call", "conditional", "custom-call"):
                for cm in _CALL_RE.finditer(line):
                    total.add(cost_of(cm.group(1), stack + (name,)))
            elif op == "while":
                bm, cm2 = _BODY_RE.search(line), _COND_RE.search(line)
                if bm and cm2:
                    t = trip_count(comps.get(cm2.group(1), []))
                    total.add(cost_of(bm.group(1), stack + (name,)), times=t)
            else:
                for kind in _COLL_KINDS:
                    if op == kind or op == kind + "-start":
                        if kind == "reduce-scatter":
                            # per-chip traffic ≈ FULL input tensor (ring RS),
                            # not the 1/n-sized result
                            names = _NAME_RE.findall(args)
                            src = symtab.get(names[0].lstrip("%"), "") if names else ""
                            total.coll_bytes[kind] += _shape_bytes(src) or _shape_bytes(
                                type_str, tuple_max=True
                            )
                        else:
                            total.coll_bytes[kind] += _shape_bytes(
                                type_str, tuple_max=op.endswith("-start")
                            )
                        total.coll_count[kind] += 1
                        break
        memo[name] = total
        return total

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None or entry not in comps:
        # fall back: treat the whole module flat (no loop scaling)
        flat = Cost()
        for name in comps:
            flat.add(cost_of(name))
        result = flat
    else:
        result = cost_of(entry)
    return {
        "flops": result.flops,
        "collective_bytes": {k: result.coll_bytes[k] for k in _COLL_KINDS},
        "collective_counts": {k: result.coll_count[k] for k in _COLL_KINDS},
        "collective_bytes_total": sum(result.coll_bytes.values()),
    }
