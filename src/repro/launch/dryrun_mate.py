import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run for the paper's own workload: the distributed super-key filter
AND the sharded offline index build.

Lowers the corpus-sharded subsumption filter (rows over all mesh axes,
queries replicated, per-table psum) for DWTC-scale inputs and records the
same JSON schema as the LM cells, so benchmarks/roofline.py includes
'mate-filter' rows.  Run after (or alongside) repro.launch.dryrun:

    PYTHONPATH=src python -m repro.launch.dryrun_mate [--impl blocked]

``--build-shards N`` (default 8, 0 disables) additionally exercises the
sharded OFFLINE phase end-to-end on N of the virtual devices: a real (small)
corpus is built through ``MateSession.build(..., mesh=...)`` — unique-value
hashing under shard_map, host-side posting merge — and verified
byte-identical to the single-host build, so the launch smoke path covers
the offline half of the distributed architecture too.
"""

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import distributed
from repro.launch import mesh as meshlib
from repro.launch.dryrun import RESULTS_DIR, parse_collectives
from repro.launch import hlo_cost

# DWTC scale: 1.45B rows; per 2-pod step we filter a 2^30-row shard set
SHAPES = {
    "filter_1g": dict(rows=1 << 30, keys=256, n_tables=1 << 20),
    "filter_dwtc": dict(rows=1_450_000_000, keys=128, n_tables=1 << 20),
}


def lower(shape_name: str, multi_pod: bool, impl: str):
    spec = SHAPES[shape_name]
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    row_axes = tuple(mesh.axis_names)  # rows shard over ALL axes
    n_shards = mesh.size
    rows = -(-spec["rows"] // n_shards) * n_shards
    lanes = 4
    sk_sds = jax.ShapeDtypeStruct(
        (rows, lanes), jnp.uint32, sharding=NamedSharding(mesh, P(row_axes))
    )
    rt_sds = jax.ShapeDtypeStruct(
        (rows,), jnp.int32, sharding=NamedSharding(mesh, P(row_axes))
    )
    q_sds = jax.ShapeDtypeStruct(
        (spec["keys"], lanes), jnp.uint32, sharding=NamedSharding(mesh, P())
    )
    fn = distributed.make_distributed_filter(
        mesh, spec["n_tables"], row_axes, backend=impl
    )
    t0 = time.time()
    with mesh:
        lowered = fn.lower(sk_sds, rt_sds, q_sds)
        compiled = lowered.compile()
    compile_s = time.time() - t0
    text = compiled.as_text()
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    return {
        "arch": "mate-filter",
        "shape": shape_name + ("" if impl == "broadcast" else f"-{impl}"),
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": mesh.size,
        "variant": {"name": impl},
        "compile_seconds": round(compile_s, 1),
        "memory_analysis": {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes")
            if hasattr(mem, k)
        },
        "cost_analysis": {
            k: float(v) for k, v in dict(cost or {}).items()
            if isinstance(v, (int, float)) and (
                k in ("flops",) or k.startswith("bytes accessed"))
        },
        "collectives": parse_collectives(text),
        "hlo_cost": hlo_cost.analyze(text),
        # filter has no params; 'useful work' = 1 subsumption test per
        # (row × key): 4 AND + 4 CMP ops ≈ 8 int ops
        "params_total": 0.0,
        "params_active": 0.0,
        "kind": "filter",
        "global_batch": spec["keys"],
        "seq_len": spec["rows"],
        "probe_ops": float(spec["rows"]) * spec["keys"] * 8,
        "stream_bytes": float(rows) * (lanes * 4 + 4),
    }


def exercise_sharded_build(n_shards: int) -> None:
    """Real (non-dry) sharded offline build on virtual devices, through the
    ``MateSession.build(..., mesh=...)`` surface, verified byte-identical to
    the single-host pass."""
    from repro.core import xash
    from repro.core.index import MateIndex, index_artifacts_equal
    from repro.core.session import DiscoveryConfig, MateSession
    from repro.data import synthetic

    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=60, seed=7))
    mesh = meshlib.make_mesh((n_shards,), ("data",))
    t0 = time.time()
    session = MateSession.build(corpus, DiscoveryConfig(bits=128), mesh=mesh)
    stats = session.build_stats
    ref = MateIndex(
        corpus, cfg=xash.XashConfig(bits=128), use_corpus_char_freq=True
    )
    identical = index_artifacts_equal(session.index, ref)
    print(
        f"[build] sharded offline build on {n_shards} devices: "
        f"{stats.values_total} unique values, {stats.bytes_hashed} bytes "
        f"hashed, hash={stats.hash_seconds:.2f}s merge={stats.merge_seconds:.3f}s "
        f"({time.time()-t0:.1f}s total) identical_to_single_host={identical}",
        flush=True,
    )
    assert identical, "sharded build diverged from the single-host pass"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--impl", default=None, choices=[None, "broadcast", "blocked"])
    ap.add_argument("--shape", default="filter_1g")
    ap.add_argument("--build-shards", type=int, default=8,
                    help="also run the sharded index build on this many "
                         "virtual devices (0 disables)")
    args = ap.parse_args()
    if args.build_shards:
        exercise_sharded_build(args.build_shards)
    impls = [args.impl] if args.impl else ["broadcast", "blocked"]
    out_dir = os.path.abspath(RESULTS_DIR)
    os.makedirs(out_dir, exist_ok=True)
    for impl in impls:
        for mp in (False, True):
            tag = "2x16x16" if mp else "16x16"
            name = f"mate-filter__{args.shape}-{impl}__{tag}.json"
            path = os.path.join(out_dir, name)
            print(f"[lower] {name}", flush=True)
            try:
                rec = lower(args.shape, mp, impl)
            except Exception:
                import traceback

                rec = {"error": traceback.format_exc()}
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if "error" in rec:
                print(rec["error"].splitlines()[-1])
            else:
                ma = rec["memory_analysis"]
                hc = rec["hlo_cost"]
                print(
                    f"  ok {rec['compile_seconds']}s args/dev="
                    f"{ma['argument_size_in_bytes']/1e9:.2f}GB "
                    f"temp={ma['temp_size_in_bytes']/1e9:.2f}GB "
                    f"coll={hc['collective_bytes_total']/1e6:.1f}MB "
                    f"bytes_acc={rec['cost_analysis'].get('bytes accessed', 0)/1e9:.1f}GB",
                    flush=True,
                )


if __name__ == "__main__":
    main()
