"""Mamba2 SSD (state-space duality, arXiv:2405.21060) in pure JAX.

Training/prefill uses the chunked SSD algorithm: within chunks the recurrence
is expanded to a (masked, decay-weighted) attention-like quadratic form that
maps onto the MXU; across chunks a tiny ``lax.scan`` carries the
``[B, heads, d_state, head_dim]`` state.  This is the TPU-native adaptation:
no selective-scan CUDA kernel, the same math re-blocked for systolic matmuls
(DESIGN.md §2).

Decode is the O(1) recurrence: ``h = a·h + dt·(B ⊗ x)``, ``y = C·h + D·x``
plus a ring conv state of width d_conv-1.

Used by mamba2-1.3b (uniform stack) and jamba-v0.1-52b (hybrid blocks;
d_state=16 — the SSD algorithm subsumes the Mamba-1 block at that setting).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.params import ParamSpec


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    return s, d_in, nh


def ssm_specs(cfg: ModelConfig) -> dict:
    s, d_in, nh = _dims(cfg)
    d = cfg.d_model
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return {
        "wz": ParamSpec((d, d_in), ("embed", "ssm_inner")),
        "wx": ParamSpec((d, d_in), ("embed", "ssm_inner")),
        "wbc": ParamSpec((d, 2 * s.n_groups * s.d_state), ("embed", "ssm_state")),
        "wdt": ParamSpec((d, nh), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((s.d_conv, conv_dim), (None, "ssm_inner"), scale=0.5),
        "conv_b": ParamSpec((conv_dim,), ("ssm_inner",), init="zeros"),
        "A_log": ParamSpec((nh,), ("ssm_inner",), init="zeros"),  # A = -exp(0) = -1
        "dt_bias": ParamSpec((nh,), ("ssm_inner",), init="zeros"),
        "D": ParamSpec((nh,), ("ssm_inner",), init="ones"),
        "norm": ParamSpec((d_in,), ("ssm_inner",), init="ones"),
        "wo": ParamSpec((d_in, d), ("ssm_inner", "embed")),
    }


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over seq. xbc: [B, S, C]; w: [K, C]."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return jax.nn.silu(out + b[None, None, :])


def _segsum_decay(la_c: jnp.ndarray) -> jnp.ndarray:
    """la_c: [..., Lc] log-decays → L[i, j] = exp(Σ_{j<t<=i} la) masked i>=j."""
    lc = la_c.shape[-1]
    cs = jnp.cumsum(la_c, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # [..., i, j]
    mask = jnp.tril(jnp.ones((lc, lc), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(
    x: jnp.ndarray,  # [B, S, nh, hd]
    dt: jnp.ndarray,  # [B, S, nh] (post-softplus)
    A: jnp.ndarray,  # [nh] negative
    Bm: jnp.ndarray,  # [B, S, G, ds]
    Cm: jnp.ndarray,  # [B, S, G, ds]
    chunk: int,
    h0: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [B,S,nh,hd], final state [B,nh,ds,hd])."""
    b, s, nh, hd = x.shape
    g, ds = Bm.shape[2], Bm.shape[3]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // chunk
    rep = nh // g

    xc = x.reshape(b, nc, chunk, nh, hd)
    dtc = dt.reshape(b, nc, chunk, nh).astype(jnp.float32)
    Bc = jnp.repeat(Bm.reshape(b, nc, chunk, g, ds), rep, axis=3)  # [B,NC,L,nh,ds]
    Cc = jnp.repeat(Cm.reshape(b, nc, chunk, g, ds), rep, axis=3)
    dtx = (dtc[..., None] * xc.astype(jnp.float32)).astype(x.dtype)  # [B,NC,L,nh,hd]

    la = dtc * A[None, None, None, :]  # log decay, [B,NC,L,nh]
    la_t = la.transpose(0, 1, 3, 2)  # [B,NC,nh,L]
    Lmat = _segsum_decay(la_t)  # [B,NC,nh,L,L]

    # intra-chunk (quadratic, MXU-friendly)
    cb = jnp.einsum("bclhn,bcshn->bchls", Cc, Bc)  # [B,NC,nh,L,L]
    y_intra = jnp.einsum(
        "bchls,bcshp->bclhp", (cb * Lmat).astype(x.dtype), dtx
    )

    # chunk-final states
    cum = jnp.cumsum(la_t, axis=-1)  # [B,NC,nh,L]
    decay_to_end = jnp.exp(cum[..., -1:] - cum)  # [B,NC,nh,L]
    states = jnp.einsum(
        "bcshn,bcshp->bchnp",
        (Bc * decay_to_end.transpose(0, 1, 3, 2)[..., None]).astype(x.dtype),
        dtx,
    )  # [B,NC,nh,ds,hd]
    chunk_decay = jnp.exp(cum[..., -1])  # [B,NC,nh]

    def step(h, inp):
        st, cd = inp  # [B,nh,ds,hd], [B,nh]
        h_out = h  # state entering this chunk
        h_next = h * cd[..., None, None].astype(h.dtype) + st.astype(h.dtype)
        return h_next, h_out

    h_init = (
        jnp.zeros((b, nh, ds, hd), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    )
    h_last, h_prev = jax.lax.scan(
        step,
        h_init,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2)),
    )
    h_prev = h_prev.transpose(1, 0, 2, 3, 4)  # [B,NC,nh,ds,hd]

    # inter-chunk contribution
    in_decay = jnp.exp(cum).transpose(0, 1, 3, 2)  # [B,NC,L,nh]
    y_inter = jnp.einsum(
        "bclhn,bchnp->bclhp",
        (Cc * in_decay[..., None]).astype(x.dtype),
        h_prev.astype(x.dtype),
    )
    y = (y_intra + y_inter).reshape(b, sp, nh, hd)[:, :s]
    return y, h_last


def ssm_fwd(
    p: dict, cfg: ModelConfig, u: jnp.ndarray, state: dict | None = None
) -> tuple[jnp.ndarray, dict]:
    """Full-sequence Mamba2 block. u: [B, S, D] → (y [B,S,D], final state)."""
    s, d_in, nh = _dims(cfg)
    b, slen, _ = u.shape
    z = u @ p["wz"].astype(u.dtype)
    x = u @ p["wx"].astype(u.dtype)
    bc = u @ p["wbc"].astype(u.dtype)
    dt_raw = u @ p["wdt"].astype(u.dtype)

    xbc = jnp.concatenate([x, bc], axis=-1)
    xbc = _causal_conv(xbc, p["conv_w"].astype(u.dtype), p["conv_b"].astype(u.dtype))
    x, bc = xbc[..., :d_in], xbc[..., d_in:]
    Bm = bc[..., : s.n_groups * s.d_state].reshape(b, slen, s.n_groups, s.d_state)
    Cm = bc[..., s.n_groups * s.d_state :].reshape(b, slen, s.n_groups, s.d_state)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = x.reshape(b, slen, nh, s.head_dim)
    y, h_last = ssd_chunked(xh, dt, A, Bm, Cm, cfg.ssm.chunk)
    y = y + xh * p["D"].astype(u.dtype)[None, None, :, None]
    y = y.reshape(b, slen, d_in)

    # gated RMSNorm then output projection
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm"].astype(jnp.float32)).astype(u.dtype)
    out = jnp.einsum("...i,id->...d", y, p["wo"].astype(u.dtype),
                     preferred_element_type=u.dtype)

    # conv ring state must hold the PRE-conv xbc inputs of the last K-1 steps
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    xbc_pre = jnp.concatenate(
        [u @ p["wx"].astype(u.dtype), u @ p["wbc"].astype(u.dtype)], axis=-1
    )
    take = min(s.d_conv - 1, slen)
    conv_state = jnp.zeros((b, s.d_conv - 1, conv_dim), u.dtype)
    conv_state = conv_state.at[:, s.d_conv - 1 - take :, :].set(
        xbc_pre[:, slen - take :, :]
    )
    return out, {"h": h_last, "conv": conv_state, "pos": jnp.full((b,), slen, jnp.int32)}


def ssm_decode(
    p: dict, cfg: ModelConfig, u: jnp.ndarray, state: dict
) -> tuple[jnp.ndarray, dict]:
    """Single-token recurrence. u: [B, 1, D]."""
    s, d_in, nh = _dims(cfg)
    b = u.shape[0]
    u1 = u[:, 0]
    z = u1 @ p["wz"].astype(u.dtype)
    x = u1 @ p["wx"].astype(u.dtype)
    bc = u1 @ p["wbc"].astype(u.dtype)
    dt_raw = u1 @ p["wdt"].astype(u.dtype)

    xbc = jnp.concatenate([x, bc], axis=-1)  # [B, conv_dim]
    window = jnp.concatenate([state["conv"], xbc[:, None, :]], axis=1)  # [B, K, C]
    w = p["conv_w"].astype(u.dtype)
    conv_out = jnp.sum(window * w[None], axis=1) + p["conv_b"].astype(u.dtype)
    xbc_act = jax.nn.silu(conv_out)
    x_act, bc_act = xbc_act[..., :d_in], xbc_act[..., d_in:]
    Bm = bc_act[..., : s.n_groups * s.d_state].reshape(b, s.n_groups, s.d_state)
    Cm = bc_act[..., s.n_groups * s.d_state :].reshape(b, s.n_groups, s.d_state)
    rep = nh // s.n_groups
    Bh = jnp.repeat(Bm, rep, axis=1)  # [B, nh, ds]
    Ch = jnp.repeat(Cm, rep, axis=1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A[None, :])  # [B, nh]
    xh = x_act.reshape(b, nh, s.head_dim).astype(jnp.float32)
    h = state["h"] * a[..., None, None] + jnp.einsum(
        "bhn,bhp->bhnp", Bh.astype(jnp.float32) * dt[..., None], xh
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), h)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, d_in)

    y = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(y * y, axis=-1, keepdims=True)
    y = (y * jax.lax.rsqrt(var + cfg.norm_eps) * p["norm"].astype(jnp.float32)).astype(u.dtype)
    out = jnp.einsum("bi,id->bd", y, p["wo"].astype(u.dtype),
                     preferred_element_type=u.dtype)[:, None, :]
    new_state = {
        "h": h,
        "conv": window[:, 1:, :],
        "pos": state["pos"] + 1,
    }
    return out, new_state


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    s, d_in, nh = _dims(cfg)
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return {
        "h": jnp.zeros((batch, nh, s.d_state, s.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
