"""Model assembly: spec trees, scan-over-layers forward passes, KV caches.

Layer stacks are grouped into *scan groups* of structurally identical blocks
(weights stacked on a leading 'layers' axis, iterated with ``lax.scan``) —
keeps HLO size O(1) in depth, the standard MaxText approach:

  uniform   — n identical decoder layers (attn|mla|ssm mixer + mlp|moe ffn)
  deepseek  — 3 dense layers, then 58 MoE layers (two scan groups) + MTP
  jamba     — 4 blocks × [7 mamba + 1 attn sublayers, alternating mlp/moe]
  vlm       — 8 blocks × [4 self-attn + 1 cross-attn layers]
  encdec    — whisper: bidirectional encoder scan + causal decoder scan with
              cross-attention (frame embeddings from the stubbed frontend)

Every forward returns (logits, aux) where aux carries MoE load-balancing
losses; serve paths return/consume cache pytrees whose leading dim mirrors
the scan group stacking.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import layers, mla, moe, ssm
from repro.models.config import ModelConfig
from repro.models.params import ParamSpec


# ---------------------------------------------------------------------------
# spec utilities
# ---------------------------------------------------------------------------

def stack_specs(tree, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale),
        tree,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def _layer_specs(cfg: ModelConfig, mixer: str, ffn: str) -> dict:
    if mixer == "attn":
        mix = layers.attention_specs(cfg)
    elif mixer == "cross":
        mix = layers.attention_specs(cfg, cross=True)
    elif mixer == "mla":
        mix = mla.mla_specs(cfg)
    elif mixer == "ssm":
        mix = ssm.ssm_specs(cfg)
    else:
        raise ValueError(mixer)
    out = {"mixer_norm": layers.norm_specs(cfg), "mixer": mix}
    if ffn == "mlp":
        out["ffn_norm"] = layers.norm_specs(cfg)
        out["ffn"] = layers.mlp_specs(cfg)
    elif ffn == "moe":
        out["ffn_norm"] = layers.norm_specs(cfg)
        out["ffn"] = moe.moe_specs(cfg)
    elif ffn == "none":
        pass
    else:
        raise ValueError(ffn)
    return out


def _layer_fwd(p, cfg, x, positions, mixer, ffn, *, window=0, enc_out=None,
               enc_positions=None):
    """Residual decoder layer, full-sequence. Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = layers.norm_fwd(p["mixer_norm"], cfg, x)
    if mixer == "attn":
        h = layers.attention_fwd(p["mixer"], cfg, h, positions, causal=True,
                                 window=window)
    elif mixer == "cross":
        h = layers.attention_fwd(p["mixer"], cfg, h, positions, causal=False,
                                 kv_x=enc_out, kv_positions=enc_positions)
    elif mixer == "enc_attn":
        h = layers.attention_fwd(p["mixer"], cfg, h, positions, causal=False)
    elif mixer == "mla":
        h = mla.mla_fwd(p["mixer"], cfg, h, positions)
    elif mixer == "ssm":
        h, _ = ssm.ssm_fwd(p["mixer"], cfg, h)
    x = x + h
    if ffn != "none":
        h = layers.norm_fwd(p["ffn_norm"], cfg, x)
        if ffn == "moe":
            h, a = moe.moe_fwd(p["ffn"], cfg, h)
            aux = aux + a
        else:
            h = layers.mlp_fwd(p["ffn"], cfg, h)
        x = x + h
    return x, aux


def _layer_decode(p, cfg, x, cache, mixer, ffn, *, window=0):
    """Residual decoder layer, one token, with cache. Returns (x, cache)."""
    h = layers.norm_fwd(p["mixer_norm"], cfg, x)
    if mixer == "attn":
        h, cache = layers.attention_decode(p["mixer"], cfg, h, cache, window=window)
    elif mixer == "cross":
        # cross K/V cached at prefill; attend with no causal mask
        q, _, _ = layers._project_qkv(p["mixer"], cfg, h)
        kk = layers.repeat_kv(cache["k"], cfg.n_heads)
        vv = layers.repeat_kv(cache["v"], cfg.n_heads)
        import numpy as np

        sc = jnp.einsum("bshd,bthd->bhst", q, kk).astype(jnp.float32)
        sc = sc / np.sqrt(q.shape[-1])
        pr = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhst,bthd->bshd", pr, vv)
        h = jnp.einsum("bshd,hdo->bso", o, p["mixer"]["wo"].astype(x.dtype))
    elif mixer == "mla":
        h, cache = mla.mla_decode(p["mixer"], cfg, h, cache, absorb=cfg.mla_absorb)
    elif mixer == "ssm":
        h, cache = ssm.ssm_decode(p["mixer"], cfg, h, cache)
    x = x + h
    if ffn != "none":
        h = layers.norm_fwd(p["ffn_norm"], cfg, x)
        if ffn == "moe":
            h, _ = moe.moe_fwd(p["ffn"], cfg, h)
        else:
            h = layers.mlp_fwd(p["ffn"], cfg, h)
        x = x + h
    return x, cache


def _layer_cache(cfg, mixer, batch, max_seq, window=0, enc_len=0, dtype=jnp.bfloat16):
    if mixer == "attn":
        return layers.init_attn_cache(cfg, batch, max_seq, window, dtype)
    if mixer == "cross":
        return {
            "k": jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        }
    if mixer == "mla":
        return mla.init_mla_cache(cfg, batch, max_seq, dtype)
    if mixer == "ssm":
        return ssm.init_ssm_state(cfg, batch, dtype)
    raise ValueError(mixer)


# ---------------------------------------------------------------------------
# group plans: which scan groups a config lowers to
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GroupPlan:
    name: str
    n: int  # scan length (number of stacked blocks)
    sublayers: tuple[tuple[str, str], ...]  # (mixer, ffn) per sublayer in a block


def group_plans(cfg: ModelConfig) -> list[GroupPlan]:
    if cfg.encoder is not None:  # whisper: decoder here; encoder handled apart
        return [GroupPlan("dec", cfg.n_layers, (("attn", "none"), ("cross", "mlp")))]
    if cfg.vision is not None:
        k = cfg.vision.cross_attn_every
        assert cfg.n_layers % k == 0
        subs = tuple([("attn", "mlp")] * (k - 1) + [("cross", "mlp")])
        return [GroupPlan("blocks", cfg.n_layers // k, subs)]
    if cfg.layer_pattern == "jamba":
        per = cfg.attn_every
        assert cfg.n_layers % per == 0
        subs = []
        for i in range(per):
            mixer = "attn" if i == per // 2 else "ssm"
            ffn = "moe" if (cfg.moe is not None and i % cfg.moe.every == cfg.moe.every - 1) else "mlp"
            subs.append((mixer, ffn))
        return [GroupPlan("blocks", cfg.n_layers // per, tuple(subs))]
    if cfg.ssm is not None:  # pure SSM
        return [GroupPlan("layers", cfg.n_layers, (("ssm", "none"),))]
    mixer = "mla" if cfg.mla is not None else "attn"
    if cfg.moe is not None:
        fd = cfg.moe.first_dense
        plans = []
        if fd:
            plans.append(GroupPlan("dense", fd, ((mixer, "mlp"),)))
        if cfg.moe.every > 1:
            subs = tuple(
                (mixer, "moe" if i % cfg.moe.every == cfg.moe.every - 1 else "mlp")
                for i in range(cfg.moe.every)
            )
            plans.append(GroupPlan("moe", (cfg.n_layers - fd) // cfg.moe.every, subs))
        else:
            plans.append(GroupPlan("moe", cfg.n_layers - fd, ((mixer, "moe"),)))
        return plans
    return [GroupPlan("layers", cfg.n_layers, ((mixer, "mlp"),))]


# ---------------------------------------------------------------------------
# model specs
# ---------------------------------------------------------------------------

def model_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    out: dict[str, Any] = {
        "embed": ParamSpec((v, d), ("vocab", "embed"), init="embed"),
        "final_norm": layers.norm_specs(cfg),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = ParamSpec((d, v), ("embed", "vocab"))
    for plan in group_plans(cfg):
        block = {f"s{i}": _layer_specs(cfg, m, f) for i, (m, f) in enumerate(plan.sublayers)}
        out[plan.name] = stack_specs(block, plan.n)
    if cfg.encoder is not None:
        enc_block = {"s0": _layer_specs(cfg, "attn", "mlp")}
        # encoder self-attention is bidirectional; same spec shapes
        out["encoder"] = stack_specs(enc_block, cfg.encoder.n_layers)
        out["enc_final_norm"] = layers.norm_specs(cfg)
        out["enc_pos"] = ParamSpec(
            (cfg.encoder.n_frames, d), ("frames", "embed"), init="embed"
        )
    if cfg.vision is not None:
        out["vision_norm"] = layers.norm_specs(cfg)
    if cfg.mtp_depth:
        mtp_block = {
            "proj": ParamSpec((2 * d, d), ("embed", None)),
            "norm": layers.norm_specs(cfg),
            "layer": _layer_specs(cfg, "mla" if cfg.mla else "attn", "mlp"),
        }
        out["mtp"] = mtp_block
    return out


# ---------------------------------------------------------------------------
# forward (training / scoring)
# ---------------------------------------------------------------------------

REMAT_POLICY = "full"  # 'full' | 'dots' (save matmul outputs: no re-gather
# of FSDP weights in the backward pass, more activation memory) | 'none'


@jax.custom_vjp
def _act_barrier(h):
    # optimization_barrier has no differentiation rule on older jax (0.4.x);
    # gradients pass straight through (the barrier is an identity).
    return jax.lax.optimization_barrier(h)


def _act_barrier_fwd(h):
    return _act_barrier(h), None


def _act_barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


_act_barrier.defvjp(_act_barrier_fwd, _act_barrier_bwd)


def _remat_wrap(body, remat: bool):
    if not remat or REMAT_POLICY == "none":
        return body
    if REMAT_POLICY == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(body)


def _scan_group(params_group, x, positions, cfg, plan: GroupPlan, *, remat: bool,
                enc_out=None, enc_positions=None):
    def block_body(carry, layer_params):
        h, aux = carry
        # barrier: stops XLA commuting convert(dynamic-slice(stack)) into
        # dynamic-slice(convert(stack)), which would materialise an f32 copy
        # of the whole saved-activation stack (2× activation memory).
        h = _act_barrier(h)
        h = layers.constrain_seq(h)
        for i, (mixer, ffn) in enumerate(plan.sublayers):
            window = cfg.sliding_window if mixer == "attn" else 0
            h, a = _layer_fwd(
                layer_params[f"s{i}"], cfg, h, positions, mixer, ffn,
                window=window, enc_out=enc_out, enc_positions=enc_positions,
            )
            aux = aux + a
            h = layers.constrain_seq(h)
        return (h, aux), None

    body = _remat_wrap(block_body, remat)
    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params_group
    )
    return x, aux


def _encode(params, cfg: ModelConfig, frames, patches, dtype=jnp.bfloat16):
    """Run the (stub-fronted) encoder side: whisper frames or VLM patches.
    Returns (enc_out, enc_positions) or (None, None)."""
    if cfg.encoder is not None:
        assert frames is not None, "whisper needs frame embeddings (stub frontend)"
        e = frames.astype(dtype) + params["enc_pos"].astype(dtype)[None]
        e_pos = jnp.arange(frames.shape[1], dtype=jnp.int32)

        def enc_body(carry, lp):
            h, _ = carry
            hh = layers.norm_fwd(lp["s0"]["mixer_norm"], cfg, h)
            hh = layers.attention_fwd(lp["s0"]["mixer"], cfg, hh, e_pos, causal=False)
            h = h + hh
            hh = layers.norm_fwd(lp["s0"]["ffn_norm"], cfg, h)
            h = h + layers.mlp_fwd(lp["s0"]["ffn"], cfg, hh)
            return (h, jnp.zeros((), jnp.float32)), None

        (e, _), _ = jax.lax.scan(
            enc_body, (e, jnp.zeros((), jnp.float32)), params["encoder"]
        )
        return layers.norm_fwd(params["enc_final_norm"], cfg, e), e_pos
    if cfg.vision is not None:
        assert patches is not None, "vlm needs patch embeddings (stub frontend)"
        enc_out = layers.norm_fwd(params["vision_norm"], cfg, patches.astype(dtype))
        return enc_out, jnp.arange(patches.shape[1], dtype=jnp.int32)
    return None, None


def forward_hidden(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    *,
    frames: jnp.ndarray | None = None,
    patches: jnp.ndarray | None = None,
    remat: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward WITHOUT the LM head.

    tokens: int32[B, S] → (hidden bf16[B,S,D] post final-norm, aux).
    The loss head is applied chunked in train/step.py so [B,S,V] logits never
    materialise at 150k vocabs.
    """
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    aux = jnp.zeros((), jnp.float32)
    enc_out, enc_positions = _encode(params, cfg, frames, patches)

    for plan in group_plans(cfg):
        x, a = _scan_group(
            params[plan.name], x, positions, cfg, plan, remat=remat,
            enc_out=enc_out, enc_positions=enc_positions,
        )
        aux = aux + a

    return layers.norm_fwd(params["final_norm"], cfg, x), aux


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    *,
    frames: jnp.ndarray | None = None,
    patches: jnp.ndarray | None = None,
    remat: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. tokens: int32[B, S] → (logits f32[B,S,V], aux)."""
    x, aux = forward_hidden(
        params, cfg, tokens, frames=frames, patches=patches, remat=remat
    )
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(x.dtype)
    logits = (x @ head).astype(jnp.float32)
    return logits, aux


def mtp_hidden(params, cfg, tokens, hidden):
    """DeepSeek MTP module hidden states: predict token t+2 from
    [h_t ; emb(token_{t+1})]."""
    if not cfg.mtp_depth:
        return None
    p = params["mtp"]
    s = tokens.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    nxt = params["embed"].astype(hidden.dtype)[
        jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    ]
    h = jnp.concatenate([hidden, nxt], axis=-1) @ p["proj"].astype(hidden.dtype)
    h, _ = _layer_fwd(p["layer"], cfg, h, positions, "mla" if cfg.mla else "attn", "mlp")
    return layers.norm_fwd(p["norm"], cfg, h)


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16,
               enc_len: int = 0) -> dict:
    cache: dict[str, Any] = {}
    for plan in group_plans(cfg):
        sub = {}
        for i, (mixer, _f) in enumerate(plan.sublayers):
            if mixer in ("attn", "mla", "ssm", "cross"):
                window = cfg.sliding_window if mixer == "attn" else 0
                one = _layer_cache(cfg, mixer, batch, max_seq, window, enc_len, dtype)
                sub[f"s{i}"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (plan.n,) + a.shape).copy()
                    if plan.n > 1
                    else a[None],
                    one,
                )
        cache[plan.name] = sub
    return cache


def decode_step(
    params: dict,
    cfg: ModelConfig,
    token: jnp.ndarray,  # int32 [B]
    cache: dict,
) -> tuple[jnp.ndarray, dict]:
    """One decode step: next-token logits [B, V] + updated cache."""
    x = params["embed"].astype(jnp.bfloat16)[token][:, None, :]
    new_cache: dict[str, Any] = {}
    for plan in group_plans(cfg):
        pgroup = params[plan.name]
        cgroup = cache[plan.name]

        def block_body(h, xs):
            lp, lc = xs
            lc_new = dict(lc)
            for i, (mixer, ffn) in enumerate(plan.sublayers):
                window = cfg.sliding_window if mixer == "attn" else 0
                ci = lc.get(f"s{i}")
                h, c2 = _layer_decode(
                    lp[f"s{i}"], cfg, h, ci, mixer, ffn, window=window
                )
                if c2 is not None:
                    lc_new[f"s{i}"] = c2
            return h, lc_new

        x, cg_new = jax.lax.scan(block_body, x, (pgroup, cgroup))
        new_cache[plan.name] = cg_new
    x = layers.norm_fwd(params["final_norm"], cfg, x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"]).astype(x.dtype)
    logits = (x[:, 0] @ head).astype(jnp.float32)
    return logits, new_cache


def prefill(
    params: dict,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    max_seq: int,
    *,
    frames: jnp.ndarray | None = None,
    patches: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, dict]:
    """Run the prompt, build the cache. Returns (last-token logits, cache).

    Implemented as full-sequence forward + cache writeback: attention layers
    recompute K/V into the cache (cheap relative to the forward itself);
    SSM layers get their final state from the chunked scan.
    """
    b, s = tokens.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    x = params["embed"].astype(jnp.bfloat16)[tokens]
    cache = init_cache(cfg, b, max_seq, enc_len=(
        cfg.encoder.n_frames if cfg.encoder is not None
        else (cfg.vision.n_tokens if cfg.vision is not None else 0)
    ))

    enc_out, enc_positions = _encode(params, cfg, frames, patches)

    new_cache: dict[str, Any] = {}
    for plan in group_plans(cfg):
        pgroup = params[plan.name]
        cgroup = cache[plan.name]

        def block_body(carry, xs):
            h = carry
            h = layers.constrain_seq(h)
            lp, lc = xs
            lc_new = dict(lc)
            for i, (mixer, ffn) in enumerate(plan.sublayers):
                window = cfg.sliding_window if mixer == "attn" else 0
                spec = lp[f"s{i}"]
                if mixer == "attn":
                    hh = layers.norm_fwd(spec["mixer_norm"], cfg, h)
                    q, k, v = layers._project_qkv(spec["mixer"], cfg, hh)
                    k = layers.rope(k, positions, cfg.rope_theta)
                    ci = lc[f"s{i}"]
                    slots = ci["k"].shape[1]
                    if window > 0 and slots < s:
                        ck = ci["k"].at[:, :, :, :].set(
                            jax.lax.dynamic_slice_in_dim(k, s - slots, slots, 1)
                        )
                        cv = ci["v"].at[:, :, :, :].set(
                            jax.lax.dynamic_slice_in_dim(v, s - slots, slots, 1)
                        )
                        spos = jnp.broadcast_to(
                            jnp.arange(s - slots, s, dtype=jnp.int32)[None], (b, slots)
                        )
                        # ring layout: slot = pos % slots
                        order = jnp.argsort(spos[0] % slots)
                        ck, cv = ck[:, order], cv[:, order]
                        spos = spos[:, order]
                    else:
                        ck = ci["k"].at[:, :s].set(k)
                        cv = ci["v"].at[:, :s].set(v)
                        spos = ci["slot_pos"].at[:, :s].set(
                            jnp.arange(s, dtype=jnp.int32)[None]
                        )
                    lc_new[f"s{i}"] = {
                        "k": ck, "v": cv,
                        "pos": jnp.full((b,), s, jnp.int32),
                        "slot_pos": spos,
                    }
                    h, _ = _layer_fwd(spec, cfg, h, positions, mixer, ffn, window=window)
                elif mixer == "mla":
                    hh = layers.norm_fwd(spec["mixer_norm"], cfg, h)
                    _q, ckv1, kr1 = mla._latents(spec["mixer"], cfg, hh, positions)
                    ci = lc[f"s{i}"]
                    lc_new[f"s{i}"] = {
                        "ckv": ci["ckv"].at[:, :s].set(ckv1),
                        "kr": ci["kr"].at[:, :s].set(kr1),
                        "pos": jnp.full((b,), s, jnp.int32),
                    }
                    h, _ = _layer_fwd(spec, cfg, h, positions, mixer, ffn)
                elif mixer == "ssm":
                    hh = layers.norm_fwd(spec["mixer_norm"], cfg, h)
                    y, st = ssm.ssm_fwd(spec["mixer"], cfg, hh)
                    h = h + y
                    if ffn != "none":
                        hh = layers.norm_fwd(spec["ffn_norm"], cfg, h)
                        if ffn == "moe":
                            hh, _a = moe.moe_fwd(spec["ffn"], cfg, hh)
                        else:
                            hh = layers.mlp_fwd(spec["ffn"], cfg, hh)
                        h = h + hh
                    lc_new[f"s{i}"] = st
                elif mixer == "cross":
                    hh = layers.norm_fwd(spec["mixer_norm"], cfg, h)
                    kv_src = enc_out
                    _q, ck, cv = layers._project_qkv(spec["mixer"], cfg, hh, kv_src)
                    lc_new[f"s{i}"] = {"k": ck, "v": cv}
                    h, _ = _layer_fwd(
                        spec, cfg, h, positions, mixer, ffn,
                        enc_out=enc_out, enc_positions=enc_positions,
                    )
                else:
                    h, _ = _layer_fwd(spec, cfg, h, positions, mixer, ffn)
                h = layers.constrain_seq(h)
            return h, lc_new

        x, cg_new = jax.lax.scan(block_body, x, (pgroup, cgroup))
        new_cache[plan.name] = cg_new

    x = layers.norm_fwd(params["final_norm"], cfg, x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"]).astype(x.dtype)
    logits = (x[:, -1] @ head).astype(jnp.float32)
    return logits, new_cache
