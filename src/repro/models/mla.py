"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and KV are projected through low-rank latents; the KV cache stores
only the compressed latent ``c_kv`` (kv_lora_rank) plus the shared RoPE key
(qk_rope_head_dim) per token — 576 values/token for V3 instead of
2·128·128 = 32768 for vanilla MHA.

Two decode paths:
  * naive  — decompress the whole cache to per-head K/V each step
             (paper-faithful-to-DeepSeek formulation; memory-bound);
  * absorb — fold the decompression matrices into the query/output
             projections so attention runs directly in latent space
             (the optimisation DeepSeek describes; our §Perf hillclimb flips
             this flag and measures the roofline delta).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.params import ParamSpec


def mla_specs(cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    return {
        "wdq": ParamSpec((d, m.q_lora_rank), ("embed", "q_lora")),
        "q_norm": ParamSpec((m.q_lora_rank,), ("q_lora",), init="ones"),
        "wuq": ParamSpec((m.q_lora_rank, h, dn + dr), ("q_lora", "heads", "head_dim")),
        "wdkv": ParamSpec((d, m.kv_lora_rank + dr), ("embed", "kv_lora")),
        "kv_norm": ParamSpec((m.kv_lora_rank,), ("kv_lora",), init="ones"),
        "wuk": ParamSpec((m.kv_lora_rank, h, dn), ("kv_lora", "heads", "head_dim")),
        "wuv": ParamSpec((m.kv_lora_rank, h, dv), ("kv_lora", "heads", "head_dim")),
        "wo": ParamSpec((h, dv, d), ("heads", "head_dim", "embed")),
    }


def _latents(p, cfg, x, positions):
    """Compressed latents for tokens x: (q [B,S,H,dn+dr], c_kv [B,S,r], k_rope [B,S,dr])."""
    m = cfg.mla
    dn, dr = m.qk_nope_head_dim, m.qk_rope_head_dim
    cq = layers.rms_norm_simple(x @ p["wdq"].astype(x.dtype), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wuq"].astype(x.dtype))
    qn, qr = q[..., :dn], q[..., dn:]
    qr = layers.rope(qr, positions, cfg.rope_theta)
    ckv_full = x @ p["wdkv"].astype(x.dtype)
    ckv = layers.rms_norm_simple(
        ckv_full[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps
    )
    kr = ckv_full[..., m.kv_lora_rank :][:, :, None, :]  # [B,S,1,dr]
    kr = layers.rope(kr, positions, cfg.rope_theta)[:, :, 0]
    return jnp.concatenate([qn, qr], axis=-1), ckv, kr


def mla_fwd(p: dict, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray,
            causal: bool = True) -> jnp.ndarray:
    """Full-sequence MLA (training/prefill)."""
    m = cfg.mla
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    q, ckv, kr = _latents(p, cfg, x, positions)
    kn = jnp.einsum("btr,rhk->bthk", ckv, p["wuk"].astype(x.dtype))
    v = jnp.einsum("btr,rhk->bthk", ckv, p["wuv"].astype(x.dtype))
    k = jnp.concatenate(
        [kn, jnp.broadcast_to(kr[:, :, None, :], kn.shape[:3] + (dr,))], axis=-1
    )
    if x.shape[1] ** 2 <= layers.FLASH_THRESHOLD ** 2 // 16:
        bias = layers._mask_bias(positions, positions, causal, 0)
        out = layers._sdpa_full(q, k, v, bias)
    else:
        out = layers._sdpa_flash(q, k, v, positions, positions, causal, 0)
    return jnp.einsum("bshd,hdo->bso", out, p["wo"].astype(x.dtype),
                      preferred_element_type=x.dtype)


def mla_decode(
    p: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,
    cache: dict,
    absorb: bool = True,
) -> tuple[jnp.ndarray, dict]:
    """Single-token decode. cache: {'ckv': [B,S,r], 'kr': [B,S,dr], 'pos': [B]}."""
    m = cfg.mla
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    b = x.shape[0]
    pos = cache["pos"]
    q, ckv1, kr1 = _latents(p, cfg, x, pos[:, None])  # q: [B,1,H,dn+dr]
    ckv = layers._cache_write(cache["ckv"], pos, ckv1[:, 0])
    kr = layers._cache_write(cache["kr"], pos, kr1[:, 0])
    slots = ckv.shape[1]
    t_idx = jnp.arange(slots, dtype=jnp.int32)
    valid = t_idx[None, :] <= pos[:, None]  # [B, S]
    scale = 1.0 / np.sqrt(dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]

    if absorb:
        # fold W_uk into the query: score = (qn W_uk^T) · ckv + qr · kr
        q_lat = jnp.einsum("bshk,rhk->bshr", qn, p["wuk"].astype(x.dtype))
        sc = (
            jnp.einsum("bshr,btr->bhst", q_lat, ckv)
            + jnp.einsum("bshk,btk->bhst", qr, kr)
        ).astype(jnp.float32) * scale
        sc = sc + jnp.where(valid, 0.0, layers.NEG_INF)[:, None, None, :]
        probs = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
        # attend in latent space, then decompress once per step
        lat = jnp.einsum("bhst,btr->bshr", probs, ckv)  # [B,1,H,r]
        out = jnp.einsum("bshr,rhk->bshk", lat, p["wuv"].astype(x.dtype))
    else:
        kn = jnp.einsum("btr,rhk->bthk", ckv, p["wuk"].astype(x.dtype))
        v = jnp.einsum("btr,rhk->bthk", ckv, p["wuv"].astype(x.dtype))
        k = jnp.concatenate(
            [kn, jnp.broadcast_to(kr[:, :, None, :], kn.shape[:3] + (dr,))], axis=-1
        )
        sc = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
        sc = sc + jnp.where(valid, 0.0, layers.NEG_INF)[:, None, None, :]
        probs = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
        out = jnp.einsum("bhst,bthd->bshd", probs, v)
    y = jnp.einsum("bshd,hdo->bso", out, p["wo"].astype(x.dtype),
                   preferred_element_type=x.dtype)
    return y, {"ckv": ckv, "kr": kr, "pos": pos + 1}


def init_mla_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
        "kr": jnp.zeros((batch, max_seq, m.qk_rope_head_dim), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
    }
