"""Model configuration covering the ten assigned architectures.

One composable ``ModelConfig`` describes every family: dense decoder
(GQA/bias/qk_norm/SWA), MoE (shared+routed), MLA (+MTP), enc-dec (Whisper),
cross-attention VLM, hybrid Mamba+attention (Jamba), and attention-free SSM
(Mamba2).  Configs for the assigned archs live in ``repro.configs.<id>``.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0  # shared experts, always active
    d_ff_shared: int = 0  # width of the fused shared-expert MLP (0 → none)
    first_dense: int = 0  # leading dense layers (DeepSeek: 3)
    every: int = 1  # MoE every k-th layer (Jamba: 2)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder; the conv/mel frontend is a STUB — inputs are
    precomputed frame embeddings [batch, n_frames, d_model]."""

    n_layers: int
    n_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    """Llama-3.2-Vision-style stub: precomputed patch/tile embeddings
    [batch, n_tokens, d_model]; decoder gets cross-attn every k layers."""

    n_tokens: int = 1601
    cross_attn_every: int = 5


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 → d_model // n_heads
    # attention details
    attn_bias: bool = False  # qwen1.5: QKV bias
    qk_norm: bool = False  # qwen3
    rope_theta: float = 10_000.0
    sliding_window: int = 0  # 0 → full attention (danube: SWA)
    # norms / act
    norm_type: str = "rms"  # 'rms' | 'ln' (starcoder2, whisper: ln)
    norm_eps: float = 1e-6
    act: str = "silu"  # 'silu' | 'gelu'
    glu: bool = True  # gated MLP (llama-style); False → fc-gelu-fc
    tie_embeddings: bool = False
    # families
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    mla_absorb: bool = True  # decode in latent space (False = naive baseline)
    mtp_depth: int = 0  # DeepSeek multi-token prediction modules
    ssm: SSMConfig | None = None
    layer_pattern: str = "uniform"  # 'uniform' | 'jamba'
    attn_every: int = 8  # jamba: 1 attn per 8 layers
    encoder: EncoderConfig | None = None
    vision: VisionConfig | None = None
    # numerics
    dtype: str = "bfloat16"
    # training-time upper bound for learned/rope position handling
    max_seq_len: int = 524_288

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def is_attention_free(self) -> bool:
        return self.ssm is not None and self.layer_pattern == "uniform"

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode (long_500k) is admissible: SSM,
        hybrid, or sliding-window attention."""
        return self.ssm is not None or self.sliding_window > 0

    def moe_layer_ids(self) -> list[int]:
        if self.moe is None:
            return []
        return [
            i
            for i in range(self.n_layers)
            if i >= self.moe.first_dense and (i % self.moe.every == self.moe.every - 1 if self.moe.every > 1 else True)
        ]

    def params_count(self) -> dict[str, float]:
        """Approximate parameter counts (total and active) for roofline's
        MODEL_FLOPS = 6·N·D."""
        d, h = self.d_model, self.head_dim
        v = self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.mla is not None:
            m = self.mla
            attn = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (
                m.qk_nope_head_dim + m.qk_rope_head_dim
            )
            attn += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            attn += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            attn += self.n_heads * m.v_head_dim * d
        else:
            attn = d * self.n_heads * h + 2 * d * self.n_kv_heads * h + self.n_heads * h * d
        mlp_dense = d * self.d_ff * (3 if self.glu else 2)
        n_attn_layers = (
            self.n_layers
            if self.ssm is None
            else (self.n_layers // self.attn_every if self.layer_pattern == "jamba" else 0)
        )
        n_ssm_layers = 0
        if self.ssm is not None:
            n_ssm_layers = (
                self.n_layers - n_attn_layers
                if self.layer_pattern == "jamba"
                else self.n_layers
            )
        s = self.ssm
        ssm_l = 0
        if s is not None:
            d_in = s.expand * d
            ssm_l = d * 2 * d_in + d * (2 * s.n_groups * s.d_state) + d_in * d + d_in * d // s.head_dim
        total = emb + n_attn_layers * attn + n_ssm_layers * ssm_l
        active = total
        if self.moe is not None:
            mo = self.moe
            moe_ids = self.moe_layer_ids()
            n_moe = len(moe_ids)
            n_dense_mlp = self.n_layers - n_moe if self.ssm is None else (
                self.n_layers - n_moe
            )
            expert = d * mo.d_ff_expert * 3
            shared = d * (mo.d_ff_shared or mo.d_ff_expert * mo.n_shared) * 3 if mo.n_shared else 0
            router = d * mo.n_routed
            total += n_moe * (mo.n_routed * expert + shared + router)
            total += n_dense_mlp * mlp_dense
            active += n_moe * (mo.top_k * expert + shared + router)
            active += n_dense_mlp * mlp_dense
        else:
            mlp_layers = self.n_layers if self.ssm is None or self.layer_pattern == "jamba" else 0
            total += mlp_layers * mlp_dense
            active += mlp_layers * mlp_dense
        if self.encoder is not None:
            enc_l = attn + mlp_dense + attn  # self+cross handled roughly
            total += self.encoder.n_layers * enc_l
            active += self.encoder.n_layers * enc_l
        return {"total": float(total), "active": float(active)}
