"""Parameter specification / materialisation.

Models are described as pytrees of ``ParamSpec`` (shape + logical axes +
initialiser).  Three consumers:

  * ``materialize``      — real arrays (smoke tests, examples, training);
  * ``abstract``         — ShapeDtypeStructs (dry-run: no allocation);
  * ``partition_specs``  — logical axes → mesh PartitionSpec via rule table.

Logical axis names used across the zoo:
  'vocab', 'embed', 'heads', 'kv_heads', 'head_dim', 'mlp', 'experts',
  'ssm_inner', 'ssm_state', 'layers' (scan-stacked), None (replicated).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Any, ...]  # logical axis per dim (str | None)
    init: str = "normal"  # 'normal' | 'zeros' | 'ones' | 'embed'
    scale: float | None = None  # None → 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_array(spec: ParamSpec, key, dtype) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape, jnp.float32) * 0.02).astype(dtype)
    fan_in = spec.shape[0] if len(spec.shape) > 1 else max(spec.shape[0], 1)
    if len(spec.shape) >= 3:  # stacked/experts: fan-in is the contract dim
        fan_in = spec.shape[-2]
    scale = spec.scale if spec.scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)


def materialize(specs, key, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(
        specs, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    arrays = [_init_array(s, k, dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrays)


def abstract(specs, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# default logical→mesh rules (single- and multi-pod): TP on 'model',
# FSDP on 'data' (embed/contract dims), experts on 'model' (EP).
DEFAULT_RULES: dict[str, Any] = {
    "vocab": "model",
    "embed": "data",  # FSDP shard of the contracting dim
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "layers": None,
    "q_lora": None,
    "kv_lora": None,
    "frames": None,
}


def spec_to_pspec(spec: ParamSpec, rules: dict[str, Any]) -> P:
    return P(*(rules.get(a) if a is not None else None for a in spec.axes))


def partition_specs(specs, rules: dict[str, Any] | None = None):
    rules = rules or DEFAULT_RULES
    return jax.tree.map(
        lambda s: spec_to_pspec(s, rules),
        specs,
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


def shardings(specs, mesh: Mesh, rules: dict[str, Any] | None = None):
    pspecs = partition_specs(specs, rules)
    return jax.tree.map(lambda p: NamedSharding(mesh, p), pspecs)


def validate_divisibility(specs, mesh: Mesh, rules: dict[str, Any] | None = None):
    """Replace rules that don't divide evenly by replication (e.g. 8 KV heads
    on a 16-way model axis).  Returns adjusted per-leaf pspecs."""
    rules = rules or DEFAULT_RULES

    def fix(spec: ParamSpec) -> P:
        out = []
        for dim, axis in zip(spec.shape, spec.axes):
            mesh_axis = rules.get(axis) if axis is not None else None
            if mesh_axis is None:
                out.append(None)
                continue
            size = (
                int(np.prod([mesh.shape[a] for a in mesh_axis]))
                if isinstance(mesh_axis, tuple)
                else mesh.shape[mesh_axis]
            )
            out.append(mesh_axis if dim % size == 0 else None)
        return P(*out)

    return jax.tree.map(fix, specs, is_leaf=lambda x: isinstance(x, ParamSpec))
