"""Core layers: norms, RoPE, attention (all flavours), MLP.

Conventions:
  * activations bf16, softmax/norm statistics f32;
  * attention is computed with KV heads repeated to full heads — keeps the
    'heads' axis cleanly TP-sharded for every assigned arch; the KV *cache*
    still stores only ``n_kv_heads`` (GQA memory win is preserved where it
    matters);
  * sequences longer than ``FLASH_THRESHOLD`` use a chunked online-softmax
    (flash-style) path so 32k-prefill activations never materialise S×S;
  * decode uses a position-indexed cache update; sliding-window layers use a
    ring buffer of ``window`` slots.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.params import ParamSpec

FLASH_THRESHOLD = 8192
FLASH_BLOCK_Q = 1024
FLASH_BLOCK_KV = 1024
NEG_INF = -1e30

# ---------------------------------------------------------------------------
# activation sharding constraints
#
# GSPMD's propagation through scan bodies routinely drops the batch sharding
# of activations (replicating them per device).  The launcher/dry-run enables
# explicit constraints at layer boundaries and inside the flash/CE loops —
# the same discipline MaxText applies.  Disabled (no-op) unless a mesh is
# installed, so CPU unit tests are unaffected.
# ---------------------------------------------------------------------------

_ACT_BATCH_AXES: tuple | None = None
_ACT_MODEL_AXIS: str | None = None
_ACT_BATCH_SIZE: int = 1
_ACT_MODEL_SIZE: int = 1


def enable_activation_sharding(mesh, model_axis: str = "model"):
    """Enable layer-boundary activation constraints for ``mesh`` (uses axes
    'pod'/'data' for batch and ``model_axis`` for heads/experts)."""
    global _ACT_BATCH_AXES, _ACT_MODEL_AXIS, _ACT_BATCH_SIZE, _ACT_MODEL_SIZE
    _ACT_BATCH_AXES = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    _ACT_MODEL_AXIS = model_axis if model_axis in mesh.axis_names else None
    _ACT_BATCH_SIZE = int(np.prod([mesh.shape[a] for a in _ACT_BATCH_AXES])) if _ACT_BATCH_AXES else 1
    _ACT_MODEL_SIZE = mesh.shape[model_axis] if _ACT_MODEL_AXIS else 1


def disable_activation_sharding():
    global _ACT_BATCH_AXES, _ACT_MODEL_AXIS
    _ACT_BATCH_AXES = None
    _ACT_MODEL_AXIS = None


SEQ_SHARD = False  # Megatron-style sequence parallelism for the residual
# stream: shard [B,S,D] activations on S over 'model' between layers, so TP
# projections end in reduce-scatters and only GQA K/V (≪ d_model wide) are
# gathered to full sequence length. Enabled per-variant by the launcher.


def constrain_seq(x: jnp.ndarray):
    """[B, S, D] → P(batch_axes, model, None) when enabled and divisible."""
    if (
        not SEQ_SHARD
        or _ACT_BATCH_AXES is None
        or _ACT_MODEL_AXIS is None
        or x.ndim != 3
        or x.shape[1] % _ACT_MODEL_SIZE != 0
    ):
        return constrain_batch(x, 0)
    from jax.sharding import PartitionSpec as _P

    spec = [None, _ACT_MODEL_AXIS, None]
    if x.shape[0] % _ACT_BATCH_SIZE == 0:
        spec[0] = _ACT_BATCH_AXES
    return jax.lax.with_sharding_constraint(x, _P(*spec))


def constrain_batch(x: jnp.ndarray, batch_dim: int = 0, heads_dim: int | None = None):
    """Constrain activation: batch dim over ('pod','data'), optional heads
    dim over 'model'; other dims replicated. No-op when sharding disabled;
    per-dim fallback to replication when sizes don't divide."""
    if _ACT_BATCH_AXES is None or x.ndim == 0:
        return x
    spec = [None] * x.ndim
    if x.shape[batch_dim] % _ACT_BATCH_SIZE == 0:
        spec[batch_dim] = _ACT_BATCH_AXES
    if (
        heads_dim is not None
        and _ACT_MODEL_AXIS is not None
        and x.shape[heads_dim] % _ACT_MODEL_SIZE == 0
    ):
        spec[heads_dim] = _ACT_MODEL_AXIS
    if all(s is None for s in spec):
        return x
    from jax.sharding import PartitionSpec as _P

    return jax.lax.with_sharding_constraint(x, _P(*spec))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_specs(cfg: ModelConfig, d: int | None = None) -> dict:
    d = d or cfg.d_model
    if cfg.norm_type == "rms":
        return {"scale": ParamSpec((d,), ("embed",), init="ones")}
    return {
        "scale": ParamSpec((d,), ("embed",), init="ones"),
        "bias": ParamSpec((d,), ("embed",), init="zeros"),
    }


def norm_fwd(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """RMS/LayerNorm with f32 STATISTICS but no full-tensor f32 copy.

    Statistics (mean/variance) are accumulated in f32; the normalised tensor
    is produced directly in x.dtype.  Materialising `x.astype(f32)` at layer
    entry makes XLA save an f32 copy of every scan carry (2× activation
    memory, observed in the dry-run HLO) for the backward pass.
    """
    var = jnp.mean(
        jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True
    )
    if cfg.norm_type == "rms":
        inv = jax.lax.rsqrt(var + cfg.norm_eps)
        return (x * inv.astype(x.dtype)) * p["scale"].astype(x.dtype)
    mu = jnp.mean(x.astype(jnp.float32), axis=-1, keepdims=True)
    var = var - jnp.square(mu)
    inv = jax.lax.rsqrt(var + cfg.norm_eps)
    out = (x - mu.astype(x.dtype)) * inv.astype(x.dtype)
    return out * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)


def rms_norm_simple(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., S, H, D] (D even), positions: [..., S] int32."""
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (np.arange(0, d, 2, dtype=np.float32) / d))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, D/2]
    sin, cos = jnp.sin(angles)[..., None, :], jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention_specs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, hd, kv = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.n_kv_heads
    p = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.attn_bias:
        p["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), init="zeros")
        p["bk"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
        p["bv"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
        p["k_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
    return p


def _project_qkv(p: dict, cfg: ModelConfig, x, kv_x=None):
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", kv_x, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", kv_x, p["wv"].astype(x.dtype))
    if cfg.attn_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm_simple(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm_simple(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def repeat_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """[B, T, Kv, D] -> [B, T, H, D]."""
    kv = k.shape[-2]
    if kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // kv, axis=-2)


def _mask_bias(q_pos, k_pos, causal: bool, window: int) -> jnp.ndarray:
    """additive bias [..., S_q, S_k] from position tensors."""
    ok = jnp.ones(q_pos.shape[-1:] + k_pos.shape[-1:], dtype=bool)
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    if causal:
        ok = ok & (diff >= 0)
    if window > 0:
        ok = ok & (diff < window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa_full(q, k, v, bias):
    """q: [B,S,H,D]; k,v: [B,T,H,D]; bias: [S,T] or [B,S,T]."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    if bias.ndim == 2:
        scores = scores + bias[None, None]
    else:
        scores = scores + bias[:, None]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def _sdpa_flash(q, k, v, q_pos, k_pos, causal, window,
                block_q=FLASH_BLOCK_Q, block_kv=FLASH_BLOCK_KV):
    """Chunked online-softmax attention; never materialises S×T.

    q: [B,S,H,D]; k,v: [B,T,H,D]; positions 1-D int32.
    """
    b, s, h, d = q.shape
    dv = v.shape[-1]  # MLA: v head dim differs from q/k
    t = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    s_pad = -(-s // block_q) * block_q
    t_pad = -(-t // block_kv) * block_kv
    qp = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    qpos = jnp.pad(q_pos, (0, s_pad - s), constant_values=-(10 ** 9))
    kpos = jnp.pad(k_pos, (0, t_pad - t), constant_values=2 ** 30)

    nq, nk = s_pad // block_q, t_pad // block_kv
    qp = qp.reshape(b, nq, block_q, h, d)
    kp = kp.reshape(b, nk, block_kv, h, d)
    vp = vp.reshape(b, nk, block_kv, h, dv)
    qpos = qpos.reshape(nq, block_q)
    kpos = kpos.reshape(nk, block_kv)

    def q_block(args):
        qb, qposb = args  # [b, block_q, h, d], [block_q]

        @jax.checkpoint  # flash backward: recompute block scores, never save
        def kv_step(carry, inp):  # the [b,h,q,k] probabilities
            m, l, acc = carry
            kb, vb, kposb = inp
            kb = constrain_batch(kb, 0, 2)
            vb = constrain_batch(vb, 0, 2)
            sc = jnp.einsum("bqhd,bkhd->bhqk", qb, kb).astype(jnp.float32) * scale
            sc = constrain_batch(sc, 0, 1)
            sc = sc + _mask_bias(qposb, kposb, causal, window)[None, None]
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(qb.dtype), vb
            ).astype(jnp.float32)
            acc_new = constrain_batch(acc_new, 0, 1)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, h, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, block_q), jnp.float32)
        a0 = jnp.zeros((b, h, block_q, dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kp.transpose(1, 0, 2, 3, 4), vp.transpose(1, 0, 2, 3, 4), kpos),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out = out.transpose(0, 2, 1, 3).astype(qb.dtype)  # [b, block_q, h, d]
        return constrain_batch(out, 0, 2)

    out = jax.lax.map(q_block, (qp.transpose(1, 0, 2, 3, 4), qpos))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, s_pad, h, dv)
    return out[:, :s]


def attention_fwd(
    p: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    kv_x: jnp.ndarray | None = None,
    kv_positions: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Full-sequence attention (training / prefill).

    x: [B, S, D]; positions: [S] int32. kv_x: cross-attention memory.
    """
    q, k, v = _project_qkv(p, cfg, x, kv_x)
    if kv_x is None:  # self-attention → RoPE
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        k_pos = positions
    else:
        k_pos = (
            kv_positions
            if kv_positions is not None
            else jnp.arange(kv_x.shape[1], dtype=jnp.int32)
        )
    k = repeat_kv(k, cfg.n_heads)
    v = repeat_kv(v, cfg.n_heads)
    if x.shape[1] * k.shape[1] <= FLASH_THRESHOLD * FLASH_THRESHOLD // 16:
        bias = _mask_bias(positions, k_pos, causal, window)
        out = _sdpa_full(q, k, v, bias)
    else:
        out = _sdpa_flash(q, k, v, positions, k_pos, causal, window)
    return jnp.einsum("bshd,hdo->bso", out, p["wo"].astype(x.dtype),
                      preferred_element_type=x.dtype)


CACHE_ONEHOT_UPDATE = True  # one-hot multiply-add cache writes: elementwise,
# so GSPMD partitions them on ANY cache sharding. dynamic_update_slice into a
# sequence-sharded cache triggers SPMD 'involuntary full remat' (gathers the
# whole cache every step — EXPERIMENTS.md §Perf decode hillclimb). False →
# the dus baseline.


def _cache_write(buf: jnp.ndarray, slot: jnp.ndarray, val: jnp.ndarray):
    """buf: [B, slots, ...]; slot: [B]; val: [B, ...] → buf with row written."""
    if not CACHE_ONEHOT_UPDATE:
        return buf.at[jnp.arange(buf.shape[0]), slot].set(val)
    slots = buf.shape[1]
    oh = jnp.arange(slots, dtype=jnp.int32)[None, :] == slot[:, None]  # [B, S]
    oh = oh.reshape(oh.shape + (1,) * (buf.ndim - 2))
    return jnp.where(oh, val[:, None], buf)


def attention_decode(
    p: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,
    cache: dict,
    *,
    window: int = 0,
) -> tuple[jnp.ndarray, dict]:
    """Single-token decode with KV cache.

    x: [B, 1, D].  cache: {'k','v': [B, S_slots, Kv, D], 'pos': [B] int32
    (next position)}.  Full-attention layers use S_slots = max_seq; SWA
    layers use a ring buffer with S_slots = window.
    """
    b = x.shape[0]
    pos = cache["pos"]  # [B]
    q, k, v = _project_qkv(p, cfg, x)
    q = rope(q, pos[:, None], cfg.rope_theta)
    k = rope(k, pos[:, None], cfg.rope_theta)

    slots = cache["k"].shape[1]
    slot = jnp.where(window > 0, pos % slots, jnp.minimum(pos, slots - 1))
    ck = _cache_write(cache["k"], slot, k[:, 0])
    cv = _cache_write(cache["v"], slot, v[:, 0])
    cpos = cache.get("slot_pos")
    if cpos is None:
        cpos = jnp.broadcast_to(jnp.arange(slots, dtype=jnp.int32)[None], (b, slots))
        cpos = jnp.where(
            cpos <= pos[:, None], cpos, -(10 ** 9)
        )
    else:
        cpos = _cache_write(cpos, slot, pos)

    kk = repeat_kv(ck, cfg.n_heads)
    vv = repeat_kv(cv, cfg.n_heads)
    scale = 1.0 / np.sqrt(q.shape[-1])
    scores = jnp.einsum("bshd,bthd->bhst", q, kk).astype(jnp.float32) * scale
    diff = pos[:, None] - cpos  # [B, slots]
    ok = (diff >= 0) & (cpos >= 0)  # cpos < 0 marks never-written slots
    if window > 0:
        ok = ok & (diff < window)
    scores = scores + jnp.where(ok, 0.0, NEG_INF)[:, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, vv)
    y = jnp.einsum("bshd,hdo->bso", out, p["wo"].astype(x.dtype),
                   preferred_element_type=x.dtype)
    new_cache = {"k": ck, "v": cv, "pos": pos + 1, "slot_pos": cpos}
    return y, new_cache


def init_attn_cache(cfg: ModelConfig, batch: int, max_seq: int, window: int = 0,
                    dtype=jnp.bfloat16) -> dict:
    slots = min(window, max_seq) if window > 0 else max_seq
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, slots, kv, hd), dtype),
        "v": jnp.zeros((batch, slots, kv, hd), dtype),
        "pos": jnp.zeros((batch,), jnp.int32),
        "slot_pos": jnp.full((batch, slots), -(10 ** 9), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.glu:
        return {
            "wi_gate": ParamSpec((d, f), ("embed", "mlp")),
            "wi_up": ParamSpec((d, f), ("embed", "mlp")),
            "wo": ParamSpec((f, d), ("mlp", "embed")),
        }
    return {
        "wi": ParamSpec((d, f), ("embed", "mlp")),
        "wo": ParamSpec((f, d), ("mlp", "embed")),
    }


def _act(cfg: ModelConfig, x):
    return jax.nn.silu(x) if cfg.act == "silu" else jax.nn.gelu(x)


def mlp_fwd(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.glu:
        h = _act(cfg, x @ p["wi_gate"].astype(x.dtype)) * (x @ p["wi_up"].astype(x.dtype))
    else:
        h = _act(cfg, x @ p["wi"].astype(x.dtype))
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(x.dtype),
                      preferred_element_type=x.dtype)
