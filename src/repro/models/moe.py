"""Mixture-of-Experts layer: token-choice top-k routing, shared experts,
capacity-based dispatch (expert-parallel friendly).

Dispatch is the classic capacity-buffer formulation: tokens are scattered
into per-expert buffers ``[E, C, D]``; expert matmuls run as one grouped
einsum (the E axis shards over 'model' → EP); results gather back weighted by
router probabilities.  Overflowing tokens are dropped (capacity_factor
controls the drop rate) — the standard TPU trade for static shapes.

DeepSeek-V3 nuances implemented: optional shared expert(s) fused into one
wide MLP; routed scaling; router in f32.  (Aux-loss-free balancing is
approximated by the standard load-balancing aux loss — documented in
DESIGN.md.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.config import ModelConfig, MoEConfig
from repro.models.params import ParamSpec


def moe_specs(cfg: ModelConfig) -> dict:
    mo = cfg.moe
    d, e, f = cfg.d_model, mo.n_routed, mo.d_ff_expert
    p = {
        "router": ParamSpec((d, e), ("embed", "experts"), scale=0.02),
        "wi_gate": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
        "wi_up": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
        "wo": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed")),
    }
    if mo.n_shared:
        fs = mo.d_ff_shared or mo.d_ff_expert * mo.n_shared
        p["shared"] = {
            "wi_gate": ParamSpec((d, fs), ("embed", "mlp")),
            "wi_up": ParamSpec((d, fs), ("embed", "mlp")),
            "wo": ParamSpec((fs, d), ("mlp", "embed")),
        }
    return p


MOE_IMPL = "einsum"  # 'einsum' (grouped dispatch, EP all-to-all) | 'scatter'
MOE_GROUP_SIZE = 256  # tokens per dispatch group (t5x-style)


def moe_fwd(p: dict, cfg: ModelConfig, x: jnp.ndarray):
    if MOE_IMPL == "einsum":
        return moe_fwd_einsum(p, cfg, x)
    return moe_fwd_scatter(p, cfg, x)


def moe_fwd_einsum(
    p: dict, cfg: ModelConfig, x: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Grouped one-hot einsum dispatch (GShard/t5x formulation).

    Tokens are reshaped into groups of MOE_GROUP_SIZE with per-group expert
    capacity C = group·k/E·cf; dispatch/combine are one-hot einsums — no
    scatter/gather, so GSPMD partitions them into clean all-to-alls over the
    (data × model) mesh instead of replicating token tensors (the scatter
    formulation's 'involuntary full rematerialization', see EXPERIMENTS.md
    §Perf hillclimb #1: ~28× collective-bytes reduction on qwen2-moe).
    """
    mo: MoEConfig = cfg.moe
    b, s, d = x.shape
    n = b * s
    e, k = mo.n_routed, mo.top_k
    gsz = min(MOE_GROUP_SIZE, n)
    g = n // gsz
    assert n % gsz == 0, (n, gsz)
    xg = x.reshape(g, gsz, d)
    xg = layers.constrain_batch(xg, 0)

    logits = jnp.einsum(
        "gsd,de->gse", xg.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)  # [g, s, e]
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    me = jnp.mean(probs.reshape(n, e), axis=0)
    ce_frac = jnp.sum(
        jax.nn.one_hot(top_e.reshape(-1), e, dtype=jnp.float32), axis=0
    ) / (n * k)
    aux = jnp.sum(me * ce_frac) * e * mo.aux_loss_weight

    capacity = int(np.ceil(gsz * k / e * mo.capacity_factor))
    # running per-expert fill across the k slots (slot-major priority)
    fill = jnp.zeros((g, e), jnp.int32)
    disp = jnp.zeros((g, e, capacity, d), x.dtype)
    combine_y = jnp.zeros((g, gsz, d), x.dtype)
    eo_list, poh_list = [], []
    for j in range(k):
        eo = jax.nn.one_hot(top_e[..., j], e, dtype=jnp.int32)  # [g, s, e]
        pos = fill[:, None, :] + jnp.cumsum(eo, axis=1) - eo  # [g, s, e]
        pos_tok = jnp.sum(pos * eo, axis=-1)  # [g, s]
        keep = pos_tok < capacity
        poh = jax.nn.one_hot(pos_tok, capacity, dtype=x.dtype) * keep[..., None]
        eo_list.append((eo.astype(x.dtype), poh, keep))
        fill = fill + jnp.sum(eo, axis=1)
        disp = disp + jnp.einsum(
            "gse,gsc,gsd->gecd", eo.astype(x.dtype), poh, xg
        )
    disp = layers.constrain_batch(disp, 0, 1)  # groups→data, experts→model (EP)

    h = jax.nn.silu(
        jnp.einsum("gecd,edf->gecf", disp, p["wi_gate"].astype(x.dtype))
    ) * jnp.einsum("gecd,edf->gecf", disp, p["wi_up"].astype(x.dtype))
    out = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(x.dtype),
                     preferred_element_type=x.dtype)
    out = layers.constrain_batch(out, 0, 1)

    y = jnp.zeros((g, gsz, d), x.dtype)
    for j in range(k):
        eo, poh, keep = eo_list[j]
        w = top_p[..., j].astype(x.dtype) * keep.astype(x.dtype)  # [g, s]
        y = y + w[..., None] * jnp.einsum("gse,gsc,gecd->gsd", eo, poh, out)
    y = y.reshape(b, s, d)
    if mo.n_shared:
        y = y + layers.mlp_fwd(p["shared"], cfg, x.reshape(b, s, d))
    return y, aux


def moe_fwd_scatter(
    p: dict, cfg: ModelConfig, x: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] → (y, aux_loss)."""
    mo: MoEConfig = cfg.moe
    b, s, d = x.shape
    n = b * s
    e, k = mo.n_routed, mo.top_k
    xf = x.reshape(n, d)

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # [N, k]
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce_frac = jnp.zeros((e,), jnp.float32).at[top_e.reshape(-1)].add(1.0) / (n * k)
    aux = jnp.sum(me * ce_frac) * e * mo.aux_loss_weight

    capacity = int(np.ceil(n * k / e * mo.capacity_factor))
    flat_e = top_e.reshape(-1)  # [N*k]
    flat_p = top_p.reshape(-1)
    # position of each (token, slot) within its expert
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # [N*k, E]
    pos = jnp.cumsum(onehot, axis=0) - 1
    pos_in_e = jnp.sum(pos * onehot, axis=-1)  # [N*k]
    keep = pos_in_e < capacity
    tok_idx = jnp.repeat(jnp.arange(n), k)

    disp = jnp.zeros((e, capacity, d), x.dtype)
    disp = disp.at[
        jnp.where(keep, flat_e, e - 1),
        jnp.where(keep, pos_in_e, capacity - 1),
    ].add(jnp.where(keep[:, None], xf[tok_idx], 0))
    disp = layers.constrain_batch(disp, 1, 0)  # experts → 'model' (EP a2a)

    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", disp, p["wi_gate"].astype(x.dtype))
    ) * jnp.einsum("ecd,edf->ecf", disp, p["wi_up"].astype(x.dtype))
    out = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype),
                     preferred_element_type=x.dtype)  # [E, C, D]
    out = layers.constrain_batch(out, 1, 0)

    gathered = out[jnp.where(keep, flat_e, 0), jnp.where(keep, pos_in_e, 0)]
    gathered = jnp.where(keep[:, None], gathered, 0) * flat_p[:, None].astype(x.dtype)
    y = jnp.zeros((n, d), x.dtype).at[tok_idx].add(gathered)

    if mo.n_shared:
        y = y + layers.mlp_fwd(p["shared"], cfg, xf)
    return y.reshape(b, s, d), aux
