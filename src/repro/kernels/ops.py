"""Jit'd public wrappers around the Pallas kernels.

Handles padding to block multiples, the [n, lanes] <-> [lanes, n] layout
transposes, and interpret-mode selection (``interpret=True`` on CPU hosts so
the kernels run everywhere; on TPU backends the real Mosaic path is used).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encoding
from repro.core.xash import DEFAULT_CONFIG, XashConfig
from repro.kernels import filter_kernel, registry, xash_kernel
from repro.kernels.registry import Backend


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def fused_filter_default() -> bool:
    """True when the unpinned dispatch resolves to the fused counts-only
    launch (``MATE_FILTER_BACKEND=fused``, or a real TPU where the fused
    kernel is the roofline path).  Selection itself lives in
    ``kernels.registry`` — this is a convenience predicate over it."""
    return registry.resolve_backend().fused


def _pad_to(x: jnp.ndarray, axis: int, multiple: int, value=0):
    size = x.shape[axis]
    target = max(-(-size // multiple) * multiple, multiple)
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


def superkey(
    enc_rows: np.ndarray | jnp.ndarray,
    cfg: XashConfig = DEFAULT_CONFIG,
    *,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Super keys of encoded rows. enc: uint8[n, n_cols, max_len] -> uint32[n, lanes]."""
    interpret = _on_cpu() if interpret is None else interpret
    block_n = block_n or xash_kernel.DEFAULT_BLOCK_N
    n = enc_rows.shape[0]
    enc = _pad_to(jnp.asarray(enc_rows, dtype=jnp.int32), 0, block_n)
    rank = jnp.asarray(cfg.freq_rank(), dtype=jnp.int32)[None, :]
    out_t = xash_kernel.xash_superkey(
        enc, rank, cfg, block_n=block_n, interpret=interpret
    )
    return out_t.T[:n]


def xash_values(
    enc_values: np.ndarray | jnp.ndarray,
    cfg: XashConfig = DEFAULT_CONFIG,
    *,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Per-value XASH: uint8[n, max_len] -> uint32[n, lanes] (1-cell rows)."""
    return superkey(jnp.asarray(enc_values)[:, None, :], cfg, interpret=interpret)


# per-shard values per launch: bounds the [chunk, max_len, 37] one-hot
# intermediate of the vectorised hash, mirroring the single-host chunking
# (core.index._XASH_CHUNK)
_MESH_HASH_CHUNK = 1 << 15


def xash_values_mesh(
    enc_values: np.ndarray,
    cfg: XashConfig = DEFAULT_CONFIG,
    *,
    mesh,
    row_axes: tuple[str, ...] | None = None,
    chunk: int = _MESH_HASH_CHUNK,
    times_out: list | None = None,
) -> np.ndarray:
    """Mesh-sharded unique-value XASH: uint8[n, max_len] -> uint32[n, lanes].

    The offline build's throughput-critical pass: values are block-partitioned
    over ``row_axes`` and hashed under ``shard_map`` by the SAME vectorised
    ``core.xash.xash`` the single-host ``MateIndex`` build runs.  Per-value
    hashing has no cross-value term and is pure integer arithmetic, so the
    gathered shard outputs are BIT-IDENTICAL to the single-host pass at any
    device count — the invariant ``tests/test_sharded_build.py`` pins.

    ``chunk`` bounds values-per-shard-per-launch (device memory, see
    ``_MESH_HASH_CHUNK``); padding values hash to all-zero lanes and are
    sliced off.  ``times_out`` (optional list) receives per-launch wall
    seconds for ``BuildStats`` accounting — launches are SPMD-collective, so
    every shard participates in every entry.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import distributed
    from repro.core import xash as xash_lib

    row_axes = tuple(row_axes or mesh.axis_names)
    n_shards = distributed.mesh_shard_count(mesh, row_axes)
    n = enc_values.shape[0]
    out = np.zeros((n, cfg.lanes), dtype=np.uint32)
    if n == 0:
        return out
    sharding = NamedSharding(mesh, P(row_axes))
    hash_fn = jax.jit(
        distributed.shard_map_compat(
            lambda e: xash_lib.xash(e, cfg),
            mesh=mesh,
            in_specs=P(row_axes),
            out_specs=P(row_axes),
        )
    )
    import time as _time

    step = chunk * n_shards
    for s in range(0, n, step):
        block = np.asarray(enc_values[s : s + step])
        nb = block.shape[0]
        block = distributed.pad_rows_to_shards(block, n_shards)
        t0 = _time.perf_counter()
        lanes = np.asarray(hash_fn(jax.device_put(block, sharding)))
        if times_out is not None:
            times_out.append(_time.perf_counter() - t0)
        out[s : s + nb] = lanes[:nb]
    return out


def flash_attention(
    q: jnp.ndarray,  # [B, S, H, d]
    k: jnp.ndarray,  # [B, T, H, d]
    v: jnp.ndarray,  # [B, T, H, dv]
    *,
    causal: bool = True,
    window: int = 0,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Pallas flash attention on [B, S, H, d] layouts (pads S/T to blocks).

    Heads must already be repeated to full count (layers.repeat_kv).
    """
    from repro.kernels import flash_kernel

    interpret = _on_cpu() if interpret is None else interpret
    b, s, h, d = q.shape
    t, dv = k.shape[1], v.shape[3]
    bq, bkv = flash_kernel.DEFAULT_BLOCK_Q, flash_kernel.DEFAULT_BLOCK_KV
    qp = _pad_to(q.transpose(0, 2, 1, 3).reshape(b * h, s, d), 1, bq)
    kp = _pad_to(k.transpose(0, 2, 1, 3).reshape(b * h, t, d), 1, bkv)
    vp = _pad_to(v.transpose(0, 2, 1, 3).reshape(b * h, t, dv), 1, bkv)
    # padded kv rows have position > every real q (masked by causal); for
    # non-causal, mask them via a window trick is unsound — require causal
    # or aligned shapes for non-causal use.
    assert causal or (s % bq == 0 and t % bkv == 0), "non-causal needs aligned shapes"
    out = flash_kernel.flash_attention(
        qp, kp, vp, causal=causal, window=window, interpret=interpret
    )
    return out[:, :s].reshape(b, h, s, dv).transpose(0, 2, 1, 3)


def filter_match(
    row_sk: jnp.ndarray,
    query_sk: jnp.ndarray,
    *,
    block_n: int | None = None,
    block_q: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Subsumption match matrix: (uint32[n, lanes], uint32[q, lanes]) -> bool[n, q].

    Padded rows have super key 0 (subsume only all-zero queries); padded
    queries are sliced off before returning.
    """
    interpret = _on_cpu() if interpret is None else interpret
    block_n = block_n or filter_kernel.DEFAULT_BLOCK_N
    block_q = block_q or filter_kernel.DEFAULT_BLOCK_Q
    n, q = row_sk.shape[0], query_sk.shape[0]
    # pad rows with all-ones superkeys → they match everything; slice off.
    row_t = _pad_to(jnp.asarray(row_sk, jnp.uint32).T, 1, block_n)
    qry_t = _pad_to(jnp.asarray(query_sk, jnp.uint32).T, 1, block_q)
    out = filter_kernel.filter_match(
        row_t, qry_t, block_n=block_n, block_q=block_q, interpret=interpret
    )
    return out[:n, :q].astype(jnp.bool_)


@jax.jit
def _subsume_block(row_sk: jnp.ndarray, query_sk: jnp.ndarray) -> jnp.ndarray:
    """Vectorised XLA subsumption: (uint32[n, lanes], uint32[q, lanes]) -> bool[n, q]."""
    return jnp.all((query_sk[None, :, :] & ~row_sk[:, None, :]) == 0, axis=-1)


def subsume_np(row_sk: np.ndarray, query_sk: np.ndarray) -> np.ndarray:
    """Host-side subsumption oracle (§6.3): bool[n, q].

    The single definition of the filter predicate outside the kernels — the
    engines' numpy paths route here so the semantics can't silently diverge.
    """
    rows = np.asarray(row_sk, dtype=np.uint32)
    qry = np.asarray(query_sk, dtype=np.uint32)
    return np.all((qry[None, :, :] & ~rows[:, None, :]) == 0, axis=-1)


# CPU fallback pads each dim up to a power-of-two bucket so XLA compiles
# O(log) distinct shapes instead of one program per batch size.
_FALLBACK_MIN_N = 512
_FALLBACK_MIN_Q = 64
# below this many (row × key) probes, numpy beats the XLA dispatch latency
_MIN_XLA_PROBES = 1 << 17


def _pow2_bucket(size: int, minimum: int) -> int:
    b = minimum
    while b < size:
        b <<= 1
    return b


# finer bucketing for the fused hits+counts launch: pow2 up to 8k, then 8k
# steps — the padded rows cost real compute (subsume + reductions), and at
# pow2 granularity that waste approaches 2x; still O(few) compiled shapes.
_BUCKET_STEP = 8192


def _bucket(size: int, minimum: int) -> int:
    if size <= _BUCKET_STEP:
        return _pow2_bucket(size, minimum)
    return -(-size // _BUCKET_STEP) * _BUCKET_STEP


def _check_fused_block_n(block_n: int) -> None:
    """Validate a user-facing ``fused_block_n`` override.

    A ``ValueError`` (not an ``assert``) so the check also fires under
    ``python -O`` — the override flows in from ``DiscoveryConfig`` and this
    message mirrors its ``__post_init__`` wording.
    """
    if block_n < 128 or block_n & (block_n - 1):
        raise ValueError(
            f"fused_block_n must be a power of two >= 128, got {block_n}"
        )


def filter_match_auto(
    row_sk: np.ndarray | jnp.ndarray,
    query_sk: np.ndarray | jnp.ndarray,
    backend: Backend | str | None = None,
) -> np.ndarray:
    """Backend-dispatched super-key row filter (§6.3): bool[n, q] on the host.

    On TPU this launches the Pallas ``filter_kernel`` (the memory-roofline
    path); on any other backend (CPU/GPU hosts) it runs the vectorised XLA
    subsumption instead of the Pallas interpreter, which is orders of
    magnitude slower per launch.  Tiny blocks (< ~100k probes) short-circuit
    to numpy, where the XLA dispatch latency alone would dominate.
    ``backend`` pins one path (resolved via ``kernels.registry``: explicit >
    ``MATE_FILTER_BACKEND`` > platform default — the CI matrix uses the env
    level to exercise interpret-mode Pallas on CPU hosts).
    """
    n, q = row_sk.shape[0], query_sk.shape[0]
    if n == 0 or q == 0:
        return np.zeros((n, q), dtype=bool)
    backend = registry.resolve_backend(backend).name
    if backend in ("fused", "fused-gather"):
        backend = "pallas"  # fused paths have no matrix output; same family
    if backend == "auto":
        backend = "numpy" if n * q < _MIN_XLA_PROBES else "xla"
    if backend == "numpy":
        return subsume_np(row_sk, query_sk)
    if backend == "xla":
        rows = _pad_to(
            jnp.asarray(row_sk, jnp.uint32), 0, _pow2_bucket(n, _FALLBACK_MIN_N)
        )
        qry = _pad_to(
            jnp.asarray(query_sk, jnp.uint32), 0, _pow2_bucket(q, _FALLBACK_MIN_Q)
        )
        return np.asarray(_subsume_block(rows, qry))[:n, :q]
    return np.asarray(filter_match(row_sk, query_sk))


def _per_table_counts(hits, seg, num_segments: int):
    """Per-table eligible-hit counts from a bool[n, q] hits matrix.

    The row reduction runs as an f32 matvec — on CPU XLA that lowers to a
    BLAS gemv and is ~1.6x faster end-to-end than an integer row sum, which
    forces a second un-fused pass over the matrix.  f32 is exact here
    (row sums are bounded by q « 2^24).
    """
    ones = jnp.ones((hits.shape[1], 1), jnp.float32)
    per_row = (hits.astype(jnp.float32) @ ones)[:, 0].astype(jnp.int32)
    return jax.ops.segment_sum(per_row, seg, num_segments=num_segments)


@functools.partial(jax.jit, static_argnames=("num_segments",))
def _hits_counts_block(row_sk, query_sk, elig, seg, *, num_segments: int):
    """Subsumption ∧ eligibility plus per-table hit counts, all on device."""
    hits = jnp.all((query_sk[None, :, :] & ~row_sk[:, None, :]) == 0, axis=-1) & elig
    return hits, _per_table_counts(hits, seg, num_segments)


@functools.partial(jax.jit, static_argnames=("num_segments",))
def _combine_counts(match, elig, seg, *, num_segments: int):
    """Same reduction as ``_hits_counts_block`` over a precomputed match."""
    hits = match.astype(jnp.bool_) & elig
    return hits, _per_table_counts(hits, seg, num_segments)


# above this table count the fused one-hot tile would blow VMEM even at the
# minimum row block, and the composed path wins anyway (readback is already
# counts-dominated at that scale) — see filter_kernel.fused_block_n
_FUSED_MAX_TABLES = filter_kernel.FUSED_MAX_TABLES


def filter_table_counts(
    row_sk: np.ndarray | jnp.ndarray,
    query_sk: np.ndarray | jnp.ndarray,
    elig: np.ndarray | None,
    seg_ids: np.ndarray,
    n_tables: int,
    *,
    mode: str = "sum",
    interpret: bool | None = None,
    block_n: int | None = None,
) -> np.ndarray:
    """Fused filter+segment-count launch: per-table eligible-hit counts with
    COUNTS-ONLY readback — the rows × queries match matrix is never
    materialised, not even in HBM (paper §6.3 at its true roofline:
    ~16 bytes read per row, 4 bytes written per table).

    Args:
      row_sk:   uint32[n, lanes] candidate-row super keys.
      query_sk: uint32[q, lanes] query-key super keys.
      elig:     bool[n, q] eligibility per (item, key), or None (all eligible).
      seg_ids:  int32[n] table index (0..n_tables) of each candidate item.
      n_tables: number of tables covered by this block.
      mode:     'sum' (eligible hits per table) | 'any' (rows with ≥1 hit).
      block_n:  optional power-of-two row-block override
                (``DiscoveryConfig.fused_block_n``); clamped to the VMEM
                budget block, so it can only shrink the tile, never blow it.
    Returns:
      int32[n_tables] counts on the host — the only transfer.
    """
    n, q = row_sk.shape[0], query_sk.shape[0]
    if n == 0 or q == 0 or n_tables == 0:
        return np.zeros(n_tables, dtype=np.int32)
    assert n_tables <= _FUSED_MAX_TABLES, n_tables
    interpret = _on_cpu() if interpret is None else interpret
    nb = _bucket(n, _FALLBACK_MIN_N)
    qb = _pow2_bucket(q, _FALLBACK_MIN_Q)
    tb = max(-(-n_tables // 128) * 128, 128)
    # power-of-two block ≤ nb: divides both pow2 buckets and 8192-multiples,
    # so the grid covers every padded row exactly
    budget_n = filter_kernel.fused_block_n(tb)
    if block_n is not None:
        _check_fused_block_n(block_n)
        budget_n = min(budget_n, block_n)
    block_n = min(nb, budget_n)
    block_q = qb if mode == "any" else min(qb, filter_kernel.DEFAULT_BLOCK_Q)
    rows_p = np.zeros((nb, row_sk.shape[1]), dtype=np.uint32)
    rows_p[:n] = row_sk
    # padded queries get all-ones super keys (subsumed by nothing)
    qry_p = np.full((qb, query_sk.shape[1]), 0xFFFFFFFF, dtype=np.uint32)
    qry_p[:q] = query_sk
    seg_p = np.full(nb, -1, dtype=np.int32)  # padding rows scatter nowhere
    seg_p[:n] = seg_ids
    elig_p = None
    if elig is not None:
        elig_p = np.zeros((nb, qb), dtype=np.int8)
        elig_p[:n, :q] = elig
        elig_p = jnp.asarray(elig_p)
    counts, _key_counts = filter_kernel.filter_table_counts(
        jnp.asarray(rows_p).T,
        jnp.asarray(qry_p).T,
        elig_p,
        jnp.asarray(seg_p),
        n_tables=tb,
        n_queries=q,
        block_n=block_n,
        block_q=block_q,
        mode=mode,
        interpret=interpret,
    )
    return np.asarray(counts)[:n_tables]


# device superkey stores above this size stay host-resident and the
# fused-gather backend demotes to the host-gather fused launch — a lake that
# big should be sharded across hosts (ROADMAP item 1) rather than squeezed
# into one device's HBM alongside the working set.
GATHER_STORE_MAX_BYTES = 2 << 30


def gather_store_fits(superkeys: np.ndarray | jnp.ndarray) -> bool:
    """True when the per-row superkey store fits the device-store budget."""
    return superkeys.nbytes <= GATHER_STORE_MAX_BYTES


def gather_filter_table_counts(
    store: jnp.ndarray,
    rows: np.ndarray,
    query_sk: np.ndarray | jnp.ndarray,
    elig: np.ndarray | None,
    seg_ids: np.ndarray,
    n_tables: int,
    *,
    block_n: int | None = None,
    interpret: bool | None = None,
) -> np.ndarray:
    """Gather-fused filter+segment-count launch: posting-list row offsets in,
    per-table counts out — ONE launch from CSR posting lists to counts.

    The composed path ships n×lanes gathered superkeys through HBM before the
    filter ever runs; here the kernel scalar-prefetches the (ragged, padded)
    row offsets and DMA-gathers each row block from the device-resident
    ``store`` straight into VMEM, so the gathered block never exists in HBM
    and the host ships n×4 offset bytes instead of n×lanes×4 key bytes.

    Args:
      store:    uint32[N, lanes_s] device-resident superkey store
                (``MateIndex.device_store()``), row-major.
      rows:     int[n] row offsets into ``store`` (the CSR candidate rows).
      query_sk: uint32[q, lanes] query-key super keys; ``lanes <= lanes_s``
                probes a lane-prefix degrade over the full-width store.
      elig:     bool[n, q] eligibility per (item, key), or None.
      seg_ids:  int32[n] table index (0..n_tables) of each candidate item.
      n_tables: number of tables covered by this block.
      block_n:  optional power-of-two row-block override
                (``DiscoveryConfig.fused_block_n``); clamped to the VMEM
                budget block, so it can only shrink the tile, never blow it.
    Returns:
      int32[n_tables] counts on the host — bit-identical to
      ``filter_table_counts(store[rows][:, :lanes], ...)`` (mode='sum').
    """
    n, q = rows.shape[0], query_sk.shape[0]
    if n == 0 or q == 0 or n_tables == 0:
        return np.zeros(n_tables, dtype=np.int32)
    if n_tables > _FUSED_MAX_TABLES:
        raise ValueError(
            f"gather-fused scatter tile supports at most {_FUSED_MAX_TABLES}"
            f" tables per launch, got {n_tables} — split the batch or use the"
            " composed path"
        )
    interpret = _on_cpu() if interpret is None else interpret
    nb = _bucket(n, _FALLBACK_MIN_N)
    qb = _pow2_bucket(q, _FALLBACK_MIN_Q)
    tb = max(-(-n_tables // 128) * 128, 128)
    budget_n = filter_kernel.fused_block_n(tb)
    if block_n is not None:
        _check_fused_block_n(block_n)
        budget_n = min(budget_n, block_n)
    block_n = min(nb, budget_n)
    block_q = min(qb, filter_kernel.DEFAULT_BLOCK_Q)
    # padding offsets point at row 0 (always valid); their seg id is -1 so
    # they scatter nowhere regardless of what row 0's superkey matches.
    rows_p = np.zeros(nb, dtype=np.int32)
    rows_p[:n] = rows
    qry_p = np.full((qb, query_sk.shape[1]), 0xFFFFFFFF, dtype=np.uint32)
    qry_p[:q] = query_sk
    seg_p = np.full(nb, -1, dtype=np.int32)
    seg_p[:n] = seg_ids
    elig_p = None
    if elig is not None:
        elig_p = np.zeros((nb, qb), dtype=np.int8)
        elig_p[:n, :q] = elig
        elig_p = jnp.asarray(elig_p)
    counts = filter_kernel.gather_filter_table_counts(
        jnp.asarray(rows_p),
        store,
        jnp.asarray(qry_p).T,
        elig_p,
        jnp.asarray(seg_p),
        n_tables=tb,
        n_queries=q,
        block_n=block_n,
        block_q=block_q,
        interpret=interpret,
    )
    return np.asarray(counts)[:n_tables]


def filter_hits_table_counts(
    row_sk: np.ndarray | jnp.ndarray,
    query_sk: np.ndarray | jnp.ndarray,
    elig: np.ndarray,
    seg_ids: np.ndarray,
    n_tables: int,
    *,
    use_device: bool = True,
    backend: Backend | str | None = None,
    fused_block_n: int | None = None,
    store: jnp.ndarray | None = None,
    rows: np.ndarray | None = None,
) -> tuple[np.ndarray | jnp.ndarray | None, np.ndarray]:
    """Device-side inputs for the §6.2 bound checks: eligible filter hits plus
    per-table hit counts, WITHOUT transferring the match matrix to the host.

    Args:
      row_sk:   uint32[n, lanes] candidate-row super keys.
      query_sk: uint32[q, lanes] query-key super keys.
      elig:     bool[n, q] init-value eligibility per (item, key) pair.
      seg_ids:  int32[n] table index (0..n_tables) of each candidate item.
      n_tables: number of tables covered by this block.
      use_device: False forces the host numpy path (legacy ``use_kernel``).
      backend:  resolved ``Backend`` (or name) for this call; None follows
                the registry precedence (env var, then platform default).
      fused_block_n: optional row-block override for the fused launch.
      store:    device-resident superkey store for the ``fused-gather``
                backend (``MateIndex.device_store()``); with ``rows`` set the
                gather-fused launch replaces ``row_sk`` entirely.
      rows:     int[n] store row offsets for the gather-fused launch.
    Returns:
      (hits, counts) — ``counts`` int32[n_tables] is the one per-batch host
      readback the rule-1/rule-2 bounds consume.  On the composed XLA/Pallas
      paths ``hits`` bool[n, q] stays device-resident (slice it per surviving
      table; only those slices are ever read back).  On the FUSED paths
      ``hits`` is None: the match matrix was never produced at all — callers
      recompute the (few) surviving tables' slices on demand.  ``row_sk`` may
      be None when ``store``+``rows`` are given (the gather-fused contract:
      the host never gathers the candidate superkeys); a demotion off the
      gather path then materialises them from the device store.
    """
    n = rows.shape[0] if row_sk is None else row_sk.shape[0]
    q = query_sk.shape[0]
    if n == 0 or q == 0 or n_tables == 0:
        return np.zeros((n, q), dtype=bool), np.zeros(n_tables, dtype=np.int32)
    if not use_device:
        backend = "numpy"
    backend = registry.resolve_backend(backend).name
    if backend == "fused-gather":
        if store is not None and rows is not None and n_tables <= _FUSED_MAX_TABLES:
            counts = gather_filter_table_counts(
                store, rows, query_sk, elig, seg_ids, n_tables,
                block_n=fused_block_n,
            )
            return None, counts
        # no device store (or the scatter tile would blow VMEM): demote to
        # the host-gather fused launch, which shares the cap fallback below
        backend = "fused"
    if row_sk is None:
        # demoted off the gather path without host superkeys: gather them
        # from the device store (rare — cap overflow or store missing).
        row_sk = np.asarray(store)[np.asarray(rows)][:, : query_sk.shape[1]]
    if backend == "fused" and n_tables > _FUSED_MAX_TABLES:
        backend = "pallas"  # scatter tile would blow VMEM; composed oracle
    if backend == "fused":
        counts = filter_table_counts(
            row_sk, query_sk, elig, seg_ids, n_tables, block_n=fused_block_n
        )
        return None, counts
    if backend == "auto":
        backend = "numpy" if n * q < _MIN_XLA_PROBES else "xla"
    if backend == "numpy":
        hits = subsume_np(row_sk, query_sk) & np.asarray(elig, dtype=bool)
        counts = np.bincount(
            np.asarray(seg_ids, dtype=np.int64),
            weights=hits.sum(axis=1),
            minlength=n_tables,
        ).astype(np.int32)
        return hits, counts[:n_tables]
    # bucket every dim so XLA compiles O(few) distinct shapes; padded
    # rows/queries have elig False, so their (arbitrary) super keys and the
    # segment-0 padding of seg_ids contribute nothing to hits or counts.
    nb = _bucket(n, _FALLBACK_MIN_N)
    qb = _pow2_bucket(q, _FALLBACK_MIN_Q)
    tb = _pow2_bucket(n_tables, 16)
    rows_p = np.zeros((nb, row_sk.shape[1]), dtype=np.uint32)
    rows_p[:n] = row_sk
    qry_p = np.zeros((qb, query_sk.shape[1]), dtype=np.uint32)
    qry_p[:q] = query_sk
    elig_p = np.zeros((nb, qb), dtype=bool)
    elig_p[:n, :q] = elig
    seg_p = np.zeros(nb, dtype=np.int32)
    seg_p[:n] = seg_ids
    if backend == "pallas":
        interpret = _on_cpu()
        match = filter_kernel.filter_match(
            jnp.asarray(rows_p).T,
            jnp.asarray(qry_p).T,
            block_n=min(nb, filter_kernel.DEFAULT_BLOCK_N),
            block_q=min(qb, filter_kernel.DEFAULT_BLOCK_Q),
            interpret=interpret,
        )
        hits, counts = _combine_counts(
            match, jnp.asarray(elig_p), jnp.asarray(seg_p), num_segments=tb
        )
    else:
        hits, counts = _hits_counts_block(
            jnp.asarray(rows_p),
            jnp.asarray(qry_p),
            jnp.asarray(elig_p),
            jnp.asarray(seg_p),
            num_segments=tb,
        )
    return hits[:n, :q], np.asarray(counts)[:n_tables]


def filter_count(
    row_sk: jnp.ndarray,
    query_sk: jnp.ndarray,
    *,
    block_n: int | None = None,
    block_q: int | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused per-query candidate count: -> int32[q].

    Padded rows must NOT count: they are padded with all-zero super keys and
    an all-zero query would wrongly match them, so the wrapper pads queries
    with all-ones (matching nothing except all-ones rows, which padding never
    creates) and subtracts nothing for rows: a zero row superkey subsumes only
    zero queries — real queries always have ≥1 bit per non-empty key value, so
    zero-key queries (empty strings) are the only edge case and they match
    every row under ANY filter (vacuous truth), identical to the reference.
    """
    interpret = _on_cpu() if interpret is None else interpret
    block_n = block_n or filter_kernel.DEFAULT_BLOCK_N
    block_q = block_q or filter_kernel.DEFAULT_BLOCK_Q
    n, q = row_sk.shape[0], query_sk.shape[0]
    row_t = _pad_to(jnp.asarray(row_sk, jnp.uint32).T, 1, block_n, value=0)
    qry_t = _pad_to(
        jnp.asarray(query_sk, jnp.uint32).T, 1, block_q, value=np.uint32(0xFFFFFFFF)
    )
    counts = filter_kernel.filter_count(
        row_t, qry_t, block_n=block_n, block_q=block_q, interpret=interpret
    )
    # padded rows have zero super keys: they match a query only if the query
    # is all-zero; correct for that exact case.
    n_pad = row_t.shape[1] - n
    if n_pad:
        zero_q = jnp.all(jnp.asarray(query_sk, jnp.uint32) == 0, axis=-1)
        counts = counts[:q] - jnp.where(zero_q, n_pad, 0).astype(jnp.int32)
        return counts
    return counts[:q]
