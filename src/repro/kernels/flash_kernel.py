"""Pallas TPU flash-attention (forward) kernel.

The LM substrate's hottest compute path: blocked online-softmax attention
with explicit VMEM tiling.  Grid = (batch·heads, q_blocks); the kv loop is
the innermost grid axis so the (m, l, acc) running statistics live in VMEM
scratch across kv steps (standard TPU flash schedule).

Causal + sliding-window masking via position arithmetic (same semantics as
``layers._mask_bias``); validated against ``layers._sdpa_flash`` /
``_sdpa_full`` in interpret mode (tests/test_kernels.py).  Training uses the
jnp flash path for autodiff; this kernel is the serving/prefill fast path on
real TPUs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_KV = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, block_q: int,
                  block_kv: int, n_kv: int):
    qi = pl.program_id(1)  # q block index
    ki = pl.program_id(2)  # kv block index (innermost)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]  # [block_q, d]
    k = k_ref[0]  # [block_kv, d]
    v = v_ref[0]  # [block_kv, dv]
    sc = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0
    )
    k_pos = ki * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1
    )
    diff = q_pos - k_pos
    ok = jnp.ones_like(diff, dtype=jnp.bool_)
    if causal:
        ok = ok & (diff >= 0)
    if window > 0:
        ok = ok & (diff < window)
    sc = jnp.where(ok, sc, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(sc, axis=-1, keepdims=True))
    p = jnp.exp(sc - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # [BH, S, d]
    k: jnp.ndarray,  # [BH, T, d]
    v: jnp.ndarray,  # [BH, T, dv]
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    interpret: bool = False,
) -> jnp.ndarray:
    """Blocked flash attention. S, T must divide block sizes (ops.py pads)."""
    bh, s, d = q.shape
    t = k.shape[1]
    dv = v.shape[2]
    assert s % block_q == 0 and t % block_kv == 0, (s, t)
    n_q, n_kv = s // block_q, t // block_kv
    scale = 1.0 / np.sqrt(d)
    grid = (bh, n_q, n_kv)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale, causal=causal, window=window,
            block_q=block_q, block_kv=block_kv, n_kv=n_kv,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
