"""Pallas TPU kernel for XASH hashing + super-key OR-aggregation (paper §5).

Offline indexing hashes every cell of the corpus — billions of values for
DWTC-scale lakes — so it is the throughput-critical half of MATE.  The kernel
fuses, per row block:

    for each cell:  character stats → rare-char selection → bit positions
                    (Eq. 6/7 + rotation) → 128-bit one-hot
    OR-aggregate cells → pack to uint32 lanes

entirely in VMEM, writing only the final ``[lanes, block]`` super keys to HBM
(48·C bytes read, 16 bytes written per row — no intermediate materialisation).

TPU notes:
  * the rare-char arg-min is implemented as (min, compare, masked-sum) —
    no gathers, no sorts; scores are unique by construction (count*64+rank,
    rank a permutation of 0..36) so the compare selects exactly one char;
  * everything is VPU work on [block, 37]/[block, 128] tiles; MXU is unused
    (this is not a matmul workload);
  * the cell loop is a ``fori_loop`` with the 128-wide accumulator carried in
    vregs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import encoding
from repro.core.xash import XashConfig

DEFAULT_BLOCK_N = 128


def _cell_bits(cell, rank_row, cfg: XashConfig):
    """bits: bool[bn, bits] for one cell slice ``cell`` int32[bn, L]."""
    a = encoding.ALPHABET_SIZE
    bn, max_len = cell.shape
    cbits, region, lseg = cfg.c, cfg.char_region, cfg.len_segment
    BIG = jnp.int32(1 << 24)

    is_char = cell > 0
    l_v = jnp.sum(is_char.astype(jnp.int32), axis=-1)  # [bn]

    iota_a = jax.lax.broadcasted_iota(jnp.int32, (bn, max_len, a), 2)
    onehot = (cell[:, :, None] == iota_a + 1) & is_char[:, :, None]
    onehot_i = onehot.astype(jnp.int32)
    count = jnp.sum(onehot_i, axis=1)  # [bn, a]
    pos_w = jax.lax.broadcasted_iota(jnp.int32, (bn, max_len, a), 1) + 1
    sum_pos = jnp.sum(onehot_i * pos_w, axis=1)  # [bn, a]

    score = jnp.where(count > 0, count * 64 + rank_row[None, :], BIG)
    iota_char = jax.lax.broadcasted_iota(jnp.int32, (bn, a), 1)
    iota_bits = jax.lax.broadcasted_iota(jnp.int32, (bn, cfg.bits), 1)

    bits = jnp.zeros((bn, cfg.bits), dtype=jnp.bool_)
    for _pick in range(cfg.n_char_bits):
        m = jnp.min(score, axis=-1, keepdims=True)  # [bn, 1]
        sel = score == m  # exactly one True per row (scores unique)
        chosen_count = jnp.sum(count * sel, axis=-1)
        chosen_sum = jnp.sum(sum_pos * sel, axis=-1)
        chosen_id = jnp.sum(iota_char * sel, axis=-1)
        denom = jnp.maximum(chosen_count * l_v, 1)
        x = -((-chosen_sum * cbits) // denom)
        x = jnp.clip(x, 1, cbits)
        p = chosen_id * cbits + (x - 1)
        p_rot = jnp.remainder(p - l_v, region)
        bitpos = lseg + p_rot  # [bn]
        valid = (m[:, 0] < BIG) & (l_v > 0)
        bits = bits | ((iota_bits == bitpos[:, None]) & valid[:, None])
        score = jnp.where(sel, BIG, score)

    len_bit = jnp.remainder(l_v, lseg)
    bits = bits | ((iota_bits == len_bit[:, None]) & (l_v > 0)[:, None])
    return bits


def _superkey_kernel(enc_ref, rank_ref, out_ref, *, cfg: XashConfig, n_cols: int):
    bn = enc_ref.shape[0]
    rank_row = rank_ref[0, :]  # [37]

    def body(c, acc):
        cell = pl.load(
            enc_ref, (slice(None), pl.dslice(c, 1), slice(None))
        ).reshape(bn, enc_ref.shape[2])
        return acc | _cell_bits(cell, rank_row, cfg)

    bits = jax.lax.fori_loop(
        0, n_cols, body, jnp.zeros((bn, cfg.bits), dtype=jnp.bool_)
    )
    # pack bool[bn, bits] -> uint32[lanes, bn]
    lanes = cfg.lanes
    grouped = bits.reshape(bn, lanes, 32).astype(jnp.uint32)
    weights = jnp.left_shift(
        jnp.uint32(1), jax.lax.broadcasted_iota(jnp.uint32, (bn, lanes, 32), 2)
    )
    packed = jnp.sum(grouped * weights, axis=-1, dtype=jnp.uint32)  # [bn, lanes]
    out_ref[...] = packed.T


@functools.partial(jax.jit, static_argnames=("cfg", "block_n", "interpret"))
def xash_superkey(
    enc: jnp.ndarray,
    rank: jnp.ndarray,
    cfg: XashConfig,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret: bool = False,
) -> jnp.ndarray:
    """Super keys for encoded rows.

    Args:
      enc: int32[n, n_cols, max_len], n divisible by block_n.
      rank: int32[1, 37] ascending-frequency char ranks.
    Returns:
      uint32[lanes, n] (transposed layout; ops.py untransposes).
    """
    n, n_cols, max_len = enc.shape
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_superkey_kernel, cfg=cfg, n_cols=n_cols),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, n_cols, max_len), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, encoding.ALPHABET_SIZE), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((cfg.lanes, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((cfg.lanes, n), jnp.uint32),
        interpret=interpret,
    )(enc, rank)
