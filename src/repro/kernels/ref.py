"""Pure-jnp oracles for the Pallas kernels.

These are the semantics the kernels must reproduce exactly (tests sweep
shapes/dtypes and assert equality — the outputs are integral, so equality is
exact, no tolerance needed).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import xash as xash_core


def xash_superkey_ref(enc: jnp.ndarray, cfg=xash_core.DEFAULT_CONFIG) -> jnp.ndarray:
    """Super keys of rows.

    Args:
      enc: uint8/int32 [n_rows, n_cols, max_len] encoded cells.
    Returns:
      uint32[n_rows, lanes].
    """
    return xash_core.superkey(enc.astype(jnp.uint8), cfg)


def xash_ref(enc: jnp.ndarray, cfg=xash_core.DEFAULT_CONFIG) -> jnp.ndarray:
    """Per-value XASH. enc: [n, max_len] -> uint32[n, lanes]."""
    return xash_core.xash(enc.astype(jnp.uint8), cfg)


def filter_match_ref(row_sk: jnp.ndarray, query_sk: jnp.ndarray) -> jnp.ndarray:
    """Subsumption match matrix.

    Args:
      row_sk:   uint32[n, lanes] candidate-row super keys.
      query_sk: uint32[q, lanes] query composite-key super keys.
    Returns:
      bool[n, q] — True where query key may be contained in row (§6.3).
    """
    conflict = query_sk[None, :, :] & ~row_sk[:, None, :]
    return jnp.all(conflict == 0, axis=-1)


def filter_count_ref(row_sk: jnp.ndarray, query_sk: jnp.ndarray) -> jnp.ndarray:
    """Per-query count of candidate rows passing the filter: int32[q]."""
    return jnp.sum(filter_match_ref(row_sk, query_sk), axis=0, dtype=jnp.int32)
