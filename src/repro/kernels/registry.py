"""Filter-backend registry — the ONE place backend selection happens.

Three PRs of growth left backend choice scattered across three idioms: the
``MATE_FILTER_BACKEND`` env var read inside ``kernels/ops.py``, ``fused=`` /
``use_kernel=`` booleans on the engines, and ``impl=`` strings on the
distributed filter.  This module centralises all of it:

  * ``Backend`` — a frozen, resolved selection.  Engines and wrappers take a
    ``Backend`` (or a name that resolves to one) instead of ad-hoc booleans.
  * ``resolve_backend(backend, platform)`` — the single precedence rule:

        explicit config  >  MATE_FILTER_BACKEND env var  >  platform default

    (platform default: ``fused-gather`` on TPU — the roofline path, demoting
    to ``fused`` when the device superkey store is absent or over budget —
    and ``auto`` everywhere else, where ``auto`` is the size-based numpy/XLA
    split).
  * ``register_backend`` — the extension point; the built-in table covers
    the four §6.3 filter implementations plus ``auto``.

NO other module may read ``MATE_FILTER_BACKEND`` — CI lints for it
(``tools/lint_backend_env.py``) so the env var cannot quietly grow new
readers again.
"""

from __future__ import annotations

import dataclasses
import os

import jax

ENV_VAR = "MATE_FILTER_BACKEND"


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """Registry entry describing one filter implementation."""

    name: str
    description: str
    fused: bool = False  # counts-only launch; match matrix never exists
    device: bool = True  # launches device work (False: host numpy oracle)
    gather: bool = False  # DMA-gathers rows from the device superkey store


@dataclasses.dataclass(frozen=True)
class Backend:
    """A RESOLVED backend selection: what the engines actually thread.

    ``source`` records which precedence level won ('config' | 'env' |
    'platform') — bench rows and stats surfaces report it so a run's
    provenance is never ambiguous.
    """

    name: str
    source: str = "config"

    @property
    def spec(self) -> BackendSpec:
        return _REGISTRY[self.name]

    @property
    def fused(self) -> bool:
        return self.spec.fused

    @property
    def device(self) -> bool:
        return self.spec.device

    @property
    def gather(self) -> bool:
        return self.spec.gather

    def __str__(self) -> str:  # noqa: DunderStr — used in bench rows/logs
        return self.name


_REGISTRY: dict[str, BackendSpec] = {}


def register_backend(spec: BackendSpec) -> BackendSpec:
    """Register a filter backend; names are unique and immutable."""
    if spec.name in _REGISTRY:
        raise ValueError(f"backend {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


register_backend(BackendSpec(
    "fused", "fused filter+segment-count Pallas kernel (counts-only readback;"
    " interpret mode off-TPU)", fused=True,
))
register_backend(BackendSpec(
    "fused-gather", "gather-fused Pallas kernel: DMA-gathers candidate rows"
    " from the device superkey store inside the fused counts-only launch"
    " (demotes to 'fused' when the store is absent or over budget;"
    " interpret mode off-TPU)", fused=True, gather=True,
))
register_backend(BackendSpec(
    "pallas", "composed Pallas filter_kernel + XLA segment-sum"
    " (interpret mode off-TPU)",
))
register_backend(BackendSpec(
    "xla", "vectorised XLA subsumption",
))
register_backend(BackendSpec(
    "numpy", "host-side numpy oracle", device=False,
))
register_backend(BackendSpec(
    "auto", "size-based numpy/XLA split (CPU default)",
))


def backend_names() -> tuple[str, ...]:
    """Registered backend names (stable registration order)."""
    return tuple(_REGISTRY)


def platform_default(platform: str | None = None) -> str:
    """Backend name a platform defaults to when nothing is pinned."""
    platform = platform or jax.default_backend()
    return "fused-gather" if platform == "tpu" else "auto"


def resolve_backend(
    backend: Backend | str | None = None,
    platform: str | None = None,
) -> Backend:
    """Resolve a backend selection with the one precedence rule.

    ``backend`` may be an already-resolved ``Backend`` (returned as-is), a
    registered name (source='config'), or None — in which case the
    ``MATE_FILTER_BACKEND`` env var applies (source='env') and, failing
    that, the platform default (source='platform').  Unknown names raise;
    an unknown env value is ignored (matching the historic dispatch, so a
    typo'd env var degrades to the platform default instead of crashing
    every launch).
    """
    if isinstance(backend, Backend):
        return backend
    if backend is not None:
        if backend not in _REGISTRY:
            raise ValueError(
                f"unknown filter backend {backend!r}; registered: "
                f"{', '.join(_REGISTRY)}"
            )
        return Backend(backend, source="config")
    env = os.environ.get(ENV_VAR, "").strip().lower()
    if env in _REGISTRY:
        return Backend(env, source="env")
    return Backend(platform_default(platform), source="platform")
