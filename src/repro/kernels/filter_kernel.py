"""Pallas TPU kernel for the super-key row filter (paper §6.3).

This is MATE's hot loop: for every (candidate row, query key) pair test
``(q & ~row) == 0`` over the hash lanes.  On TPU this is a pure-VPU
streaming workload; the kernel tiles both operands into VMEM and emits either
the match matrix or a fused per-query count (the count variant never
materialises the n×q matrix in HBM — the reduction happens in VMEM, which is
what makes the filter memory-roofline-optimal: 16 bytes read per row, 4 bytes
written per query).

Layout note: super keys live in HBM as ``uint32[n, lanes]``; lanes is tiny
(4 for 128-bit hashes) and would be a terrible minor-most dim for the 8×128
VREG tiling, so the wrappers in ops.py transpose to ``[lanes, n]`` before the
call — each lane row is then a well-formed 128-aligned vector.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 1024
DEFAULT_BLOCK_Q = 256


def _match_kernel(row_ref, query_ref, out_ref, *, lanes: int):
    """row_ref: uint32[lanes, bn]; query_ref: uint32[lanes, bq];
    out_ref: int8[bn, bq]."""
    acc = None
    for lane in range(lanes):
        r = row_ref[lane, :]  # [bn]
        q = query_ref[lane, :]  # [bq]
        ok = (q[None, :] & ~r[:, None]) == 0  # [bn, bq]
        acc = ok if acc is None else (acc & ok)
    out_ref[...] = acc.astype(jnp.int8)


def _count_kernel(row_ref, query_ref, out_ref, *, lanes: int, n_blocks: int):
    """Fused filter+count: accumulates per-query candidate counts over the
    row-block grid axis. out_ref: int32[bq]."""
    i = pl.program_id(1)  # row-block index (inner grid axis)
    acc = None
    for lane in range(lanes):
        r = row_ref[lane, :]
        q = query_ref[lane, :]
        ok = (q[None, :] & ~r[:, None]) == 0
        acc = ok if acc is None else (acc & ok)
    partial = jnp.sum(acc.astype(jnp.int32), axis=0)  # [bq]

    @pl.when(i == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(i != 0)
    def _accum():
        out_ref[...] += partial


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_q", "interpret")
)
def filter_match(
    row_sk_t: jnp.ndarray,
    query_sk_t: jnp.ndarray,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    block_q: int = DEFAULT_BLOCK_Q,
    interpret: bool = False,
) -> jnp.ndarray:
    """Match matrix from transposed super keys.

    Args:
      row_sk_t:   uint32[lanes, n] (n divisible by block_n).
      query_sk_t: uint32[lanes, q] (q divisible by block_q).
    Returns:
      int8[n, q].
    """
    lanes, n = row_sk_t.shape
    _, q = query_sk_t.shape
    grid = (n // block_n, q // block_q)
    return pl.pallas_call(
        functools.partial(_match_kernel, lanes=lanes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((lanes, block_n), lambda i, j: (0, i)),
            pl.BlockSpec((lanes, block_q), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_q), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, q), jnp.int8),
        interpret=interpret,
    )(row_sk_t, query_sk_t)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_q", "interpret")
)
def filter_count(
    row_sk_t: jnp.ndarray,
    query_sk_t: jnp.ndarray,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    block_q: int = DEFAULT_BLOCK_Q,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused per-query candidate count. Returns int32[q]."""
    lanes, n = row_sk_t.shape
    _, q = query_sk_t.shape
    n_blocks = n // block_n
    grid = (q // block_q, n_blocks)  # row axis INNER → sequential accumulation
    return pl.pallas_call(
        functools.partial(_count_kernel, lanes=lanes, n_blocks=n_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((lanes, block_n), lambda j, i: (0, i)),
            pl.BlockSpec((lanes, block_q), lambda j, i: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_q,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.int32),
        interpret=interpret,
    )(row_sk_t, query_sk_t)
