"""Pallas TPU kernel for the super-key row filter (paper §6.3).

This is MATE's hot loop: for every (candidate row, query key) pair test
``(q & ~row) == 0`` over the hash lanes.  On TPU this is a pure-VPU
streaming workload; the kernel tiles both operands into VMEM and emits either
the match matrix, a fused per-query count, or a fused per-TABLE segment count
(``filter_table_counts``: subsumption ∧ eligibility row-summed and
scatter-accumulated over the CSR table ids — the reduction happens in VMEM,
the n×q matrix never reaches HBM, which is what makes the filter
memory-roofline-optimal: 16 bytes read per row, 4 bytes written per table).

Layout note: super keys live in HBM as ``uint32[n, lanes]``; lanes is tiny
(4 for 128-bit hashes) and would be a terrible minor-most dim for the 8×128
VREG tiling, so the wrappers in ops.py transpose to ``[lanes, n]`` before the
call — each lane row is then a well-formed 128-aligned vector.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_N = 1024
DEFAULT_BLOCK_Q = 256

# The fused count kernel's one-hot scatter tile is [block_n, tb] f32; keep it
# within ~4 MiB of VMEM.  At the table cap the block floor (128, the lane-dim
# tiling minimum) sits exactly on budget: 128 · 8192 · 4 B = 4 MiB.
FUSED_ONEHOT_BUDGET = 1 << 20  # block_n · tb elements
FUSED_MAX_TABLES = 8192


def fused_block_n(n_tables_padded: int, cap: int = DEFAULT_BLOCK_N) -> int:
    """Row-block size for ``filter_table_counts``: the largest power of two
    ≤ ``cap`` keeping the one-hot tile within FUSED_ONEHOT_BUDGET, floored at
    128.  Power-of-two so it divides every padded row count the wrappers
    produce (pow2 buckets below 8192, multiples of 8192 above)."""
    b = 128
    while b * 2 <= cap and (b * 2) * n_tables_padded <= FUSED_ONEHOT_BUDGET:
        b *= 2
    return b


def _match_kernel(row_ref, query_ref, out_ref, *, lanes: int):
    """row_ref: uint32[lanes, bn]; query_ref: uint32[lanes, bq];
    out_ref: int8[bn, bq]."""
    acc = None
    for lane in range(lanes):
        r = row_ref[lane, :]  # [bn]
        q = query_ref[lane, :]  # [bq]
        ok = (q[None, :] & ~r[:, None]) == 0  # [bn, bq]
        acc = ok if acc is None else (acc & ok)
    out_ref[...] = acc.astype(jnp.int8)


def _count_kernel(row_ref, query_ref, out_ref, *, lanes: int, n_blocks: int):
    """Fused filter+count: accumulates per-query candidate counts over the
    row-block grid axis. out_ref: int32[bq]."""
    i = pl.program_id(1)  # row-block index (inner grid axis)
    acc = None
    for lane in range(lanes):
        r = row_ref[lane, :]
        q = query_ref[lane, :]
        ok = (q[None, :] & ~r[:, None]) == 0
        acc = ok if acc is None else (acc & ok)
    partial = jnp.sum(acc.astype(jnp.int32), axis=0)  # [bq]

    @pl.when(i == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(i != 0)
    def _accum():
        out_ref[...] += partial


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_q", "interpret")
)
def filter_match(
    row_sk_t: jnp.ndarray,
    query_sk_t: jnp.ndarray,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    block_q: int = DEFAULT_BLOCK_Q,
    interpret: bool = False,
) -> jnp.ndarray:
    """Match matrix from transposed super keys.

    Args:
      row_sk_t:   uint32[lanes, n] (n divisible by block_n).
      query_sk_t: uint32[lanes, q] (q divisible by block_q).
    Returns:
      int8[n, q].
    """
    lanes, n = row_sk_t.shape
    _, q = query_sk_t.shape
    grid = (n // block_n, q // block_q)
    return pl.pallas_call(
        functools.partial(_match_kernel, lanes=lanes),
        grid=grid,
        in_specs=[
            pl.BlockSpec((lanes, block_n), lambda i, j: (0, i)),
            pl.BlockSpec((lanes, block_q), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_n, block_q), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, q), jnp.int8),
        interpret=interpret,
    )(row_sk_t, query_sk_t)


def _table_counts_kernel(
    *refs, lanes: int, mode: str, has_elig: bool, n_queries: int
):
    """Fused filter + segment-count: subsumption ∧ eligibility, row-summed and
    scatter-accumulated into per-table counts via the CSR segment ids — the
    [bn, bq] match tile lives only in VREGs/VMEM and is reduced before the
    next grid step, so the n×q matrix never reaches HBM.

    Refs (has_elig controls arity):
      row_ref:    uint32[lanes, bn]   candidate-row super keys (transposed)
      query_ref:  uint32[lanes, bq]   query-key super keys (transposed)
      elig_ref:   int8[bn, bq]        eligibility (only when has_elig)
      seg_ref:    int32[bn]           table index per row; -1 = padding row
      counts_ref: int32[tb]           per-table counts (ONE block, all steps)
      key_ref:    int32[bq]           per-key survivor counts

    ``mode``: 'sum' counts eligible (row, key) hits per table (the engines'
    exact rule-2 bound); 'any' counts rows matching ≥1 key (the distributed
    filter's per-table semantics — requires a single query block, since
    per-block ORs cannot be summed across query blocks).

    The scatter is a one-hot f32 matvec: seg ids broadcast-compared against
    the table-id iota, then per_row @ onehot on the MXU.  f32 accumulation is
    exact here (per-step partials are bounded by bn·bq « 2^24).
    """
    if has_elig:
        row_ref, query_ref, elig_ref, seg_ref, counts_ref, key_ref = refs
    else:
        row_ref, query_ref, seg_ref, counts_ref, key_ref = refs
        elig_ref = None
    j = pl.program_id(0)  # query-block index
    i = pl.program_id(1)  # row-block index (inner grid axis → sequential)
    acc = None
    for lane in range(lanes):
        r = row_ref[lane, :]  # [bn]
        q = query_ref[lane, :]  # [bq]
        ok = (q[None, :] & ~r[:, None]) == 0  # [bn, bq]
        acc = ok if acc is None else (acc & ok)
    if elig_ref is not None:
        acc = acc & (elig_ref[...] != 0)
    # mask padded query columns (col id ≥ n_queries): their all-ones super
    # keys match nothing EXCEPT saturated (all-ones) row super keys, which
    # would otherwise be overcounted when no eligibility mask zero-pads them
    bn_, bq_ = acc.shape
    col = j * bq_ + jax.lax.broadcasted_iota(jnp.int32, (bn_, bq_), 1)
    acc = acc & (col < n_queries)
    seg = seg_ref[...]  # [bn]
    acc = acc & (seg >= 0)[:, None]  # padding rows contribute nothing
    acc_i32 = acc.astype(jnp.int32)
    key_partial = jnp.sum(acc_i32, axis=0)  # [bq]
    per_row = jnp.sum(acc_i32, axis=1)  # [bn]
    if mode == "any":
        per_row = (per_row > 0).astype(jnp.int32)
    bn = per_row.shape[0]
    tb = counts_ref.shape[0]
    # one-hot scatter: -1 (padding) matches no iota column → contributes 0.
    onehot = seg[:, None] == jax.lax.broadcasted_iota(jnp.int32, (bn, tb), 1)
    partial = jnp.dot(
        per_row.astype(jnp.float32)[None, :],
        onehot.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )[0].astype(jnp.int32)  # [tb]

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _init_counts():
        counts_ref[...] = partial

    @pl.when(jnp.logical_or(i != 0, j != 0))
    def _accum_counts():
        counts_ref[...] += partial

    @pl.when(i == 0)
    def _init_keys():
        key_ref[...] = key_partial

    @pl.when(i != 0)
    def _accum_keys():
        key_ref[...] += key_partial


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_tables", "n_queries", "block_n", "block_q", "mode", "interpret"
    ),
)
def filter_table_counts(
    row_sk_t: jnp.ndarray,
    query_sk_t: jnp.ndarray,
    elig: jnp.ndarray | None,
    seg_ids: jnp.ndarray,
    *,
    n_tables: int,
    n_queries: int | None = None,
    block_n: int = DEFAULT_BLOCK_N,
    block_q: int = DEFAULT_BLOCK_Q,
    mode: str = "sum",
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused filter + per-table segment count from transposed super keys.

    Args:
      row_sk_t:   uint32[lanes, n] (n divisible by block_n).
      query_sk_t: uint32[lanes, q] (q divisible by block_q).
      elig:       int8[n, q] eligibility, or None for all-eligible.
      seg_ids:    int32[n] table index per row (-1 for padding rows).
      n_tables:   padded table count tb (multiple of 128).
      n_queries:  number of REAL queries (≤ q); columns beyond it are
                  padding and contribute nothing even to saturated
                  (all-ones) row super keys.  Defaults to q.
    Returns:
      (counts int32[tb], key_counts int32[q]) — the ONLY outputs; the n×q
      match matrix is never materialised.
    """
    assert mode in ("sum", "any")
    lanes, n = row_sk_t.shape
    _, q = query_sk_t.shape
    n_queries = q if n_queries is None else n_queries
    if mode == "any":
        # per-row ANY cannot be accumulated across query blocks
        assert q == block_q, "mode='any' needs the whole query range in one block"
    grid = (q // block_q, n // block_n)  # row axis INNER → sequential accum
    in_specs = [
        pl.BlockSpec((lanes, block_n), lambda j, i: (0, i)),
        pl.BlockSpec((lanes, block_q), lambda j, i: (0, j)),
    ]
    operands = [row_sk_t, query_sk_t]
    if elig is not None:
        in_specs.append(pl.BlockSpec((block_n, block_q), lambda j, i: (i, j)))
        operands.append(elig)
    in_specs.append(pl.BlockSpec((block_n,), lambda j, i: (i,)))
    operands.append(seg_ids)
    counts, key_counts = pl.pallas_call(
        functools.partial(
            _table_counts_kernel,
            lanes=lanes,
            mode=mode,
            has_elig=elig is not None,
            n_queries=n_queries,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((n_tables,), lambda j, i: (0,)),
            pl.BlockSpec((block_q,), lambda j, i: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tables,), jnp.int32),
            jax.ShapeDtypeStruct((q,), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return counts, key_counts


def _gather_counts_kernel(
    *refs, lanes: int, has_elig: bool, n_queries: int, block_n: int
):
    """Gather-fused filter + segment-count: one launch from posting-list row
    offsets to per-table counts.

    The candidate rows' super keys are DMA-gathered from the device-resident
    store (HBM, ``memory_space=ANY``) straight into a VMEM scratch tile using
    the scalar-prefetched row offsets — the rows×lanes candidate block never
    exists in HBM, and the host never gathers (or ships) it at all.  The
    gathered tile then feeds the same subsume ∧ elig → row-sum → one-hot-MXU
    scatter as ``_table_counts_kernel``.

    Refs (``rows_ref`` is the scalar-prefetch operand; has_elig sets arity):
      rows_ref:   int32[n]            posting-list row offsets (SMEM)
      store_ref:  uint32[N, lanes_s]  per-row super-key store (HBM/ANY)
      query_ref:  uint32[lanes, bq]   query-key super keys (transposed)
      elig_ref:   int8[bn, bq]        eligibility (only when has_elig)
      seg_ref:    int32[bn]           table index per row; -1 = padding row
      counts_ref: int32[tb]           per-table counts (ONE block, all steps)
      row_vmem:   uint32[bn, lanes_s] gathered super-key scratch tile
      sem:        DMA semaphore for the gather copies

    Grid is (row blocks, query blocks) with the QUERY axis innermost, the
    transpose of ``_table_counts_kernel``'s grid: the gather runs once per
    row block (at ``j == 0``) and the scratch tile is reused across the
    query-block sweep.  That ordering is only possible because this kernel
    has no per-key output — per-key counts would need consecutive row steps
    per query block — so it emits per-table counts alone ('sum' semantics).

    ``lanes`` is the number of lanes PROBED (== the query operand's lane
    count).  It may be smaller than the store's lane count (the serving
    tier's lane-prefix degrade): each row DMA still moves the full store row
    — 16..64 contiguous bytes — but only the first ``lanes`` columns of the
    scratch tile enter the subsumption test.
    """
    if has_elig:
        rows_ref, store_ref, query_ref, elig_ref, seg_ref, counts_ref = refs[:6]
        row_vmem, sem = refs[6:]
    else:
        rows_ref, store_ref, query_ref, seg_ref, counts_ref = refs[:5]
        elig_ref = None
        row_vmem, sem = refs[5:]
    i = pl.program_id(0)  # row-block index (outer)
    j = pl.program_id(1)  # query-block index (inner → scratch reuse across j)

    @pl.when(j == 0)
    def _gather():
        # one DMA per candidate row: store rows are contiguous [lanes_s]
        # uint32 runs, so each descriptor moves one aligned 16..64-byte line.
        # All copies are issued back-to-back, then drained — the per-row
        # latency overlaps across the outstanding queue.
        def _start(r, _):
            idx = rows_ref[i * block_n + r]
            pltpu.make_async_copy(
                store_ref.at[pl.ds(idx, 1)], row_vmem.at[pl.ds(r, 1)], sem
            ).start()
            return 0

        jax.lax.fori_loop(0, block_n, _start, 0)

        def _wait(r, _):
            idx = rows_ref[i * block_n + r]
            pltpu.make_async_copy(
                store_ref.at[pl.ds(idx, 1)], row_vmem.at[pl.ds(r, 1)], sem
            ).wait()
            return 0

        jax.lax.fori_loop(0, block_n, _wait, 0)

    acc = None
    for lane in range(lanes):
        r = row_vmem[:, lane]  # [bn]
        q = query_ref[lane, :]  # [bq]
        ok = (q[None, :] & ~r[:, None]) == 0  # [bn, bq]
        acc = ok if acc is None else (acc & ok)
    if elig_ref is not None:
        acc = acc & (elig_ref[...] != 0)
    # mask padded query columns — same phantom-column guard as the non-gather
    # fused kernel (saturated store rows would otherwise count them).
    bn_, bq_ = acc.shape
    col = j * bq_ + jax.lax.broadcasted_iota(jnp.int32, (bn_, bq_), 1)
    acc = acc & (col < n_queries)
    seg = seg_ref[...]  # [bn]
    acc = acc & (seg >= 0)[:, None]  # padding rows contribute nothing
    per_row = jnp.sum(acc.astype(jnp.int32), axis=1)  # [bn]
    tb = counts_ref.shape[0]
    onehot = seg[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (block_n, tb), 1
    )
    partial = jnp.dot(
        per_row.astype(jnp.float32)[None, :],
        onehot.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )[0].astype(jnp.int32)  # [tb]

    @pl.when(jnp.logical_and(i == 0, j == 0))
    def _init_counts():
        counts_ref[...] = partial

    @pl.when(jnp.logical_or(i != 0, j != 0))
    def _accum_counts():
        counts_ref[...] += partial


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_tables", "n_queries", "block_n", "block_q", "interpret"
    ),
)
def gather_filter_table_counts(
    rows: jnp.ndarray,
    store: jnp.ndarray,
    query_sk_t: jnp.ndarray,
    elig: jnp.ndarray | None,
    seg_ids: jnp.ndarray,
    *,
    n_tables: int,
    n_queries: int | None = None,
    block_n: int = DEFAULT_BLOCK_N,
    block_q: int = DEFAULT_BLOCK_Q,
    interpret: bool = False,
) -> jnp.ndarray:
    """Gather-fused filter + per-table segment count.

    One launch from posting-list offsets to counts: ``rows`` (the CSR
    candidate row ids) is scalar-prefetched, and each grid step DMA-gathers
    its row block of ``store`` into VMEM before the fused subsume ∧ elig +
    reduce + scatter — the gathered rows×lanes block never touches HBM.

    Args:
      rows:       int32[n] row offsets into ``store`` (n divisible by
                  block_n; padding offsets must be valid, e.g. 0, and carry
                  seg id -1).
      store:      uint32[N, lanes_s] device-resident super-key store,
                  ROW-major (each row's lanes contiguous, one DMA line).
      query_sk_t: uint32[lanes, q] transposed query super keys (q divisible
                  by block_q); ``lanes <= lanes_s`` — a strict prefix probes
                  a lane-degraded filter over the full-width store.
      elig:       int8[n, q] eligibility, or None for all-eligible.
      seg_ids:    int32[n] table index per row (-1 for padding rows).
      n_tables:   padded table count tb (multiple of 128).
      n_queries:  number of REAL queries (≤ q).
    Returns:
      counts int32[tb] — the ONLY output (no per-key counts: the grid runs
      query-blocks innermost so the gather amortises over them, which rules
      out the per-key accumulation layout of ``filter_table_counts``).
    """
    lanes, q = query_sk_t.shape
    n = rows.shape[0]
    assert lanes <= store.shape[1], (lanes, store.shape)
    n_queries = q if n_queries is None else n_queries
    grid = (n // block_n, q // block_q)  # query axis INNER → scratch reuse
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.ANY),  # store stays in HBM
        pl.BlockSpec((lanes, block_q), lambda i, j, rows_ref: (0, j)),
    ]
    operands = [store, query_sk_t]
    if elig is not None:
        in_specs.append(
            pl.BlockSpec((block_n, block_q), lambda i, j, rows_ref: (i, j))
        )
        operands.append(elig)
    in_specs.append(pl.BlockSpec((block_n,), lambda i, j, rows_ref: (i,)))
    operands.append(seg_ids)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((n_tables,), lambda i, j, rows_ref: (0,)),
        scratch_shapes=[
            pltpu.VMEM((block_n, store.shape[1]), jnp.uint32),
            pltpu.SemaphoreType.DMA,
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _gather_counts_kernel,
            lanes=lanes,
            has_elig=elig is not None,
            n_queries=n_queries,
            block_n=block_n,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tables,), jnp.int32),
        interpret=interpret,
    )(rows, *operands)


@functools.partial(
    jax.jit, static_argnames=("block_n", "block_q", "interpret")
)
def filter_count(
    row_sk_t: jnp.ndarray,
    query_sk_t: jnp.ndarray,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    block_q: int = DEFAULT_BLOCK_Q,
    interpret: bool = False,
) -> jnp.ndarray:
    """Fused per-query candidate count. Returns int32[q]."""
    lanes, n = row_sk_t.shape
    _, q = query_sk_t.shape
    n_blocks = n // block_n
    grid = (q // block_q, n_blocks)  # row axis INNER → sequential accumulation
    return pl.pallas_call(
        functools.partial(_count_kernel, lanes=lanes, n_blocks=n_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((lanes, block_n), lambda j, i: (0, i)),
            pl.BlockSpec((lanes, block_q), lambda j, i: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_q,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((q,), jnp.int32),
        interpret=interpret,
    )(row_sk_t, query_sk_t)
