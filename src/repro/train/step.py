"""Training step: chunked cross-entropy, MTP loss, remat, jit/shard wiring.

The loss head is CHUNKED over the sequence: hidden states are projected to
vocab logits one seq-chunk at a time inside a scan, so the [B, S, V] logits
tensor (the largest activation of LM training at 150k vocabs) never
materialises — peak activation memory drops by O(S/chunk).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers, transformer
from repro.models.config import ModelConfig
from repro.train import optimizer as opt


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: opt.AdamWConfig = dataclasses.field(default_factory=opt.AdamWConfig)
    remat: bool = True
    ce_chunk: int = 1024  # seq chunk for the loss head (0 → unchunked)
    mtp_weight: float = 0.3
    z_loss: float = 1e-4


def _ce_from_logits(logits: jnp.ndarray, labels: jnp.ndarray, z_loss: float):
    """Mean CE over valid (label >= 0) positions + z-loss. f32."""
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = (lse - gold) + z_loss * lse ** 2
    ce = jnp.where(valid, ce, 0.0)
    return jnp.sum(ce), jnp.sum(valid)


def chunked_ce(hidden: jnp.ndarray, head: jnp.ndarray, labels: jnp.ndarray,
               chunk: int, z_loss: float):
    """hidden [B,S,D] @ head [D,V] vs labels [B,S] without a full [B,S,V]."""
    b, s, d = hidden.shape
    if chunk <= 0 or s <= chunk:
        logits = (hidden @ head).astype(jnp.float32)
        tot, cnt = _ce_from_logits(logits, labels, z_loss)
        return tot / jnp.maximum(cnt, 1)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = (s + pad) // chunk
    hc = hidden.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint  # recompute chunk logits in backward: [B,chunk,V] never
    def body(carry, inp):  # outlives its chunk (forward OR backward)
        tot, cnt = carry
        h, l = inp
        h = layers.constrain_batch(h, 0)
        logits = (h @ head).astype(jnp.float32)
        logits = layers.constrain_batch(logits, 0, 2)  # vocab TP-sharded
        t, c = _ce_from_logits(logits, l, z_loss)
        return (tot + t, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),) * 2, (hc, lc))
    return tot / jnp.maximum(cnt, 1)


def loss_fn(params, cfg: ModelConfig, tcfg: TrainConfig, batch: dict):
    """batch: tokens int32[B,S], labels int32[B,S] (+frames/patches)."""
    tokens, labels = batch["tokens"], batch["labels"]
    kw = {k: batch[k] for k in ("frames", "patches") if k in batch}
    hidden, aux = transformer.forward_hidden(
        params, cfg, tokens, remat=tcfg.remat, **kw
    )
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(hidden.dtype)
    loss = chunked_ce(hidden, head, labels, tcfg.ce_chunk, tcfg.z_loss)
    metrics = {"ce": loss, "aux": aux}
    if cfg.mtp_depth:
        mtp_h = transformer.mtp_hidden(params, cfg, tokens, hidden)
        # MTP predicts token t+2: labels shifted one extra step
        mtp_labels = jnp.concatenate(
            [labels[:, 1:], jnp.full_like(labels[:, :1], -1)], axis=1
        )
        mtp_loss = chunked_ce(mtp_h, head, mtp_labels, tcfg.ce_chunk, tcfg.z_loss)
        loss = loss + tcfg.mtp_weight * mtp_loss
        metrics["mtp"] = mtp_loss
    loss = loss + aux
    metrics["loss"] = loss
    return loss, metrics


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    jit-compatible; the caller supplies in/out shardings for pjit-style
    distribution (launch/train.py and launch/dryrun.py do).
    """

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, tcfg, batch), has_aux=True
        )(params)
        params, opt_state, om = opt.adamw_update(
            params, grads, opt_state, tcfg.adamw
        )
        metrics.update(om)
        return params, opt_state, metrics

    return train_step
