"""AdamW from scratch, with optional 8-bit (block-quantised) moments.

No optax in this environment — this is a complete implementation:
  * decoupled weight decay, bias correction, global-norm clipping;
  * moment dtype selectable: f32 (default), bf16, or int8 with per-block
    absmax scales (the distributed-memory optimisation: cuts optimizer HBM
    by 4× / 8×, visible in the dry-run memory_analysis);
  * states mirror parameter pytrees so GSPMD shards them identically to
    their parameters (ZeRO-3 falls out of the FSDP param specs).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

Q_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_dtype: str = "f32"  # 'f32' | 'bf16' | 'int8'


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_frac."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


# -- int8 block quantisation --------------------------------------------------

def _quant(x: jnp.ndarray) -> dict:
    flat = x.reshape(-1)
    pad = (-flat.size) % Q_BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, Q_BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dequant(d: dict, shape: tuple[int, ...]) -> jnp.ndarray:
    flat = (d["q"].astype(jnp.float32) * d["scale"]).reshape(-1)
    size = 1
    for s in shape:
        size *= s
    return flat[:size].reshape(shape)


def _make_state(p: jnp.ndarray, dtype: str):
    if dtype == "int8":
        return _quant(jnp.zeros(p.shape, jnp.float32))
    dt = jnp.float32 if dtype == "f32" else jnp.bfloat16
    return jnp.zeros(p.shape, dt)


def _read_state(s, dtype: str, shape: tuple[int, ...]) -> jnp.ndarray:
    if dtype == "int8":
        return _dequant(s, shape)
    return s.astype(jnp.float32)


def _write_state(x: jnp.ndarray, dtype: str):
    if dtype == "int8":
        return _quant(x)
    return x.astype(jnp.float32 if dtype == "f32" else jnp.bfloat16)


# -- public API ----------------------------------------------------------------

def init_state(params, cfg: AdamWConfig) -> dict:
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: _make_state(p, cfg.state_dtype), params),
        "v": jax.tree.map(lambda p: _make_state(p, cfg.state_dtype), params),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step (pure function). Returns (params, state, metrics)."""
    step = state["step"] + 1
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
    lr = schedule(cfg, step.astype(jnp.float32))
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mf = _read_state(m, cfg.state_dtype, p.shape)
        vf = _read_state(v, cfg.state_dtype, p.shape)
        mf = cfg.b1 * mf + (1 - cfg.b1) * g
        vf = cfg.b2 * vf + (1 - cfg.b2) * g * g
        u = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return p_new, _write_state(mf, cfg.state_dtype), _write_state(vf, cfg.state_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return (
        new_p,
        {"step": step, "m": new_m, "v": new_v},
        {"grad_norm": gn, "lr": lr},
    )


apply_updates = partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0, 2))(
    adamw_update
)
