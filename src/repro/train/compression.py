"""Gradient compression for data-parallel reduction: int8 + error feedback.

For manual-DP training (shard_map over the data axis — the pipeline-parallel
and elastic paths use it), gradients are quantised to int8 with per-tensor
scales BEFORE the cross-replica psum, cutting DP all-reduce bytes 4×
(bf16→int8) while error feedback keeps the optimiser unbiased over steps:

    e_t   accumulated local quantisation residual
    q_t   = quant(g_t + e_t);  e_{t+1} = (g_t + e_t) - dequant(q_t)
    ĝ_t   = psum(q_t) · scale / n_replicas

With GSPMD/jit training the reduction is implicit in the backward pass, so
this module targets the explicit-collective paths; tests validate unbiased
convergence vs exact reduction.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g)) / 127.0
    q = jnp.clip(jnp.round(g / jnp.maximum(scale, 1e-12)), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, errors, axis_name: str):
    """Per-leaf int8 psum with error feedback.

    Returns (reduced_grads f32, new_errors).  Must run inside shard_map with
    ``axis_name`` mapped to the data-parallel mesh axis.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = quantize(gf)
        new_e = gf - dequantize(q, scale)
        # int8 values summed in int32 to avoid overflow; scales averaged —
        # each replica contributes q_i * scale_i, we reduce q_i*scale_i
        # exactly by reducing the f32 dequantised tensor's int part:
        red = jax.lax.psum(dequantize(q, scale), axis_name) / n
        return red, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in out]),
        jax.tree.unflatten(treedef, [o[1] for o in out]),
    )
