"""GPipe pipeline parallelism over a mesh axis (usually the DCN 'pod' axis).

Layers are stacked [L, ...] and viewed as [n_stages, L/n_stages, ...] with
dim0 sharded over the stage axis via shard_map; activations hand off between
stages with ``lax.ppermute`` inside a ``lax.scan`` over the GPipe schedule
(T = n_micro + n_stages - 1 ticks, bubble fraction (S-1)/T).  ``jax.grad``
differentiates straight through (ppermute's transpose is the reverse
permute), so the 1F1B-style backward falls out of autodiff.

Supports 'uniform'-pattern decoder configs (every assigned dense arch).  The
embedding/head run on every stage replica but only their own tick's data is
used — simple, and the matmuls are negligible next to the stack.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

# jax.shard_map landed after 0.4.x; fall back to the experimental home,
# which spells check_vma as check_rep
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def _shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _exp_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_vma,
        )

from repro.models import layers as L, transformer
from repro.models.config import ModelConfig
from repro.train.step import chunked_ce


def stage_view(params: dict, n_stages: int) -> dict:
    """Reshape stacked layer weights [L, ...] -> [n_stages, L/S, ...]."""
    out = dict(params)
    out["layers"] = jax.tree.map(
        lambda a: a.reshape((n_stages, a.shape[0] // n_stages) + a.shape[1:]),
        params["layers"],
    )
    return out


def pipeline_loss_fn(
    cfg: ModelConfig,
    mesh: Mesh,
    n_micro: int,
    staged_example,
    stage_axis: str = "pod",
    batch_axes: tuple = ("data",),
):
    """Returns loss(params_staged, tokens, labels) with pipeline execution.

    params_staged: model params with ['layers'] leaves shaped
    [n_stages, L/S, ...] (dim0 sharded over ``stage_axis``); other params
    replicated. ``staged_example``: any pytree with that structure (used to
    build per-leaf shard_map specs).  tokens/labels: [B, S] over batch_axes.
    """
    n_stages = mesh.shape[stage_axis]
    plans = transformer.group_plans(cfg)
    assert len(plans) == 1 and plans[0].name == "layers", (
        "pipeline parallelism supports uniform decoder stacks"
    )
    plan = plans[0]
    pspec = jax.tree.map(lambda _: P(), staged_example)
    pspec["layers"] = jax.tree.map(lambda _: P(stage_axis), staged_example["layers"])

    def stack_fwd(layer_params, x, positions):
        def body(carry, lp):
            h = carry
            for i, (mixer, ffn) in enumerate(plan.sublayers):
                window = cfg.sliding_window if mixer == "attn" else 0
                h, _ = transformer._layer_fwd(
                    lp[f"s{i}"], cfg, h, positions, mixer, ffn, window=window
                )
            return h, None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, layer_params)
        return x

    @functools.partial(
        _shard_map,
        mesh=mesh,
        in_specs=(pspec, P(batch_axes, None), P(batch_axes, None)),
        out_specs=P(),
        check_vma=False,
    )
    def run(staged_params, tokens, labels):
        stage = jax.lax.axis_index(stage_axis)
        local_layers = jax.tree.map(lambda a: a[0], staged_params["layers"])
        b, s = tokens.shape
        assert b % n_micro == 0, (b, n_micro)
        mb = b // n_micro
        positions = jnp.arange(s, dtype=jnp.int32)
        micros_t = tokens.reshape(n_micro, mb, s)
        micros_l = labels.reshape(n_micro, mb, s)
        embed = staged_params["embed"].astype(jnp.bfloat16)
        head = (
            staged_params["embed"].T
            if cfg.tie_embeddings
            else staged_params["lm_head"]
        ).astype(jnp.bfloat16)

        n_ticks = n_micro + n_stages - 1
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def tick(carry, t):
            x_state, loss_sum, cnt_sum = carry
            # stage 0 ingests microbatch t (or zeros past the end)
            mt = micros_t[jnp.minimum(t, n_micro - 1)]
            x_in0 = embed[mt]
            x_in = jnp.where(stage == 0, x_in0, x_state)
            y = stack_fwd(local_layers, x_in, positions)
            # last stage: loss for microbatch (t - (n_stages-1))
            mi = t - (n_stages - 1)
            lab = micros_l[jnp.clip(mi, 0, n_micro - 1)]
            h = transformer.layers.norm_fwd(staged_params["final_norm"], cfg, y)
            lsum, lcnt = _masked_ce(h, head, lab)
            take = (stage == n_stages - 1) & (mi >= 0)
            loss_sum = loss_sum + jnp.where(take, lsum, 0.0)
            cnt_sum = cnt_sum + jnp.where(take, lcnt, 0.0)
            # hand off activations to the next stage
            x_next = jax.lax.ppermute(y, stage_axis, perm)
            return (x_next, loss_sum, cnt_sum), None

        x0 = jnp.zeros((mb, s, cfg.d_model), jnp.bfloat16)
        (xf, loss_sum, cnt_sum), _ = jax.lax.scan(
            tick, (x0, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            jnp.arange(n_ticks),
        )
        # total over stages (only last stage contributed) and batch shards
        loss_sum = jax.lax.psum(loss_sum, stage_axis)
        cnt_sum = jax.lax.psum(cnt_sum, stage_axis)
        if batch_axes:
            loss_sum = jax.lax.psum(loss_sum, batch_axes)
            cnt_sum = jax.lax.psum(cnt_sum, batch_axes)
        return loss_sum / jnp.maximum(cnt_sum, 1.0)

    def _masked_ce(h, head, labels):
        logits = (h @ head).astype(jnp.float32)
        valid = labels >= 0
        safe = jnp.maximum(labels, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        ce = jnp.where(valid, lse - gold, 0.0)
        return jnp.sum(ce), jnp.sum(valid).astype(jnp.float32)

    return run
