"""Fault-tolerant checkpointing: atomic, keep-K, elastic re-shard on restore.

Layout (one directory per step)::

    <dir>/step_000123.tmp/   → written, fsynced, then atomically renamed to
    <dir>/step_000123/
        manifest.json        (pytree structure, shapes, dtypes, step)
        arr_00000.npy ...    (one file per leaf, saved as FULL arrays)

Restore is mesh-agnostic: leaves are loaded as host numpy and ``device_put``
with the CURRENT mesh's shardings — restarting on a different mesh (elastic
up/down-scaling after node failure) reshards transparently.  A SIGTERM
handler requests a final save (preemption tolerance); ``keep`` bounds disk.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import signal

import jax
import jax.numpy as jnp
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _leaves_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self.preempted = False
        os.makedirs(directory, exist_ok=True)

    def install_preemption_handler(self):
        def _handler(signum, frame):
            self.preempted = True

        signal.signal(signal.SIGTERM, _handler)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree) -> str:
        final = os.path.join(self.dir, f"step_{step:06d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _leaves_with_paths(tree)
        manifest = {"step": step, "leaves": []}
        for i, (path, leaf) in enumerate(flat):
            arr = np.asarray(jax.device_get(leaf))
            fname = f"arr_{i:05d}.npy"
            logical_dtype = str(arr.dtype)
            if arr.dtype not in (np.float64, np.float32, np.float16, np.int64,
                                 np.int32, np.int16, np.int8, np.uint64,
                                 np.uint32, np.uint16, np.uint8, np.bool_):
                # ml_dtypes (bfloat16, fp8, ...): persist as raw bytes
                arr = arr.view(np.uint8)
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append(
                {
                    "path": jax.tree_util.keystr(path),
                    "file": fname,
                    "shape": list(leaf.shape),
                    "dtype": logical_dtype,
                }
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):  # idempotent re-save of the same step
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:06d}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and os.path.isdir(os.path.join(self.dir, name)):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like, shardings=None):
        """Restore into the structure of ``like``; optional per-leaf shardings
        (pytree of NamedSharding) reshard onto the current mesh (elastic)."""
        path = os.path.join(self.dir, f"step_{step:06d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like = _leaves_with_paths(like)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        shard_flat = None
        if shardings is not None:
            shard_flat = [s for _, s in _leaves_with_paths(shardings)]
        leaves = []
        for i, (kpath, leaf) in enumerate(flat_like):
            entry = by_path[jax.tree_util.keystr(kpath)]
            arr = np.load(os.path.join(path, entry["file"]))
            if str(arr.dtype) != entry["dtype"]:
                import ml_dtypes

                arr = arr.view(np.dtype(getattr(ml_dtypes, entry["dtype"]))).reshape(
                    entry["shape"]
                )
            if shard_flat is not None:
                leaves.append(jax.device_put(arr, shard_flat[i]))
            else:
                leaves.append(jnp.asarray(arr))
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def restore_latest(self, like, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, like, shardings)
