"""Assigned input shapes and per-(arch × shape) applicability.

Four shapes per architecture (40 cells):
  train_4k     seq=4096   global_batch=256   → train_step
  prefill_32k  seq=32768  global_batch=32    → prefill
  decode_32k   seq=32768  global_batch=128   → serve_step (1 token, 32k cache)
  long_500k    seq=524288 global_batch=1     → serve_step (1 token, 500k ctx)

long_500k requires sub-quadratic context handling and is SKIPPED for pure
full-attention archs (see DESIGN.md §5); it runs for ssm/hybrid/SWA archs.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "pure full-attention arch: 512k dense KV decode is the quadratic "
            "regime this shape excludes (DESIGN.md §5)"
        )
    return True, ""
