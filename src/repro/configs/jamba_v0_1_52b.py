"""jamba-v0.1-52b [hybrid] — arXiv:2403.19887 (Mamba+attn 1:7, MoE).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, blocks of 8 layers
with 1 attention : 7 mamba, MoE (16 experts top-2) every other layer.
Mamba sublayers use the SSD formulation with d_state=16 (subsumes the
Mamba-1 block — DESIGN.md §2).  Hybrid → runs long_500k.
"""

from repro.models.config import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    layer_pattern="jamba",
    attn_every=8,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=256),
    moe=MoEConfig(n_routed=16, top_k=2, d_ff_expert=14336, every=2),
)
