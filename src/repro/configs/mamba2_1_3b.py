"""mamba2-1.3b [ssm] — arXiv:2405.21060 (SSD, attention-free).

48L d_model=2048 vocab=50280, ssm_state=128, expand=2 (d_inner 4096),
head_dim 64 → 64 SSD heads, no attention, no MLP (the Mamba block IS the
layer).  O(1) state → runs long_500k.
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    n_layers=48,
    d_model=2048,
    n_heads=1,  # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1, chunk=256),
)
