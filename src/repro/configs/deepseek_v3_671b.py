"""deepseek-v3-671b [moe] — arXiv:2412.19437 (MLA, 1 shared + 256 routed
top-8, MTP).

61L d_model=7168 128H, MLA (q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64,
v_head 128), MoE 256 routed experts top-8 + 1 shared (d_ff_expert 2048),
first 3 layers dense (d_ff 18432), vocab=129280, 1 MTP module.
"""

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,  # dense layers
    vocab_size=129280,
    rope_theta=10_000.0,
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    moe=MoEConfig(
        n_routed=256,
        top_k=8,
        d_ff_expert=2048,
        n_shared=1,
        d_ff_shared=2048,
        first_dense=3,
    ),
    mtp_depth=1,
)
