"""qwen1.5-0.5b [dense] — hf:Qwen/Qwen1.5-0.5B.

24L d_model=1024 16H (GQA kv=16) d_ff=2816 vocab=151936, QKV bias, tied
embeddings (0.5B ties lm_head to embed).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=2816,
    vocab_size=151936,
    attn_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
