"""starcoder2-3b [dense] — arXiv:2402.19173 (GQA, RoPE).

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152, LayerNorm + bias,
plain (non-gated) GELU MLP, attention bias.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    norm_type="ln",
    act="gelu",
    glu=False,
    attn_bias=True,
    rope_theta=100_000.0,
)
