"""Architecture registry + reduced smoke configs.

``--arch <id>`` everywhere resolves through ``get_config``.  ``reduce_config``
shrinks any config to a CPU-smoke scale of the SAME family (pattern, MoE,
MLA, SSM structure preserved; widths/depths/vocab tiny).
"""

from __future__ import annotations

import dataclasses

from repro.configs import (
    deepseek_v3_671b,
    h2o_danube_3_4b,
    jamba_v0_1_52b,
    llama_3_2_vision_11b,
    mamba2_1_3b,
    qwen1_5_0_5b,
    qwen2_moe_a2_7b,
    qwen3_32b,
    starcoder2_3b,
    whisper_base,
)
from repro.configs.shapes import SHAPES, ShapeSpec, applicable  # noqa: F401
from repro.models.config import (
    EncoderConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    VisionConfig,
)

ARCHS: dict[str, ModelConfig] = {
    "qwen1.5-0.5b": qwen1_5_0_5b.CONFIG,
    "qwen3-32b": qwen3_32b.CONFIG,
    "h2o-danube-3-4b": h2o_danube_3_4b.CONFIG,
    "starcoder2-3b": starcoder2_3b.CONFIG,
    "whisper-base": whisper_base.CONFIG,
    "deepseek-v3-671b": deepseek_v3_671b.CONFIG,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b.CONFIG,
    "llama-3.2-vision-11b": llama_3_2_vision_11b.CONFIG,
    "jamba-v0.1-52b": jamba_v0_1_52b.CONFIG,
    "mamba2-1.3b": mamba2_1_3b.CONFIG,
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch]


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw: dict = {}
    d_model = 64
    n_heads, n_kv = 4, max(1, min(cfg.n_kv_heads * 4 // max(cfg.n_heads, 1), 4))
    if cfg.layer_pattern == "jamba":
        n_layers = cfg.attn_every  # one block
    elif cfg.vision is not None:
        n_layers = cfg.vision.cross_attn_every
        kw["vision"] = VisionConfig(n_tokens=8, cross_attn_every=cfg.vision.cross_attn_every)
    elif cfg.moe is not None and cfg.moe.first_dense:
        n_layers = 3  # 1 dense + 2 moe (first_dense reduced to 1 below)
    else:
        n_layers = 2
    if cfg.encoder is not None:
        kw["encoder"] = EncoderConfig(n_layers=2, n_frames=16)
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(
            n_routed=4,
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=96,
            n_shared=min(cfg.moe.n_shared, 1),
            d_ff_shared=96 if cfg.moe.n_shared else 0,
            first_dense=1 if cfg.moe.first_dense else 0,
            every=cfg.moe.every,
        )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(
            q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
            qk_rope_head_dim=8, v_head_dim=16,
        )
        kw["d_head"] = 0
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(
            d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk=16
        )
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=kw.pop("d_head", 16),
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=256,
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else 0,
        max_seq_len=128,
        **kw,
    )
