"""llama-3.2-vision-11b [vlm] — hf:meta-llama/Llama-3.2-11B-Vision.

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; cross-attention to
image embeddings every 5th layer.  The vision tower is a STUB:
``input_specs()`` provides precomputed patch/tile embeddings
[batch, 1601, 4096].
"""

from repro.models.config import ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    vision=VisionConfig(n_tokens=1601, cross_attn_every=5),
)
