"""qwen2-moe-a2.7b [moe] — hf:Qwen/Qwen1.5-MoE-A2.7B.

24L d_model=2048 16H (kv=16) vocab=151936, MoE: 60 routed experts top-4
(d_ff_expert=1408) + 4 shared experts (fused shared MLP width 5632),
QKV bias (qwen1.5 family).  60 experts pad to 64 on a 16-way EP axis.
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    attn_bias=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(
        n_routed=60,
        top_k=4,
        d_ff_expert=1408,
        n_shared=4,
        d_ff_shared=5632,
    ),
)
