"""h2o-danube-3-4b [dense] — arXiv:2401.16818 (llama+mistral mix, SWA).

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, sliding-window
attention (mistral-style, window 4096) → sub-quadratic: runs long_500k.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    sliding_window=4096,
    rope_theta=10_000.0,
)
