"""whisper-base [audio] — arXiv:2212.04356 (enc-dec).

6L d_model=512 8H d_ff=2048 vocab=51865, encoder-decoder; the conv/mel
frontend is a STUB: ``input_specs()`` provides precomputed frame embeddings
[batch, 1500, 512].  Decoder self-attention uses RoPE here (adaptation from
Whisper's learned positions, noted in DESIGN.md) so 32k decode shapes are
well-defined for the backbone.
"""

from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    norm_type="ln",
    act="gelu",
    glu=False,
    encoder=EncoderConfig(n_layers=6, n_frames=1500),
)
