"""Asyncio serving tier acceptance — deterministic fake-clock harness.

Everything here runs under virtual time (``serve.clock.ManualClock`` / a
dict-backed callable for the sync engine): arrival order, deadline expiry,
pump wake-ups and cancellation races are driven cycle-by-cycle, so the
suite is wall-clock-free and cannot flake on a loaded CI runner.

Pinned contracts (ISSUE 6):
  * backpressure — at ``max_queue`` waiting requests 'shed' REJECTS the
    future with ``AdmissionError`` (never hangs it) while 'degrade' admits
    the request at ``degrade_bits`` lane-prefix filtering with results
    still bit-identical to cold discovery (hard shed at 2×max_queue);
  * deadline-aware partial groups — ``deadline_margin`` launches a partial
    group BEFORE ``flush_after`` expires (fixed margin, or an EWMA of
    observed group service times when configured None);
  * cancellation — a cancelled future never launches and stops holding a
    window slot;
  * pump resilience — a failing group launch rejects every sibling future
    AND the background pump task keeps serving later groups;
  * caches — query-result and bound-cache hits are bit-identical to a cold
    ``discover`` at the same index state, and any §5.4 insert/update/delete
    invalidates affected entries (property-tested over random
    submit/mutate interleavings, deterministic seeds + hypothesis).
"""

import asyncio
import dataclasses
import itertools

import numpy as np
import pytest

try:  # hypothesis ships in requirements-ci.txt; the seeded property matrix
    from hypothesis import given, settings, strategies as st  # always runs

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core import xash
from repro.core.batched import discover_batched
from repro.core.corpus import Table
from repro.core.discovery import DiscoveryStats
from repro.core.index import build_index
from repro.core.session import DiscoveryConfig, MateSession, VALID_BITS
from repro.data import synthetic
from repro.serve.cache import BoundCache, QueryResultCache, query_fingerprint
from repro.serve.clock import ManualClock
from repro.serve.engine import AdmissionError, AsyncDiscoveryEngine, DiscoveryEngine


@pytest.fixture(scope="module")
def lake():
    spec = synthetic.SyntheticSpec(n_tables=60, seed=0)
    corpus = synthetic.make_corpus(spec)
    queries = synthetic.make_mixed_queries(corpus, 6, 10, 2, seed=7)
    return corpus, queries


@pytest.fixture(scope="module")
def built(lake):
    """One (corpus, queries, index) per width; mutation tests build fresh."""
    corpus, queries = lake
    return {
        bits: build_index(corpus, cfg=xash.XashConfig(bits=bits))[0]
        for bits in VALID_BITS
    }


def _fresh_index(lake, bits=128):
    corpus, _ = lake
    spec = synthetic.SyntheticSpec(n_tables=60, seed=0)
    return build_index(
        synthetic.make_corpus(spec), cfg=xash.XashConfig(bits=bits)
    )[0]


def _engine(index, clock, **cfg):
    cfg.setdefault("k", 5)
    session = MateSession(index, DiscoveryConfig(**cfg))
    return DiscoveryEngine(session=session, clock=clock), session


def _key(entries):
    return [(e.table_id, e.joinability, e.mapping) for e in entries]


def _cold(index, query, q_cols, k=5):
    # raw-engine reference at the SESSION's default flags (rank='quality' +
    # profile gate), so cache-hit comparisons stay exact including order
    return _key(
        discover_batched(
            index, query, q_cols, k=k, rank="quality", profile_gate=True
        )[0]
    )


async def _spin(n=12):
    for _ in range(n):
        await asyncio.sleep(0)


# ---------------------------------------------------------------------------
# Backpressure: shed and degrade
# ---------------------------------------------------------------------------

def test_shed_rejects_future_not_hangs(built, lake):
    _, queries = lake
    clk = ManualClock()
    eng, session = _engine(
        built[128], clk.now, window=8, max_queue=2, pressure_policy="shed"
    )
    admitted = [eng.submit(*queries[i]) for i in range(2)]
    shed = eng.submit(*queries[2])
    assert shed.future.done() and not shed.done  # rejected, NOT hung
    with pytest.raises(AdmissionError):
        shed.future.result(timeout=0)
    assert session.stats.shed == 1
    assert eng.queue == admitted  # the shed request never entered the queue
    served = eng.flush()
    assert served == admitted and all(r.done for r in admitted)


def test_degrade_admits_at_narrow_width_bit_identical(built, lake):
    """Under pressure with policy='degrade' the request is admitted at
    128-bit lane-prefix filtering: filter stats show the narrow width and
    MORE survivors, but the exact-verified top-k is bit-identical."""
    _, queries = lake
    clk = ManualClock()
    eng, session = _engine(
        built[512], clk.now, window=8, max_queue=1,
        pressure_policy="degrade", degrade_bits=128,
    )
    normal = eng.submit(*queries[0])
    degraded = eng.submit(*queries[1])  # queue at max_queue → degraded
    assert degraded.degraded and not normal.degraded
    assert session.stats.degraded == 1 and session.stats.shed == 0
    eng.flush()
    # the degraded request's group ran at 4 lanes (128 bits) of the 16-lane
    # index — the verified SET is still exactly the cold 512-bit answer.
    # (Quality ORDER may differ: the scoring head's containment term reads
    # the filter counts, and lane-prefix counts are looser by design.)
    assert degraded.stats.filter_lanes == 4
    assert sorted(_key(degraded.results)) == sorted(_cold(built[512], *queries[1]))
    assert _key(normal.results) == _cold(built[512], *queries[0])
    # degraded (prefix) filtering can only pass MORE pairs, never fewer
    cold_passed = discover_batched(built[512], *queries[1], k=5)[1].filter_passed
    assert degraded.stats.filter_passed >= cold_passed


def test_degrade_hard_sheds_at_twice_max_queue(built, lake):
    _, queries = lake
    clk = ManualClock()
    eng, session = _engine(
        built[256], clk.now, window=16, max_queue=1, pressure_policy="degrade"
    )
    q, qc = queries[0]
    eng.submit(q, qc)
    deg = eng.submit(q, qc)
    assert deg.degraded
    hard = eng.submit(q, qc)  # queue already at 2×max_queue
    with pytest.raises(AdmissionError):
        hard.future.result(timeout=0)
    assert session.stats.shed == 1 and session.stats.degraded == 1


def test_unbounded_queue_never_sheds(built, lake):
    _, queries = lake
    clk = ManualClock()
    eng, session = _engine(built[128], clk.now, window=4)  # max_queue=None
    reqs = [eng.submit(*queries[i % len(queries)]) for i in range(20)]
    assert session.stats.shed == 0 and len(eng.queue) == 20
    eng.flush()
    assert all(r.done for r in reqs)


# ---------------------------------------------------------------------------
# Deadline-aware partial-group launch
# ---------------------------------------------------------------------------

def test_fixed_margin_launches_partial_group_early(built, lake):
    _, queries = lake
    clk = ManualClock()
    eng, _ = _engine(
        built[128], clk.now, window=8, flush_after=1.0, deadline_margin=0.25
    )
    r1 = eng.submit(*queries[0])
    assert eng.next_deadline() == pytest.approx(0.75)
    clk.advance(0.74)
    assert eng.pump() == []  # margin-adjusted deadline not reached
    clk.advance(0.01)
    assert eng.pump() == [r1]  # launched 0.25 BEFORE flush_after expires


def test_margin_preserves_arrival_order_across_groups(built, lake):
    """Deadlines derive from each group's OLDEST request: with a margin the
    first partial group launches early and the later submit launches in its
    own (later) group — ordering by arrival, never inverted."""
    _, queries = lake
    clk = ManualClock()
    eng, _ = _engine(
        built[128], clk.now, window=2, flush_after=1.0, deadline_margin=0.5
    )
    r1 = eng.submit(*queries[0])
    clk.advance(0.6)  # r1's margin-adjusted deadline (0.5) already passed
    r2 = eng.submit(*queries[1])
    served = eng.pump()
    # both were queued → window of 2 filled → one group, submission order
    assert served == [r1, r2]
    r3 = eng.submit(*queries[2])
    assert eng.pump() == []
    assert eng.next_deadline() == pytest.approx(0.6 + 0.5)
    clk.advance(0.5)
    assert eng.pump() == [r3]


def test_auto_margin_tracks_observed_service_time(built, lake):
    """deadline_margin=None: the engine learns the margin from an EWMA of
    group service times, measured on the injected clock.  The ticking clock
    advances 0.01 per read, and ``_serve_group`` reads it exactly twice
    (start/end), so every observed service time is exactly 0.01."""
    _, queries = lake
    t = {"now": 0.0}

    def ticking_clock():
        t["now"] += 0.01
        return t["now"]

    eng, _ = _engine(
        built[128], ticking_clock, window=4, flush_after=10.0,
        deadline_margin=None,
    )
    assert eng._margin() == 0.0  # nothing observed yet
    eng.submit(*queries[0])
    eng.flush()
    assert eng._margin() == pytest.approx(0.01)
    assert eng._margin() == eng._service_ewma
    # the learned margin moves next_deadline earlier than flush_after
    r = eng.submit(*queries[1])
    assert eng.next_deadline() == pytest.approx(r.arrival + 10.0 - 0.01)
    eng.flush()
    # EWMA of a constant signal stays put: 0.7*m + 0.3*0.01 == 0.01
    assert eng._margin() == pytest.approx(0.01)


# ---------------------------------------------------------------------------
# Cancellation
# ---------------------------------------------------------------------------

def test_cancelled_request_never_launches_and_frees_window(built, lake):
    _, queries = lake
    clk = ManualClock()
    eng, _ = _engine(built[128], clk.now, window=2, flush_after=None)
    r1 = eng.submit(*queries[0])
    r2 = eng.submit(*queries[1])
    assert r2.cancel() and r2.cancelled
    served = eng.pump()  # r2 purged → window of 2 no longer full
    assert served == [] and eng.queue == [r1]
    r3 = eng.submit(*queries[2])
    served = eng.pump()  # r1 + r3 fill the window; r2 never launches
    assert served == [r1, r3]
    assert r2.results is None and r2.future.cancelled()


def test_cancelled_mid_queue_flush_skips_it(built, lake):
    _, queries = lake
    clk = ManualClock()
    eng, session = _engine(built[128], clk.now, window=2, flush_after=None)
    reqs = [eng.submit(*queries[i]) for i in range(4)]
    reqs[1].cancel()
    reqs[3].cancel()
    served = eng.flush()
    assert served == [reqs[0], reqs[2]]
    assert session.stats.requests == 2  # cancelled requests cost nothing
    assert all(r.future.cancelled() for r in (reqs[1], reqs[3]))


# ---------------------------------------------------------------------------
# Async pump task: interleaving, failure resilience, lifecycle
# ---------------------------------------------------------------------------

def test_async_pump_serves_window_and_deadline_groups(built, lake):
    _, queries = lake

    async def run():
        clk = ManualClock()
        session = MateSession(
            built[128], DiscoveryConfig(k=5, window=2, flush_after=1.0)
        )
        async with AsyncDiscoveryEngine(session=session, clock=clk) as eng:
            # window path: two submits fill the group, no clock advance
            a = asyncio.ensure_future(eng.discover_async(*queries[0]))
            b = asyncio.ensure_future(eng.discover_async(*queries[1]))
            await asyncio.gather(a, b)
            # deadline path: a single straggler waits for virtual time
            c = asyncio.ensure_future(eng.discover_async(*queries[2]))
            await _spin()
            assert not c.done()  # partial group, deadline not reached
            clk.advance(1.0)
            rc = await c
            assert rc.done
        for task, (q, qc) in zip((a, b, c), queries[:3]):
            assert _key(task.result().results) == _cold(built[128], q, qc)

    asyncio.run(run())


def test_async_group_failure_rejects_siblings_and_pump_survives(built, lake):
    """Satellite fix: a failed group launch inside the BACKGROUND pump task
    must reject every sibling future and keep the pump alive for later
    groups (an uncaught exception would orphan the loop)."""
    _, queries = lake

    async def run():
        clk = ManualClock()
        session = MateSession(
            built[128], DiscoveryConfig(k=5, window=2, flush_after=None)
        )
        async with AsyncDiscoveryEngine(session=session, clock=clk) as eng:
            good_sib = asyncio.ensure_future(eng.discover_async(*queries[0]))
            bad = asyncio.ensure_future(
                eng.discover_async(queries[0][0], [99])  # IndexError in plan
            )
            with pytest.raises(IndexError):
                await bad
            with pytest.raises(IndexError):
                await good_sib  # sibling rejected, not hung
            assert eng.pump_errors == 1
            assert eng._task is not None and not eng._task.done()  # alive
            # the pump keeps serving fresh groups after the failure
            ra, rb = await asyncio.gather(
                eng.discover_async(*queries[1]), eng.discover_async(*queries[2])
            )
            assert ra.done and rb.done
            assert eng.pump_errors == 1

    asyncio.run(run())


def test_async_cancelled_futures_never_launch(built, lake):
    _, queries = lake

    async def run():
        clk = ManualClock()
        session = MateSession(
            built[128], DiscoveryConfig(k=5, window=2, flush_after=5.0)
        )
        async with AsyncDiscoveryEngine(session=session, clock=clk) as eng:
            doomed = eng.submit(*queries[0])
            await _spin()
            doomed.cancel()
            served_before = session.stats.requests
            a, b = await asyncio.gather(
                eng.discover_async(*queries[1]), eng.discover_async(*queries[2])
            )
            assert a.done and b.done
            assert doomed.results is None and doomed.future.cancelled()
            assert session.stats.requests == served_before + 2

    asyncio.run(run())


def test_async_stop_drain_false_rejects_backlog(built, lake):
    _, queries = lake

    async def run():
        clk = ManualClock()
        session = MateSession(
            built[128], DiscoveryConfig(k=5, window=8, flush_after=None)
        )
        eng = AsyncDiscoveryEngine(session=session, clock=clk)
        await eng.start()
        req = eng.submit(*queries[0])  # partial group, no deadline: waits
        await _spin()
        await eng.stop(drain=False)
        with pytest.raises(AdmissionError):
            req.future.result(timeout=0)
        assert eng.queue == []

    asyncio.run(run())


def test_sync_discover_async_waiters_interleave_with_caches(built, lake):
    """The self-pumping sync waiters (no background task) still compose
    with the caches: one cold group, then hits resolve at submit."""
    _, queries = lake
    session = MateSession(
        built[128],
        DiscoveryConfig(k=5, window=4, flush_after=0.01, result_cache=8),
    )
    eng = DiscoveryEngine(session=session)

    async def run():
        first = await asyncio.gather(
            *[eng.discover_async(q, qc) for q, qc in queries[:3]]
        )
        again = await asyncio.gather(
            *[eng.discover_async(q, qc) for q, qc in queries[:3]]
        )
        return first, again

    first, again = asyncio.run(run())
    assert session.stats.cache_hits == 3
    for r1, r2 in zip(first, again):
        assert r2.from_cache and _key(r1.results) == _key(r2.results)


# ---------------------------------------------------------------------------
# Caches: unit behaviour
# ---------------------------------------------------------------------------

def test_fingerprint_is_content_keyed(lake):
    _, queries = lake
    (q, qc) = queries[0]
    # identity (table_id, name) is irrelevant — content decides
    clone = dataclasses.replace(q, table_id=999, name="other")
    assert query_fingerprint(q, qc) == query_fingerprint(clone, qc)
    assert query_fingerprint(q, qc) != query_fingerprint(q, list(reversed(qc)))
    assert query_fingerprint(q, qc, "order") != query_fingerprint(q, qc, "tls")
    # framing: value-boundary shifts must not collide
    t1 = Table(0, [["ab", "c"]])
    t2 = Table(0, [["a", "bc"]])
    assert query_fingerprint(t1, [0, 1]) != query_fingerprint(t2, [0, 1])


def test_result_cache_lru_eviction_and_stats():
    cache = QueryResultCache(2)
    cache.put(b"a", 5, 0, [], DiscoveryStats())
    cache.put(b"b", 5, 0, [], DiscoveryStats())
    assert cache.get(b"a", 5, 0) is not None  # refreshes a's recency
    cache.put(b"c", 5, 0, [], DiscoveryStats())  # evicts b (LRU)
    assert cache.get(b"b", 5, 0) is None
    assert cache.get(b"a", 5, 0) is not None
    assert cache.stats.evictions == 1 and cache.stats.hits == 2
    # same fingerprint, different k: distinct entries
    assert cache.get(b"a", 3, 0) is None
    assert cache.stats.hit_rate == pytest.approx(2 / 4)


def test_caches_drop_stale_epoch_entries():
    cache = QueryResultCache(4)
    cache.put(b"x", 5, 7, [], DiscoveryStats())
    assert cache.get(b"x", 5, 7) is not None
    assert cache.get(b"x", 5, 8) is None  # epoch moved: dropped, counted
    assert cache.stats.stale == 1
    assert len(cache) == 0  # the stale entry was evicted, not kept


def test_cache_capacity_validation():
    with pytest.raises(ValueError):
        QueryResultCache(0)
    with pytest.raises(ValueError):
        BoundCache(-1)


def test_config_validates_serving_knobs():
    for bad in (
        dict(max_queue=0),
        dict(pressure_policy="drop"),
        dict(degrade_bits=64),
        dict(deadline_margin=-1.0),
        dict(result_cache=-1),
        dict(bound_cache=-1),
    ):
        with pytest.raises(ValueError):
            DiscoveryConfig(**bad)
    # None means auto/disabled, not invalid
    DiscoveryConfig(max_queue=None, deadline_margin=None)


# ---------------------------------------------------------------------------
# Caches: engine integration + §5.4 invalidation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", VALID_BITS)
def test_result_cache_hit_bit_identical_all_widths(lake, bits):
    _, queries = lake
    index = _fresh_index(lake, bits)
    clk = ManualClock()
    eng, session = _engine(
        index, clk.now, window=4, flush_after=None, result_cache=8, bound_cache=8
    )
    q, qc = queries[0]
    cold_req = eng.discover(q, qc)
    hit_req = eng.discover(q, qc)
    assert hit_req.from_cache and session.stats.cache_hits == 1
    assert _key(hit_req.results) == _key(cold_req.results) == _cold(index, q, qc)
    # a hit never touches the queue or the filter
    assert hit_req.stats.filter_checks == cold_req.stats.filter_checks


@pytest.mark.parametrize("mutation", ["insert", "update", "delete"])
def test_mutation_invalidates_cached_results(lake, mutation):
    """§5.4 mutations must drop affected cache entries — the post-mutation
    answer is re-discovered, never replayed stale."""
    _, queries = lake
    index = _fresh_index(lake, 128)
    clk = ManualClock()
    eng, session = _engine(
        index, clk.now, window=4, flush_after=None, result_cache=8, bound_cache=8
    )
    q, qc = queries[0]
    first = eng.discover(q, qc)
    assert eng.discover(q, qc).from_cache  # warm before the mutation
    top = first.results[0].table_id if first.results else 0
    if mutation == "insert":
        # insert a copy of the query's own key columns: a new perfect join
        # candidate that MUST surface in the fresh answer
        session.insert_table([[r[c] for c in qc] for r in q.cells])
    elif mutation == "update":
        session.update_cell(top, 0, 0, "mutated-value-xyz")
    else:
        session.delete_table(top)
    after = eng.discover(q, qc)
    assert not after.from_cache  # stale entry was invalidated
    assert _key(after.results) == _cold(index, q, qc)  # fresh ground truth
    if mutation == "delete":
        assert all(e.table_id != top for e in after.results)


def test_bound_cache_serves_any_k_and_skips_filter(lake):
    _, queries = lake
    index = _fresh_index(lake, 128)
    clk = ManualClock()
    eng, session = _engine(
        index, clk.now, window=4, flush_after=None, bound_cache=8
    )
    q, qc = queries[0]
    eng.discover(q, qc, k=5)
    checks_after_cold = session.stats.filter_checks
    fused_after_cold = session.stats.filter_fused_launches
    matrix_after_cold = session.stats.filter_matrix_bytes
    warm = eng.discover(q, qc, k=3)  # different k: result cache can't help
    assert session.stats.bound_hits == 1
    # phase A (gather + filter launch) was skipped entirely
    assert session.stats.filter_fused_launches == fused_after_cold
    assert session.stats.filter_matrix_bytes == matrix_after_cold
    assert session.stats.filter_checks == checks_after_cold + warm.stats.filter_checks
    assert _key(warm.results) == _cold(index, q, qc, k=3)


# ---------------------------------------------------------------------------
# Property: random submit/mutate interleavings — hits bit-identical, no
# stale top-k.  Seeded versions ALWAYS run; hypothesis widens the net in CI.
# ---------------------------------------------------------------------------

_MUTATIONS = ("insert", "update", "delete", "none")


def _run_interleaving(bits: int, ops: list[tuple[str, int]]) -> None:
    """Drive an engine with caches through a submit/mutate schedule; after
    EVERY serve, the result must equal a cold discover on the CURRENT index
    (catches both stale cache hits and missed invalidations)."""
    spec = synthetic.SyntheticSpec(n_tables=24, rows_per_table=(4, 10), seed=3)
    corpus = synthetic.make_corpus(spec)
    queries = synthetic.make_mixed_queries(corpus, 4, 6, 2, seed=11)
    index = build_index(corpus, cfg=xash.XashConfig(bits=bits))[0]
    clk = ManualClock()
    session = MateSession(
        index,
        DiscoveryConfig(
            k=4, window=3, flush_after=None, result_cache=4, bound_cache=4
        ),
    )
    eng = DiscoveryEngine(session=session, clock=clk.now)
    live_tables = list(range(len(corpus.tables)))
    pending = []
    epochs_seen = {index.mutation_epoch}
    for op, arg in ops:
        if op == "submit":
            q, qc = queries[arg % len(queries)]
            req = eng.submit(q, qc, k=4)
            if req.done:
                # result-cache hit: answered AT SUBMIT, so it must equal a
                # cold discover against the index as it is RIGHT NOW (a
                # later mutation legitimately changes later answers).
                assert req.from_cache
                assert _key(req.results) == _cold(index, q, qc, k=4)
            else:
                pending.append((req, q, qc))
        elif op == "flush":
            eng.flush()
            for req, q, qc in pending:
                assert req.done
                # THE property: whatever path served it (cold, result-cache
                # hit, bound-cache replay), the answer equals a cold
                # discover against the index AS IT IS NOW.
                assert _key(req.results) == _cold(index, q, qc, k=4), (
                    f"served result diverged from cold discover (op schedule "
                    f"{ops}, from_cache={req.from_cache})"
                )
            pending.clear()
        elif op == "insert" and arg % 2 == 0:
            q, qc = queries[arg % len(queries)]
            tid = session.insert_table([[r[c] for c in qc] for r in q.cells])
            live_tables.append(tid)
        elif op == "insert":
            tid = session.insert_table([["zz", str(arg)], ["yy", "ww"]])
            live_tables.append(tid)
        elif op == "update" and live_tables:
            session.update_cell(live_tables[arg % len(live_tables)], 0, 0, f"v{arg}")
        elif op == "delete" and live_tables:
            session.delete_table(live_tables.pop(arg % len(live_tables)))
        epochs_seen.add(index.mutation_epoch)
    eng.flush()
    for req, q, qc in pending:
        assert _key(req.results) == _cold(index, q, qc, k=4)
    # sanity: schedules with mutations actually moved the epoch
    if any(op in ("insert", "update", "delete") for op, _ in ops):
        assert len(epochs_seen) > 1


def _schedule_from_seed(seed: int, n_ops: int = 14) -> list[tuple[str, int]]:
    rng = np.random.default_rng(seed)
    ops = []
    for _ in range(n_ops):
        roll = rng.random()
        if roll < 0.45:
            ops.append(("submit", int(rng.integers(0, 8))))
        elif roll < 0.65:
            ops.append(("flush", 0))
        elif roll < 0.77:
            ops.append(("insert", int(rng.integers(0, 8))))
        elif roll < 0.89:
            ops.append(("update", int(rng.integers(0, 8))))
        else:
            ops.append(("delete", int(rng.integers(0, 8))))
    ops.append(("flush", 0))
    return ops


@pytest.mark.parametrize("bits", VALID_BITS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_interleaving_property_seeded(bits, seed):
    """Deterministic always-run slice of the property: random (seeded)
    submit/mutate interleavings never serve a result that differs from a
    cold discover at serve time — at every hash width."""
    _run_interleaving(bits, _schedule_from_seed(seed * 31 + bits))


if HAVE_HYPOTHESIS:
    op_strat = st.one_of(
        st.tuples(st.just("submit"), st.integers(0, 7)),
        st.tuples(st.just("flush"), st.just(0)),
        st.tuples(st.just("insert"), st.integers(0, 7)),
        st.tuples(st.just("update"), st.integers(0, 7)),
        st.tuples(st.just("delete"), st.integers(0, 7)),
    )

    @settings(max_examples=12, deadline=None)
    @given(ops=st.lists(op_strat, min_size=2, max_size=12))
    def test_interleaving_property_hypothesis(ops):
        """Arbitrary submit/mutate interleavings ⇒ every cache hit is
        bit-identical to a cold discover and no §5.4 mutation leaves a
        stale entry servable (hypothesis-driven; 128-bit for speed — the
        seeded matrix covers all widths)."""
        _run_interleaving(128, list(ops) + [("flush", 0)])

    @settings(max_examples=10, deadline=None)
    @given(
        k1=st.integers(1, 6),
        k2=st.integers(1, 6),
        qi=st.integers(0, 3),
    )
    def test_bound_cache_any_k_hypothesis(k1, k2, qi):
        """A bound-cache replay at ANY k equals the cold discover at that
        k (phase-B scoring is k-independent of the cached phase A)."""
        spec = synthetic.SyntheticSpec(n_tables=24, rows_per_table=(4, 10), seed=3)
        corpus = synthetic.make_corpus(spec)
        queries = synthetic.make_mixed_queries(corpus, 4, 6, 2, seed=11)
        index = build_index(corpus, cfg=xash.XashConfig(bits=128))[0]
        clk = ManualClock()
        session = MateSession(
            index, DiscoveryConfig(k=4, window=2, flush_after=None, bound_cache=4)
        )
        eng = DiscoveryEngine(session=session, clock=clk.now)
        q, qc = queries[qi]
        eng.discover(q, qc, k=k1)
        warm = eng.discover(q, qc, k=k2)
        assert session.stats.bound_hits == 1
        assert _key(warm.results) == _cold(index, q, qc, k=k2)


# ---------------------------------------------------------------------------
# ISSUE 10: degrade × profile-gate × cache hygiene, and the FD-workload
# fingerprint split
# ---------------------------------------------------------------------------

def test_degraded_gated_request_exact_and_never_poisons_bound_cache(built, lake):
    """A degraded (128-bit lane-prefix) admission with the profile gate ON
    must still verify to exactly the cold full-width gated answer, and its
    phase-A bounds must NEVER enter the BoundCache (they are looser by
    design: a hot entry would keep replaying the wide survivor set long
    after the pressure spike ended).  The exact post-verification RESULTS
    may be cached — a replay is bit-identical."""
    _, queries = lake
    clk = ManualClock()
    eng, session = _engine(
        built[512], clk.now, window=8, max_queue=1,
        pressure_policy="degrade", degrade_bits=128,
        result_cache=8, bound_cache=8,
    )
    normal = eng.submit(*queries[0])
    degraded = eng.submit(*queries[1])  # queue at max_queue → degraded
    assert degraded.degraded and not normal.degraded
    eng.flush()
    epoch = built[512].mutation_epoch
    # gate on (session default) + 4-lane prefix filtering: the verified SET
    # is still exactly the cold 512-bit gated answer (order may differ —
    # the quality score's containment term reads the looser prefix counts)
    assert degraded.stats.filter_lanes == 4
    assert sorted(_key(degraded.results)) == sorted(_cold(built[512], *queries[1]))
    # bound-cache hygiene: the full-width request's bounds were cached, the
    # degraded request's were not
    assert eng.bound_cache.get(normal.fingerprint, epoch) is not None
    assert eng.bound_cache.get(degraded.fingerprint, epoch) is None
    # the RESULT cache did keep the degraded answer — it is exact after
    # verification, so a replay must be bit-identical to the cold answer
    hit = eng.submit(*queries[1])
    assert hit.from_cache and session.stats.cache_hits == 1
    assert sorted(_key(hit.results)) == sorted(_cold(built[512], *queries[1]))
    # ... and the replay resolves at submit: no queue slot, no filter work
    assert hit not in eng.queue


def test_fd_workload_fingerprint_never_hits_join_caches(built, lake):
    """FD validation re-uses plan_and_count, so an FD request's fingerprint
    MUST differ from the join-workload fingerprint of the same query —
    otherwise an FD pass could replay a cached join result (or vice versa).
    The ``workload`` field pins the split; the default stays 'join' so
    every pre-FD digest is unchanged."""
    _, queries = lake
    q, qc = queries[0]
    cfg = DiscoveryConfig()
    join_fp = query_fingerprint(
        q, qc, cfg.init_mode, rank=cfg.rank, profile_gate=cfg.profile_gate
    )
    # default == explicit workload='join' (pre-FD digests unchanged)
    assert join_fp == query_fingerprint(
        q, qc, cfg.init_mode, rank=cfg.rank, profile_gate=cfg.profile_gate,
        workload="join",
    )
    # distinct workloads → distinct digests; FD callers encode the dependent
    # column and min_support so different FD targets never collide either
    fd_fp = query_fingerprint(
        q, qc, cfg.init_mode, rank=cfg.rank, profile_gate=cfg.profile_gate,
        workload="fd:2:1",
    )
    assert fd_fp != join_fp
    assert fd_fp != query_fingerprint(
        q, qc, cfg.init_mode, rank=cfg.rank, profile_gate=cfg.profile_gate,
        workload="fd:3:1",
    )
    # engine integration: warm the join result cache, then assert the FD
    # fingerprint misses both caches at every k
    clk = ManualClock()
    eng, session = _engine(
        built[128], clk.now, window=4, flush_after=None,
        result_cache=8, bound_cache=8,
    )
    cold = eng.discover(q, qc)
    assert eng.discover(q, qc).from_cache  # join entry is hot
    epoch = built[128].mutation_epoch
    assert eng.result_cache.get(cold.fingerprint, cold.k, epoch) is not None
    assert eng.result_cache.get(fd_fp, cold.k, epoch) is None
    assert eng.bound_cache.get(fd_fp, epoch) is None
