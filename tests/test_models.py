"""Per-arch smoke tests: reduced configs, forward/train/decode on CPU."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import params as P_, transformer
from repro.train import optimizer as opt, step as step_lib

KEY = jax.random.PRNGKey(0)
ARCHS = list(configs.ARCHS)


def _setup(name, generous_moe=True):
    cfg = configs.reduce_config(configs.get_config(name))
    if generous_moe and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    specs = transformer.model_specs(cfg)
    params = P_.materialize(specs, KEY)
    return cfg, params


def _extra(cfg, b):
    kw = {}
    if cfg.encoder is not None:
        kw["frames"] = jax.random.normal(
            KEY, (b, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16
        )
    if cfg.vision is not None:
        kw["patches"] = jax.random.normal(
            KEY, (b, cfg.vision.n_tokens, cfg.d_model), jnp.bfloat16
        )
    return kw


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_finite(name):
    cfg, params = _setup(name)
    B, S = 2, 24
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits, aux = transformer.forward(params, cfg, tokens, **_extra(cfg, B))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCHS)
def test_decode_consistency(name):
    """prefill+decode must reproduce the full forward's next-token logits.

    MoE archs: exact once capacity drops are disabled, EXCEPT hybrid
    (jamba), where SSM chunked-vs-recurrent drift can flip top-k routing —
    there we require bounded drift instead (DESIGN.md §6 note).
    """
    cfg, params = _setup(name)
    if cfg.mla is not None:
        cfg = dataclasses.replace(cfg, mla_absorb=False)  # exact path
    B, S = 2, 20
    tokens = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    kw = _extra(cfg, B)
    full, _ = transformer.forward(params, cfg, tokens, remat=False, **kw)
    pre, cache = transformer.prefill(params, cfg, tokens[:, :S], max_seq=48, **kw)
    d_pre = float(jnp.max(jnp.abs(pre - full[:, S - 1])))
    dec, cache = transformer.decode_step(params, cfg, tokens[:, S], cache)
    d_dec = float(jnp.max(jnp.abs(dec - full[:, S])))
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    tol = 0.35 if (cfg.ssm is not None and cfg.moe is not None) else 0.05
    assert d_pre / scale < tol, d_pre
    assert d_dec / scale < tol, d_dec


@pytest.mark.parametrize("name", ["qwen3-32b", "qwen2-moe-a2.7b", "jamba-v0.1-52b"])
def test_train_loss_decreases(name):
    cfg, params = _setup(name)
    tcfg = step_lib.TrainConfig(
        adamw=opt.AdamWConfig(lr=1e-2, warmup_steps=1, total_steps=50),
        ce_chunk=16,
    )
    state = opt.init_state(params, tcfg.adamw)
    B, S = 4, 24
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {
        "tokens": tokens,
        "labels": jnp.concatenate([tokens[:, 1:], -jnp.ones((B, 1), jnp.int32)], 1),
    }
    batch.update(_extra(cfg, B))
    tstep = jax.jit(step_lib.make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    losses = []
    for _ in range(6):
        params, state, m = tstep(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_mla_absorb_matches_naive():
    cfg, params = _setup("deepseek-v3-671b")
    B, S = 2, 12
    tokens = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    outs = {}
    for absorb in (False, True):
        c = dataclasses.replace(cfg, mla_absorb=absorb)
        _, cache = transformer.prefill(params, c, tokens[:, :S], max_seq=32)
        dec, _ = transformer.decode_step(params, c, tokens[:, S], cache)
        outs[absorb] = dec
    diff = float(jnp.max(jnp.abs(outs[True] - outs[False])))
    scale = float(jnp.max(jnp.abs(outs[False]))) + 1e-6
    assert diff / scale < 0.15  # algebraically identical, bf16-reordered


def test_sliding_window_masks_far_context():
    """A token beyond the SWA window must not influence attention output."""
    name = "h2o-danube-3-4b"
    cfg = configs.reduce_config(configs.get_config(name))
    cfg = dataclasses.replace(cfg, sliding_window=4, n_layers=1)
    specs = transformer.model_specs(cfg)
    params = P_.materialize(specs, KEY)
    S = 12
    t1 = jax.random.randint(KEY, (1, S), 0, cfg.vocab_size)
    t2 = t1.at[0, 0].set((t1[0, 0] + 7) % cfg.vocab_size)  # perturb far past
    l1, _ = transformer.forward(params, cfg, t1, remat=False)
    l2, _ = transformer.forward(params, cfg, t2, remat=False)
    # last position attends only to the last 4 → unchanged
    assert float(jnp.max(jnp.abs(l1[:, -1] - l2[:, -1]))) < 1e-3


def test_param_counts_sane():
    for name, approx_b in [
        ("qwen1.5-0.5b", 0.62),  # incl. big embedding
        ("deepseek-v3-671b", 671),
        ("mamba2-1.3b", 1.3),
    ]:
        cfg = configs.get_config(name)
        total = cfg.params_count()["total"] / 1e9
        assert 0.5 * approx_b < total < 1.6 * approx_b, (name, total)
