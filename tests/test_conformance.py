"""Cross-backend differential conformance suite (ISSUE 10).

One seeded discovery scenario, parametrized over EVERY backend registered in
``kernels/registry.py`` × every superkey width, asserting each backend
reproduces the numpy reference bit-identically across all four engine
surfaces:

  * ``discover_batched`` — entry sequence (count rank: fully deterministic);
  * ``discover_many`` — per-request entry sequences under the shared launch;
  * two-phase ``plan_and_count`` + ``score_from_counts`` — the per-table
    COUNT VECTORS themselves (the §6.3 filter is exact bitwise arithmetic,
    so even intermediate counts may not drift) and the scored entries at
    two different k;
  * ``core.fd.discover_fds`` — FD verdict tuples on a planted-FD lake.

Plus the stats invariants that define each dispatch class: fused backends
never materialise a match matrix (``filter_matrix_bytes == 0``), non-fused
ones always do (on non-empty candidate sets).

Backend drift used to surface only in scattered per-feature suites
(test_gather_fused, test_routed, ...) — this module is the single net: a
NEW backend registered tomorrow is pulled in automatically via
``registry.backend_names()`` and must conform everywhere before CI passes.

The lake is deliberately tiny: the pallas/fused legs run interpret-mode on
CPU, so per-test cost is dominated by kernel interpretation.
"""

import numpy as np
import pytest

from repro.core import batched, fd, xash
from repro.core.index import build_index
from repro.kernels import registry

from conftest import ALL_BITS, mixed_query_lake
from test_fd import planted_fd_lake, _entry_key

BACKENDS = registry.backend_names()
K = 5


def _key(entries):
    return [(e.table_id, e.joinability, e.mapping) for e in entries]


@pytest.fixture(scope="module")
def lake():
    corpus, queries = mixed_query_lake(
        n_tables=30, corpus_seed=3, n_queries=2, n_rows=8, key_width=2,
        query_seed=5,
    )
    assert len(queries) == 2
    return corpus, queries


@pytest.fixture(scope="module")
def built(lake):
    corpus, _ = lake
    return {
        bits: build_index(corpus, cfg=xash.XashConfig(bits=bits))[0]
        for bits in ALL_BITS
    }


@pytest.fixture(scope="module")
def fd_lake():
    corpus, query, det_cols, dep_col = planted_fd_lake(3)
    indexes = {
        bits: build_index(corpus, cfg=xash.XashConfig(bits=bits))[0]
        for bits in ALL_BITS
    }
    return indexes, query, det_cols, dep_col


@pytest.fixture(scope="module")
def reference(lake, built, fd_lake):
    """Numpy-backend ground truth per width, computed once."""
    _, queries = lake
    fd_idx, fd_query, det_cols, dep_col = fd_lake
    ref = {}
    for bits in ALL_BITS:
        idx = built[bits]
        single, _ = batched.discover_batched(
            idx, queries[0][0], queries[0][1], k=K, backend="numpy"
        )
        many = batched.discover_many(idx, queries, k=K, backend="numpy")
        pcs = batched.plan_and_count(idx, queries, "numpy")
        counts = [np.asarray(pc.counts).copy() for pc in pcs]
        scored = {
            kk: [
                _key(batched.score_from_counts(idx, pc, kk)[0]) for pc in pcs
            ]
            for kk in (K, 3)
        }
        fds, _ = fd.discover_fds(
            fd_idx[bits], fd_query, det_cols, dep_col, backend="numpy"
        )
        ref[bits] = {
            "single": _key(single),
            "many": [_key(entries) for entries, _ in many],
            "counts": counts,
            "scored": scored,
            "fds": _entry_key(fds),
        }
    return ref


@pytest.mark.parametrize("bits", ALL_BITS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_backend_conforms(lake, built, fd_lake, reference, backend, bits):
    _, queries = lake
    idx = built[bits]
    ref = reference[bits]
    bk = registry.resolve_backend(backend)

    # -- discover: bit-identical entry sequence + matrix invariant --------
    single, st = batched.discover_batched(
        idx, queries[0][0], queries[0][1], k=K, backend=bk
    )
    assert _key(single) == ref["single"], "discover drifted"
    if bk.fused:
        assert st.filter_matrix_bytes == 0, (
            "fused dispatch materialised a match matrix"
        )
    elif st.filter_checks:
        assert st.filter_matrix_bytes > 0

    # -- discover_many: every request bit-identical -----------------------
    many = batched.discover_many(idx, queries, k=K, backend=bk)
    assert [_key(entries) for entries, _ in many] == ref["many"]

    # -- two-phase: the COUNT VECTORS must match, then scoring at two k ---
    pcs = batched.plan_and_count(idx, queries, bk)
    for pc, ref_counts in zip(pcs, ref["counts"]):
        np.testing.assert_array_equal(np.asarray(pc.counts), ref_counts)
    for kk in (K, 3):
        got = [
            _key(batched.score_from_counts(idx, pc, kk)[0]) for pc in pcs
        ]
        assert got == ref["scored"][kk]
    if bk.fused:
        for pc in pcs:
            _, st2 = batched.score_from_counts(idx, pc, K)
            assert st2.filter_matrix_bytes == 0

    # -- FD workload: verdict tuples bit-identical ------------------------
    fd_idx, fd_query, det_cols, dep_col = fd_lake
    fds, fd_st = fd.discover_fds(
        fd_idx[bits], fd_query, det_cols, dep_col, backend=bk
    )
    assert _entry_key(fds) == ref["fds"], "FD verdicts drifted"
    if bk.fused:
        assert fd_st.filter_matrix_bytes == 0
