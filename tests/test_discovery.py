"""Discovery (Algorithm 1) correctness: vs brute force, engines, baselines."""

import numpy as np
import pytest

from repro.core import discovery
from repro.core.batched import discover_batched, discover_many
from repro.core.corpus import Corpus, Table
from repro.core.index import MateIndex
from repro.data import synthetic


@pytest.fixture(scope="module")
def lake():
    spec = synthetic.SyntheticSpec(n_tables=150, seed=0)
    corpus = synthetic.make_corpus(spec)
    query, q_cols, expected, corpus = synthetic.make_query_with_ground_truth(corpus)
    index = MateIndex(corpus)
    return corpus, index, query, q_cols, expected


def test_topk_matches_bruteforce_and_ground_truth(lake):
    corpus, index, query, q_cols, expected = lake
    topk, stats = discovery.discover(index, query, q_cols, k=10)
    bf = discovery.topk_bruteforce(corpus, query, q_cols, 10)
    assert [(e.table_id, e.joinability) for e in topk] == bf
    exp_sorted = sorted(expected.items(), key=lambda kv: (-kv[1], kv[0]))[:10]
    assert [(e.table_id, e.joinability) for e in topk] == exp_sorted
    assert stats.verified_fp == 0 or stats.precision > 0.5


def test_no_false_negatives_end_to_end(lake):
    """Every injected joinable table must appear with full joinability."""
    corpus, index, query, q_cols, expected = lake
    k = len(expected) + 5
    topk, _ = discovery.discover(index, query, q_cols, k=k)
    got = {e.table_id: e.joinability for e in topk}
    for tid, j in expected.items():
        assert got.get(tid, -1) >= j, (tid, j, got.get(tid))


def test_sci_same_results_more_fps(lake):
    corpus, index, query, q_cols, _ = lake
    mate, s_mate = discovery.discover(index, query, q_cols, k=10, row_filter=True)
    sci, s_sci = discovery.discover(index, query, q_cols, k=10, row_filter=False)
    assert [(e.table_id, e.joinability) for e in mate] == [
        (e.table_id, e.joinability) for e in sci
    ]
    assert s_sci.verified_fp >= s_mate.verified_fp


def test_batched_engine_bit_identical(lake):
    """Acceptance bar: batched kernel-backed top-k == scalar path exactly —
    same table ids, same joinability scores, same mappings."""
    corpus, index, query, q_cols, _ = lake
    seq, _ = discovery.discover(index, query, q_cols, k=10)
    for use_kernel in (False, True):
        bat, _ = discover_batched(index, query, q_cols, k=10, use_kernel=use_kernel)
        assert [(e.table_id, e.joinability, e.mapping) for e in seq] == [
            (e.table_id, e.joinability, e.mapping) for e in bat
        ]


def test_batched_small_batches_bit_identical(lake):
    """Rule-1 between-batch pruning must not change results at any batch size."""
    corpus, index, query, q_cols, _ = lake
    seq, _ = discovery.discover(index, query, q_cols, k=5)
    for batch_tables in (1, 7, 64):
        bat, _ = discover_batched(
            index, query, q_cols, k=5, batch_tables=batch_tables, use_kernel=False
        )
        assert [(e.table_id, e.joinability) for e in seq] == [
            (e.table_id, e.joinability) for e in bat
        ], batch_tables


def test_discover_many_bit_identical(lake):
    """One shared filter launch across queries == per-query discovery."""
    corpus, index, query, q_cols, _ = lake
    queries = [(query, q_cols)] + synthetic.make_mixed_queries(
        corpus, 3, 12, 2, seed=21
    )
    out = discover_many(index, queries, k=[10, 3, 5, 10])
    for (q, qc), k_i, (entries, stats) in zip(queries, [10, 3, 5, 10], out):
        seq, _ = discovery.discover(index, q, qc, k=k_i)
        assert [(e.table_id, e.joinability, e.mapping) for e in seq] == [
            (e.table_id, e.joinability, e.mapping) for e in entries
        ]
        assert stats.tables_fetched > 0


def test_discovery_engine_slot_batching(lake):
    from repro.serve.engine import DiscoveryEngine

    corpus, index, query, q_cols, _ = lake
    engine = DiscoveryEngine(index, batch=2)
    reqs = [engine.submit(query, q_cols, k=5) for _ in range(5)]
    assert not any(r.done for r in reqs)
    served = engine.flush()
    assert served == reqs and not engine.queue
    seq, _ = discovery.discover(index, query, q_cols, k=5)
    for r in served:
        assert r.done and r.stats is not None
        assert [(e.table_id, e.joinability) for e in r.results] == [
            (e.table_id, e.joinability) for e in seq
        ]
    one = engine.discover(query, q_cols, k=5)
    assert [(e.table_id, e.joinability) for e in one.results] == [
        (e.table_id, e.joinability) for e in seq
    ]


@pytest.mark.parametrize("hash_name", ["bf", "ht", "murmur", "simhash"])
def test_baseline_hashes_same_topk(lake, hash_name):
    """Any hash gives the same RESULTS (no FNs) — only FP counts differ."""
    corpus, _, query, q_cols, _ = lake
    index = MateIndex(corpus, hash_name=hash_name)
    topk, _ = discovery.discover(index, query, q_cols, k=10)
    bf = discovery.topk_bruteforce(corpus, query, q_cols, 10)
    assert [(e.table_id, e.joinability) for e in topk] == bf


def test_mapping_argmax_permuted_columns():
    """Eq. 2: joinability maximises over column permutations."""
    corpus = Corpus(
        [
            Table(0, [["x", "b1", "a1"], ["y", "b2", "a2"], ["z", "b9", "a3"]]),
            Table(1, [["a1", "b1", "pad"], ["a9", "b9", "pad"]]),
        ]
    )
    query = Table(-1, [["a1", "b1"], ["a2", "b2"], ["a3", "b3"]])
    index = MateIndex(corpus)
    topk, _ = discovery.discover(index, query, [0, 1], k=2)
    by_id = {e.table_id: e for e in topk}
    # table 0 matches (a_i, b_i) under mapping (col2, col1) for rows 1-2
    assert by_id[0].joinability == 2
    assert by_id[0].mapping == (2, 1)
    assert by_id[1].joinability == 1


def test_key_width_3():
    corpus = Corpus(
        [
            Table(0, [["a", "b", "c", "zz"], ["a", "b", "d", "zz"]]),
            Table(1, [["c", "a", "b", "q"], ["x", "y", "z", "q"]]),
        ]
    )
    query = Table(-1, [["a", "b", "c"], ["a", "b", "d"]])
    index = MateIndex(corpus)
    topk, _ = discovery.discover(index, query, [0, 1, 2], k=2)
    by_id = {e.table_id: e.joinability for e in topk}
    assert by_id[0] == 2
    assert by_id[1] == 1


def test_init_column_modes(lake):
    corpus, index, query, q_cols, _ = lake
    for mode in ("cardinality", "order", "tls", "best", "worst"):
        col = discovery.init_column_selection(query, q_cols, mode, index)
        assert col in q_cols
    # best fetches no more PL items than worst
    def total(col):
        return sum(len(index.fetch_postings(v)) for v in set(query.column(col)))
    best = discovery.init_column_selection(query, q_cols, "best", index)
    worst = discovery.init_column_selection(query, q_cols, "worst", index)
    assert total(best) <= total(worst)


def test_table_filter_prunes(lake):
    corpus, index, query, q_cols, _ = lake
    _, stats = discovery.discover(index, query, q_cols, k=2)
    assert stats.tables_pruned_rule1 + stats.tables_pruned_rule2 > 0
    assert stats.tables_evaluated < stats.tables_fetched or stats.tables_fetched <= 2
