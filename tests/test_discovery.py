"""Discovery (Algorithm 1) correctness: vs brute force, engines, baselines."""

import numpy as np
import pytest

from repro.core import discovery, xash
from repro.core.batched import discover_batched, discover_many
from repro.core.corpus import Corpus, Table
from repro.core.index import MateIndex
from repro.data import synthetic


@pytest.fixture(scope="module")
def lake():
    spec = synthetic.SyntheticSpec(n_tables=150, seed=0)
    corpus = synthetic.make_corpus(spec)
    query, q_cols, expected, corpus = synthetic.make_query_with_ground_truth(corpus)
    index = MateIndex(corpus)
    return corpus, index, query, q_cols, expected


@pytest.fixture(scope="module")
def lake512(lake):
    """Same corpus/query, indexed at 512-bit (16-lane) super keys."""
    corpus, _index, query, q_cols, expected = lake
    index = MateIndex(corpus, cfg=xash.XashConfig(bits=512))
    return corpus, index, query, q_cols, expected


def test_topk_matches_bruteforce_and_ground_truth(lake):
    corpus, index, query, q_cols, expected = lake
    topk, stats = discovery.discover(index, query, q_cols, k=10)
    bf = discovery.topk_bruteforce(corpus, query, q_cols, 10)
    assert [(e.table_id, e.joinability) for e in topk] == bf
    exp_sorted = sorted(expected.items(), key=lambda kv: (-kv[1], kv[0]))[:10]
    assert [(e.table_id, e.joinability) for e in topk] == exp_sorted
    assert stats.verified_fp == 0 or stats.precision > 0.5


def test_no_false_negatives_end_to_end(lake):
    """Every injected joinable table must appear with full joinability."""
    corpus, index, query, q_cols, expected = lake
    k = len(expected) + 5
    topk, _ = discovery.discover(index, query, q_cols, k=k)
    got = {e.table_id: e.joinability for e in topk}
    for tid, j in expected.items():
        assert got.get(tid, -1) >= j, (tid, j, got.get(tid))


def test_sci_same_results_more_fps(lake):
    corpus, index, query, q_cols, _ = lake
    mate, s_mate = discovery.discover(index, query, q_cols, k=10, row_filter=True)
    sci, s_sci = discovery.discover(index, query, q_cols, k=10, row_filter=False)
    assert [(e.table_id, e.joinability) for e in mate] == [
        (e.table_id, e.joinability) for e in sci
    ]
    assert s_sci.verified_fp >= s_mate.verified_fp


def test_batched_engine_bit_identical(lake):
    """Acceptance bar: batched kernel-backed top-k == scalar path exactly —
    same table ids, same joinability scores, same mappings."""
    corpus, index, query, q_cols, _ = lake
    seq, _ = discovery.discover(index, query, q_cols, k=10)
    for backend in ("numpy", None):
        bat, _ = discover_batched(index, query, q_cols, k=10, backend=backend)
        assert [(e.table_id, e.joinability, e.mapping) for e in seq] == [
            (e.table_id, e.joinability, e.mapping) for e in bat
        ]


def test_batched_small_batches_bit_identical(lake):
    """Rule-1 between-batch pruning must not change results at any batch size."""
    corpus, index, query, q_cols, _ = lake
    seq, _ = discovery.discover(index, query, q_cols, k=5)
    for batch_tables in (1, 7, 64):
        bat, _ = discover_batched(
            index, query, q_cols, k=5, batch_tables=batch_tables, backend="numpy"
        )
        assert [(e.table_id, e.joinability) for e in seq] == [
            (e.table_id, e.joinability) for e in bat
        ], batch_tables


def test_discover_many_bit_identical(lake):
    """One shared filter launch across queries == per-query discovery."""
    corpus, index, query, q_cols, _ = lake
    queries = [(query, q_cols)] + synthetic.make_mixed_queries(
        corpus, 3, 12, 2, seed=21
    )
    out = discover_many(index, queries, k=[10, 3, 5, 10])
    for (q, qc), k_i, (entries, stats) in zip(queries, [10, 3, 5, 10], out):
        seq, _ = discovery.discover(index, q, qc, k=k_i)
        assert [(e.table_id, e.joinability, e.mapping) for e in seq] == [
            (e.table_id, e.joinability, e.mapping) for e in entries
        ]
        assert stats.tables_fetched > 0


def test_discovery_engine_slot_batching(lake):
    from repro.serve.engine import DiscoveryEngine

    corpus, index, query, q_cols, _ = lake
    engine = DiscoveryEngine(index, batch=2)
    reqs = [engine.submit(query, q_cols, k=5) for _ in range(5)]
    assert not any(r.done for r in reqs)
    served = engine.flush()
    assert served == reqs and not engine.queue
    # the engine serves at the session default (quality rank), which only
    # reorders the scalar engine's verified set
    seq, _ = discovery.discover(index, query, q_cols, k=5)
    want = sorted((e.table_id, e.joinability) for e in seq)
    for r in served:
        assert r.done and r.stats is not None
        assert sorted((e.table_id, e.joinability) for e in r.results) == want
    one = engine.discover(query, q_cols, k=5)
    assert sorted((e.table_id, e.joinability) for e in one.results) == want


def test_512bit_engines_bit_identical(lake512):
    """512-bit end-to-end: discover_batched, discover_many and
    DiscoveryEngine.flush all match the scalar Algorithm 1 scan exactly,
    mirroring the 128-bit assertions above (ids, scores, mappings)."""
    from repro.serve.engine import DiscoveryEngine

    corpus, index, query, q_cols, _ = lake512
    assert index.bits == 512 and index.cfg.lanes == 16
    assert index.superkeys.shape[1] == 16
    seq, _ = discovery.discover(index, query, q_cols, k=10)
    want = [(e.table_id, e.joinability, e.mapping) for e in seq]
    for backend in ("numpy", None):
        bat, _ = discover_batched(index, query, q_cols, k=10, backend=backend)
        assert [(e.table_id, e.joinability, e.mapping) for e in bat] == want
    out = discover_many(index, [(query, q_cols)] * 3, k=10)
    for entries, _stats in out:
        assert [(e.table_id, e.joinability, e.mapping) for e in entries] == want
    engine = DiscoveryEngine(index, batch=2)
    assert engine.bits == 512
    # the engine defaults to rank='quality' + the profile gate: exact match
    # against the raw engine run at the SAME flags (and set-identical to the
    # count-ranked references above by the pure-pruning/reorder contract)
    want_q = [
        (e.table_id, e.joinability, e.mapping)
        for e in discover_batched(
            index, query, q_cols, k=10, rank="quality", profile_gate=True
        )[0]
    ]
    assert sorted(want_q) == sorted(want)
    reqs = [engine.submit(query, q_cols, k=10) for _ in range(3)]
    engine.flush()
    for r in reqs:
        assert [(e.table_id, e.joinability, e.mapping) for e in r.results] == want_q


def test_512bit_topk_matches_bruteforce(lake512):
    """No width ever changes the result set — only the FP rate (§6.3)."""
    corpus, index, query, q_cols, _ = lake512
    topk, _ = discovery.discover(index, query, q_cols, k=10)
    bf = discovery.topk_bruteforce(corpus, query, q_cols, 10)
    assert [(e.table_id, e.joinability) for e in topk] == bf


def test_batched_readback_accounting(lake):
    """Device-side rule-1/2: the batched engine accounts for match-matrix
    bytes and reads back at most the full matrix (counts + verify slices).
    Under the fused dispatch (MATE_FILTER_BACKEND=fused / TPU) the matrix is
    never produced at all — zero matrix bytes is the contract instead."""
    from repro.kernels import ops

    corpus, index, query, q_cols, _ = lake
    _, st = discover_batched(index, query, q_cols, k=5)
    if ops.fused_filter_default():
        assert st.filter_matrix_bytes == 0
        assert st.filter_fused_launches > 0
        # counts vectors + recomputed surviving slices, bounded by the
        # would-be matrix (every item × every key) + 4 count bytes/table
        assert st.filter_readback_bytes <= (
            st.pl_items_checked * len(
                dict.fromkeys(
                    tuple(row[c] for c in q_cols) for row in query.cells
                )
            ) + 4 * st.tables_fetched
        )
    else:
        assert st.filter_matrix_bytes > 0
        # at most: every table verified (full slice) + 4 count bytes/table
        assert st.filter_readback_bytes <= (
            st.filter_matrix_bytes + 4 * st.tables_fetched
        )


def test_score_tables_reads_back_only_surviving_slices(lake, monkeypatch):
    """Pins the device-side rule-2 contract directly: with device-resident
    hits and a full heap, ONLY un-pruned tables' hit slices are transferred
    (prefetch disabled by the low alive fraction)."""
    import jax.numpy as jnp

    from repro.core import batched as B

    corpus, index, query, q_cols, _ = lake
    plan = B.plan_query(index, query, q_cols)
    block = plan.block
    assert block.n_tables >= 3
    t_stop = min(block.n_tables, 8)
    n_items = int(block.table_ptr[t_stop])
    k = len(plan.distinct_keys)
    hits_dev = jnp.zeros((n_items, k), dtype=bool)  # device-resident

    topk = B._TopK(1)
    topk.offer(10_000, 5, None)  # full heap, bound 5
    # exactly one table above the bound -> exactly its slice is read back
    counts = np.zeros(t_stop, dtype=np.int32)
    counts[t_stop - 1] = 6
    survivor_items = int(block.table_ptr[t_stop] - block.table_ptr[t_stop - 1])
    monkeypatch.setattr(B, "_PREFETCH_FRAC", 1.1)  # force per-table path
    st0 = plan.stats.filter_readback_bytes
    B._score_tables(
        index, plan, topk, hits_dev, counts, block.rows[:n_items], 0, t_stop, 0
    )
    assert plan.stats.filter_readback_bytes - st0 == survivor_items * k
    assert plan.stats.tables_pruned_rule2 == t_stop - 1


@pytest.mark.parametrize("hash_name", ["bf", "ht", "murmur", "simhash"])
def test_baseline_hashes_same_topk(lake, hash_name):
    """Any hash gives the same RESULTS (no FNs) — only FP counts differ."""
    corpus, _, query, q_cols, _ = lake
    index = MateIndex(corpus, hash_name=hash_name)
    topk, _ = discovery.discover(index, query, q_cols, k=10)
    bf = discovery.topk_bruteforce(corpus, query, q_cols, 10)
    assert [(e.table_id, e.joinability) for e in topk] == bf


def test_mapping_argmax_permuted_columns():
    """Eq. 2: joinability maximises over column permutations."""
    corpus = Corpus(
        [
            Table(0, [["x", "b1", "a1"], ["y", "b2", "a2"], ["z", "b9", "a3"]]),
            Table(1, [["a1", "b1", "pad"], ["a9", "b9", "pad"]]),
        ]
    )
    query = Table(-1, [["a1", "b1"], ["a2", "b2"], ["a3", "b3"]])
    index = MateIndex(corpus)
    topk, _ = discovery.discover(index, query, [0, 1], k=2)
    by_id = {e.table_id: e for e in topk}
    # table 0 matches (a_i, b_i) under mapping (col2, col1) for rows 1-2
    assert by_id[0].joinability == 2
    assert by_id[0].mapping == (2, 1)
    assert by_id[1].joinability == 1


def test_key_width_3():
    corpus = Corpus(
        [
            Table(0, [["a", "b", "c", "zz"], ["a", "b", "d", "zz"]]),
            Table(1, [["c", "a", "b", "q"], ["x", "y", "z", "q"]]),
        ]
    )
    query = Table(-1, [["a", "b", "c"], ["a", "b", "d"]])
    index = MateIndex(corpus)
    topk, _ = discovery.discover(index, query, [0, 1, 2], k=2)
    by_id = {e.table_id: e.joinability for e in topk}
    assert by_id[0] == 2
    assert by_id[1] == 1


def test_init_column_modes(lake):
    corpus, index, query, q_cols, _ = lake
    for mode in ("cardinality", "order", "tls", "best", "worst"):
        col = discovery.init_column_selection(query, q_cols, mode, index)
        assert col in q_cols
    # best fetches no more PL items than worst
    def total(col):
        return sum(len(index.fetch_postings(v)) for v in set(query.column(col)))
    best = discovery.init_column_selection(query, q_cols, "best", index)
    worst = discovery.init_column_selection(query, q_cols, "worst", index)
    assert total(best) <= total(worst)


def test_table_filter_prunes(lake):
    corpus, index, query, q_cols, _ = lake
    _, stats = discovery.discover(index, query, q_cols, k=2)
    assert stats.tables_pruned_rule1 + stats.tables_pruned_rule2 > 0
    assert stats.tables_evaluated < stats.tables_fetched or stats.tables_fetched <= 2
