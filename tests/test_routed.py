"""Routed lake (ISSUE 8): per-shard ownership, shard-local filter launches,
count-only merge — the routed-vs-single-host equivalence matrix.

The contract: a ``ShardedMateIndex`` at ANY shard count produces top-k
byte-identical to the single-host ``MateIndex`` at every width in
{128, 256, 512}, while the only bytes that cross a shard boundary are
int32 per-table count vectors (``DiscoveryStats.route_bytes_merged``) —
superkey rows never do.  The host-routed path (shards pinned to one
device) runs in every CI leg; the mesh-attached matrix runs under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the ``routed``
CI leg) and skips where fewer devices are visible.
"""

import numpy as np
import pytest

import jax

from repro.core import batched, discovery, xash
from repro.core.corpus import Corpus, Table
from repro.core.index import MateIndex
from repro.core.routing import (
    ShardedMateIndex,
    build_routed_index,
    table_aligned_bounds,
)
from repro.core.session import DiscoveryConfig, MateSession
from repro.data import synthetic
from repro.launch import mesh as meshlib
from repro.serve.engine import DiscoveryEngine

N_DEVICES = len(jax.devices())
SHARD_COUNTS = (1, 2, 4, 8)
WIDTHS = (128, 256, 512)

needs_8_devices = pytest.mark.skipif(
    N_DEVICES < max(SHARD_COUNTS),
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
    "(the routed CI leg)",
)


def topk_key(entries):
    return [(e.table_id, e.joinability, e.mapping) for e in entries]


@pytest.fixture(scope="module")
def lake():
    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=60, seed=1))
    query, q_cols, _expected, corpus = synthetic.make_query_with_ground_truth(
        corpus
    )
    return corpus, query, q_cols


@pytest.fixture(scope="module")
def single_host(lake):
    corpus, _q, _qc = lake
    return {
        bits: MateIndex(
            corpus, cfg=xash.XashConfig(bits=bits), use_corpus_char_freq=True
        )
        for bits in WIDTHS
    }


def make_routed(corpus, bits, n_shards):
    return ShardedMateIndex(
        corpus,
        cfg=xash.XashConfig(bits=bits),
        use_corpus_char_freq=True,
        n_shards=n_shards,
    )


# ---------------------------------------------------------------------------
# Shard ownership geometry
# ---------------------------------------------------------------------------


def test_table_aligned_bounds_cover_and_align(lake):
    corpus, _q, _qc = lake
    for n in (1, 2, 3, 4, 8, 17):
        bounds = table_aligned_bounds(corpus.row_base, n)
        assert bounds[0] == 0 and bounds[-1] == corpus.total_rows
        assert np.all(np.diff(bounds) >= 0)
        # every interior bound sits ON a table boundary: no table is split
        interior = bounds[1:-1]
        assert np.all(np.isin(interior, corpus.row_base)), (n, interior)


def test_no_table_crosses_a_shard(lake):
    corpus, _q, _qc = lake
    idx = make_routed(corpus, 128, 4)
    for shard in idx.shards:
        tids = np.unique(
            np.asarray(
                corpus.table_of_row(np.arange(shard.row_lo, shard.row_hi))
            )
        )
        for other in idx.shards:
            if other.shard_id == shard.shard_id:
                continue
            o_tids = np.asarray(
                corpus.table_of_row(np.arange(other.row_lo, other.row_hi))
            )
            assert not np.intersect1d(tids, o_tids).size


# ---------------------------------------------------------------------------
# Routed-vs-single-host equivalence matrix (the acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", WIDTHS)
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_routed_matrix_byte_identical(lake, single_host, n_shards, bits):
    corpus, query, q_cols = lake
    idx = make_routed(corpus, bits, n_shards)
    ref = single_host[bits]
    want, _ = batched.discover_batched(ref, query, q_cols, k=10)
    got, stats = batched.discover_batched(idx, query, q_cols, k=10)
    assert topk_key(got) == topk_key(want)
    # the routed invariant: count vectors crossed shards, superkeys did not
    assert stats.shard_launches >= 1
    assert stats.route_bytes_merged > 0
    host_gather_bytes = stats.pl_items_checked * idx.cfg.lanes * 4
    if n_shards > 1:
        assert stats.route_bytes_merged < host_gather_bytes
    # sequential Algorithm 1 agrees too (it consumes the routed index
    # through fetch_postings/superkey_of_rows only)
    seq, _ = discovery.discover(idx, query, q_cols, k=10)
    assert topk_key(seq) == topk_key(want)


@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_routed_artifact_parity(lake, single_host, n_shards):
    """fetch_postings / gather_candidates / superkey_of_rows reproduce the
    merged single-host artifacts exactly (shard concat == global order)."""
    corpus, _q, _qc = lake
    idx = make_routed(corpus, 128, n_shards)
    ref = single_host[128]
    values = [corpus.unique_values[i] for i in sorted(ref.postings)][:32]
    for v in values:
        assert np.array_equal(idx.fetch_postings(v), ref.fetch_postings(v)), v
    blk_got, blk_ref = idx.gather_candidates(values), ref.gather_candidates(
        values
    )
    assert np.array_equal(blk_got.table_ptr, blk_ref.table_ptr)
    assert np.array_equal(blk_got.table_ids, blk_ref.table_ids)
    assert np.array_equal(blk_got.rows, blk_ref.rows)
    assert np.array_equal(blk_got.value_idx, blk_ref.value_idx)
    rows = np.arange(0, corpus.total_rows, 3, dtype=np.int64)
    rng = np.random.default_rng(7)
    rng.shuffle(rows)  # out-of-order + cross-shard interleaved
    assert np.array_equal(idx.superkey_of_rows(rows), ref.superkey_of_rows(rows))


@pytest.mark.parametrize("bits", WIDTHS)
def test_routed_session_discover_many_identical(lake, single_host, bits):
    """Group batching (plan_and_count + score_from_counts) through a routed
    session matches the single-host session bit-for-bit, and the routed
    PlanCounts demux attributes launches/bytes per request."""
    corpus, query, q_cols = lake
    routed = MateSession.build(
        corpus, DiscoveryConfig(bits=bits), distributed=True, n_shards=4
    )
    assert getattr(routed.index, "routed", False)
    assert routed.build_stats is not None and routed.build_stats.sharded
    ref = MateSession(single_host[bits], DiscoveryConfig(bits=bits))
    queries = [(query, q_cols)] + synthetic.make_mixed_queries(
        corpus, 2, 10, 2, seed=11
    )
    out = routed.discover_many(queries, k=[10, 4, 4])
    out_ref = ref.discover_many(queries, k=[10, 4, 4])
    for (entries, _), (entries_ref, _) in zip(out, out_ref):
        assert topk_key(entries) == topk_key(entries_ref)
    assert routed.stats.shard_launches > 0
    assert routed.stats.route_bytes_merged > 0
    # per-request attribution: the demux carries route accounting
    plans = routed.plan_and_count(queries)
    for pc in plans:
        if pc.plan.block.n_items:
            assert pc.route_launches >= 1
            assert pc.route_bytes == pc.route_launches * pc.counts.shape[0] * 4


def test_routed_bound_cache_replay_no_new_launches(lake):
    """score_from_counts(from_cache=True) must not re-count routed launches
    — the filter was paid for by the original request."""
    corpus, query, q_cols = lake
    routed = MateSession.build(
        corpus, DiscoveryConfig(bits=128), distributed=True, n_shards=2
    )
    (pc,) = routed.plan_and_count([(query, q_cols)])
    routed.score_from_counts(pc, k=10)
    launches = routed.stats.shard_launches
    bytes_merged = routed.stats.route_bytes_merged
    routed.score_from_counts(pc, k=5, from_cache=True)
    assert routed.stats.shard_launches == launches
    assert routed.stats.route_bytes_merged == bytes_merged


# ---------------------------------------------------------------------------
# Mesh-attached routing (the 8-virtual-device CI leg)
# ---------------------------------------------------------------------------


@needs_8_devices
@pytest.mark.parametrize("bits", WIDTHS)
@pytest.mark.parametrize("n_devices", SHARD_COUNTS)
def test_mesh_routed_matrix_byte_identical(lake, single_host, n_devices, bits):
    corpus, query, q_cols = lake
    want, _ = batched.discover_batched(single_host[bits], query, q_cols, k=10)
    idx = make_routed(corpus, bits, n_devices)
    if n_devices > 1:
        mesh = meshlib.make_mesh((n_devices,), ("data",))
        idx.attach_mesh(mesh, ("data",))
    got, stats = batched.discover_batched(idx, query, q_cols, k=10)
    assert topk_key(got) == topk_key(want)
    assert stats.shard_launches >= 1 and stats.route_bytes_merged > 0


@needs_8_devices
def test_mesh_built_routed_session(lake, single_host):
    """build_routed_index over a mesh: shard_map hashing + routed index,
    mesh stays attached, discovery identical."""
    corpus, query, q_cols = lake
    mesh = meshlib.make_mesh((4,), ("data",))
    idx, stats = build_routed_index(
        corpus,
        cfg=xash.XashConfig(bits=256),
        use_corpus_char_freq=True,
        mesh=mesh,
        row_axes=("data",),
    )
    assert stats.sharded and stats.n_shards == 4
    assert sum(stats.shard_rows) == corpus.total_rows
    want, _ = batched.discover_batched(single_host[256], query, q_cols, k=10)
    got, _ = batched.discover_batched(idx, query, q_cols, k=10)
    assert topk_key(got) == topk_key(want)
    # detach falls back to host-routed launches, still identical
    idx.detach_mesh()
    got2, st2 = batched.discover_batched(idx, query, q_cols, k=10)
    assert topk_key(got2) == topk_key(want)
    assert st2.shard_launches >= 1


def test_attach_mesh_shard_mismatch_raises(lake):
    corpus, _q, _qc = lake
    idx = make_routed(corpus, 128, 2)
    if N_DEVICES < 1:
        pytest.skip("no devices")
    mesh = meshlib.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="shards"):
        idx.attach_mesh(mesh, ("data",))


def test_mesh_n_shards_conflict_raises(lake):
    corpus, _q, _qc = lake
    mesh = meshlib.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="n_shards"):
        build_routed_index(corpus, mesh=mesh, n_shards=3)


# ---------------------------------------------------------------------------
# §5.4 mutations stay shard-local (satellite 5)
# ---------------------------------------------------------------------------


def test_mutations_shard_local_epochs_and_stores():
    """insert/update/delete on a routed index bump ONLY the owning shard's
    epoch and refresh ONLY that shard's device store; top-k stays
    bit-identical to a from-scratch single-host rebuild."""
    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=60, seed=1))
    query, q_cols, _expected, corpus = synthetic.make_query_with_ground_truth(
        corpus
    )
    idx = make_routed(corpus, 128, 4)
    # materialise every shard's device store, remember identities
    for s in idx.shards:
        s.device_store()
    stores_before = [s._store for s in idx.shards]
    epochs_before = [s.mutation_epoch for s in idx.shards]
    agg_before = idx.mutation_epoch

    key_cells = [
        [query.cells[r][c] for c in q_cols] for r in range(query.n_rows)
    ]
    new_cells = [kc + ["routed-extra"] for kc in key_cells]
    tid = idx.insert_table(new_cells)  # appends to the LAST shard
    idx.update_cell(tid, 0, len(new_cells[0]) - 1, "mutated")

    epochs_after = [s.mutation_epoch for s in idx.shards]
    assert epochs_after[:-1] == epochs_before[:-1]  # untouched shards
    assert epochs_after[-1] > epochs_before[-1]  # owning shard bumped
    assert idx.mutation_epoch > agg_before  # aggregate is monotone
    # untouched shards' stores are the SAME objects (no re-upload)
    for s, store in zip(idx.shards[:-1], stores_before[:-1]):
        assert s.device_store() is store

    mutated = [list(r) for r in new_cells]
    mutated[0][-1] = "mutated"
    rebuilt = MateIndex(
        Corpus([*corpus.tables[:-1], Table(tid, mutated)]), cfg=idx.cfg
    )
    got, _ = batched.discover_batched(idx, query, q_cols, k=8)
    want, _ = batched.discover_batched(rebuilt, query, q_cols, k=8)
    assert topk_key(got) == topk_key(want)
    assert tid in [e.table_id for e in got]

    # delete stays shard-local too, and discovery drops the table
    epochs_mid = [s.mutation_epoch for s in idx.shards]
    idx.delete_table(tid)
    epochs_del = [s.mutation_epoch for s in idx.shards]
    assert epochs_del[:-1] == epochs_mid[:-1]
    assert epochs_del[-1] > epochs_mid[-1]
    ref = MateIndex(corpus2_without(corpus, tid), cfg=idx.cfg)
    got2, _ = batched.discover_batched(idx, query, q_cols, k=8)
    want2, _ = batched.discover_batched(ref, query, q_cols, k=8)
    assert topk_key(got2) == topk_key(want2)
    assert tid not in [e.table_id for e in got2]


def corpus2_without(corpus, tid):
    return Corpus([t for t in corpus.tables if t.table_id != tid])


def test_update_cell_on_interior_shard_touches_only_that_shard(lake):
    corpus, query, q_cols = lake
    idx = make_routed(corpus, 128, 4)
    for s in idx.shards:
        s.device_store()
    stores = [s._store for s in idx.shards]
    epochs = [s.mutation_epoch for s in idx.shards]
    # pick a table owned by shard 1 (an interior shard)
    shard = idx.shards[1]
    tid = int(shard.table_lo)
    assert idx.shard_of_table(tid).shard_id == 1
    old = corpus.tables[tid].cells[0][0]
    idx.update_cell(tid, 0, 0, old + "-touched")
    for i, s in enumerate(idx.shards):
        if i == 1:
            assert s.mutation_epoch > epochs[i]
            assert s.device_store() is not stores[i]
        else:
            assert s.mutation_epoch == epochs[i]
            assert s.device_store() is stores[i]
    # and the index still matches a rebuild
    rebuilt = MateIndex(Corpus(corpus.tables), cfg=idx.cfg)
    got, _ = batched.discover_batched(idx, query, q_cols, k=8)
    want, _ = batched.discover_batched(rebuilt, query, q_cols, k=8)
    assert topk_key(got) == topk_key(want)
    # restore for the module-scoped fixture's other consumers
    idx.update_cell(tid, 0, 0, old)


# ---------------------------------------------------------------------------
# Serving tier inherits routing (zero engine changes)
# ---------------------------------------------------------------------------


def test_serving_engine_over_routed_session(lake, single_host):
    corpus, query, q_cols = lake
    routed = MateSession.build(
        corpus,
        DiscoveryConfig(bits=128, result_cache=4),
        distributed=True,
        n_shards=4,
    )
    engine = DiscoveryEngine(session=routed, batch=4)
    queries = [(query, q_cols)] + synthetic.make_mixed_queries(
        corpus, 2, 10, 2, seed=11
    )
    reqs = [engine.submit(q, qc) for q, qc in queries]
    served = engine.flush()
    assert len(served) == len(queries)
    assert all(r.done for r in reqs)
    ref = MateSession(single_host[128], DiscoveryConfig(bits=128))
    for (q, qc), req in zip(queries, reqs):
        want, _ = ref.discover(q, qc, k=routed.config.k)
        assert topk_key(req.results) == topk_key(want)
    assert routed.stats.shard_launches > 0
    # repeat traffic answers from the result cache (mutation_epoch-keyed)
    hit = engine.discover(query, q_cols)
    assert hit.from_cache
    # a shard-local mutation invalidates it (aggregate epoch moved)
    routed.insert_table([["cache", "buster"]])
    miss = engine.discover(query, q_cols)
    assert not miss.from_cache
