"""Optimizer, losses, compression, checkpointing, data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.train import compression, optimizer as opt
from repro.train.step import chunked_ce

KEY = jax.random.PRNGKey(0)


def test_adamw_converges_quadratic():
    cfg = opt.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=200)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init_state(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.adamw_update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.3


@pytest.mark.parametrize("dtype", ["f32", "bf16", "int8"])
def test_optimizer_state_dtypes(dtype):
    cfg = opt.AdamWConfig(lr=0.05, state_dtype=dtype, warmup_steps=1, total_steps=100)
    params = {"w": jnp.ones((300,)) * 4.0}
    state = opt.init_state(params, cfg)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = opt.adamw_update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1.5, dtype


def test_schedule_warmup_cosine():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(opt.schedule(cfg, jnp.array(0.0))) == 0.0
    assert abs(float(opt.schedule(cfg, jnp.array(10.0))) - 1.0) < 1e-6
    assert abs(float(opt.schedule(cfg, jnp.array(100.0))) - 0.1) < 1e-3


def test_int8_quant_roundtrip():
    x = jax.random.normal(KEY, (1000,)) * 3
    q = opt._quant(x)
    back = opt._dequant(q, (1000,))
    assert float(jnp.max(jnp.abs(back - x))) < 3 * 2 / 127 + 1e-3


def test_chunked_ce_matches_full():
    B, S, D, V = 2, 32, 16, 50
    h = jax.random.normal(KEY, (B, S, D), jnp.float32)
    head = jax.random.normal(KEY, (D, V), jnp.float32)
    labels = jax.random.randint(KEY, (B, S), 0, V).at[:, -3:].set(-1)
    full = chunked_ce(h, head, labels, 0, 1e-4)
    for chunk in (8, 16, 32):
        part = chunked_ce(h, head, labels, chunk, 1e-4)
        assert abs(float(full) - float(part)) < 1e-4
    # gradients agree too
    g1 = jax.grad(lambda hh: chunked_ce(hh, head, labels, 0, 1e-4))(h)
    g2 = jax.grad(lambda hh: chunked_ce(hh, head, labels, 8, 1e-4))(h)
    assert float(jnp.max(jnp.abs(g1 - g2))) < 1e-5


def test_grad_compression_error_feedback():
    """int8+EF gradient exchange stays close to exact reduction over steps."""
    g_seq = [jax.random.normal(jax.random.PRNGKey(i), (64,)) for i in range(30)]
    err = jnp.zeros((64,))
    acc_exact = jnp.zeros((64,))
    acc_comp = jnp.zeros((64,))
    for g in g_seq:
        acc_exact += g
        gf = g + err
        q, s = compression.quantize(gf)
        deq = compression.dequantize(q, s)
        err = gf - deq
        acc_comp += deq
    # cumulative compressed sum tracks the exact sum (EF removes bias)
    assert float(jnp.max(jnp.abs(acc_comp - acc_exact))) < 0.2


def test_checkpoint_roundtrip_and_keep(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda x: x * step, tree))
    assert mgr.all_steps() == [2, 3]  # keep=2 GC'd step 1
    restored = mgr.restore(3, tree)
    assert np.array_equal(np.asarray(restored["a"]), np.arange(6).reshape(2, 3) * 3)
    assert restored["b"]["c"].dtype == jnp.bfloat16
    step, r2 = mgr.restore_latest(tree)
    assert step == 3


def test_checkpoint_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    tree = {"a": jnp.ones((3,))}
    path = mgr.save(7, tree)
    assert not os.path.exists(path + ".tmp")
    assert os.path.exists(os.path.join(path, "manifest.json"))


def test_data_pipeline_deterministic_restartable():
    cfg = DataConfig(seq_len=32, global_batch=4, vocab_size=100, seed=9)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    b1, b2 = p1.batch(17), p2.batch(17)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 32)
    assert np.array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert (b1["labels"][:, -1] == -1).all()
    # learnable structure: bigram determinism rate ≈ 70%
    det = np.mean(p1.next_tok[b1["tokens"][:, :-1]] == b1["tokens"][:, 1:])
    assert det > 0.5
