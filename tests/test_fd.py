"""FD-workload acceptance (ISSUE 10): ``core.fd.discover_fds`` against a
brute-force join + groupby oracle.

Pinned contracts:
  * ``discover_fds`` reports EXACTLY the oracle's per-table facts
    (support, holds, violations) on planted lakes containing clean FD
    tables, violators, near-miss tables (violating VALUES without the
    composite key), duplicate rows, NULL-like empty strings, permuted key
    columns, and zero-row tables — at 128/256/512 bits;
  * zero false negatives at every width: the count prune is exact on the
    negative side (§6.3 lemma), so no table the oracle reports can be
    missing;
  * global and routed ({1,2,4,8} shards) runs are bit-identical;
  * the validation re-gather is epoch-pinned — a §5.4 mutation between the
    filter launch and validation raises instead of silently validating
    against rows the filter never probed;
  * the multi-signal ensemble only SCORES and reorders — the reported facts
    are identical with signals off — and ``DiscoveryConfig`` rejects
    malformed signal specs;
  * the pure-python oracle and the pandas join+groupby oracle agree
    (pandas is optional: the python fallback keeps the harness running on
    deps-minimal environments).

The hypothesis property widens the seed net; without hypothesis the seeded
parametrizations still pin the contract.
"""

import dataclasses
import itertools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # property tests skip; unit tests still run
    HAVE_HYPOTHESIS = False

    def _skip_decorator(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    given = settings = _skip_decorator

    class _StrategyStub:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

try:
    import pandas as pd

    HAVE_PANDAS = True
except ModuleNotFoundError:
    HAVE_PANDAS = False

from repro.core import batched, fd, xash
from repro.core.corpus import Corpus, Table
from repro.core.index import build_index
from repro.core.routing import build_routed_index
from repro.core.session import DiscoveryConfig, MateSession

from conftest import ALL_BITS

SHARD_COUNTS = (1, 2, 4, 8)
SEEDS = (0, 1, 2)


# ---------------------------------------------------------------------------
# Planted-FD lake: every edge the workload must survive, seeded.
# ---------------------------------------------------------------------------

def planted_fd_lake(seed: int):
    """Returns (corpus, query, determinant_cols, dependent_col).

    Query groups 0 and 1 VIOLATE the FD (two dependent values); group 2 has
    a duplicate row (clean); one group uses an empty-string determinant
    value and an empty-string dependent value.  The lake plants clean-FD
    tables, violators (hold a violating composite key), near-misses (hold
    the violating VALUES but never the composite key), a permuted-column
    match, a zero-row table, and seeded single-value noise.
    """
    rng = np.random.default_rng(seed)
    n_keys = 6
    keys = [(f"a{seed}k{r}", f"b{seed}k{r}") for r in range(n_keys)]
    q_cells = []
    for r, (a, b) in enumerate(keys):
        q_cells.append([a, b, f"d{r}"])
        if r < 2:
            q_cells.append([a, b, f"d{r}x"])  # violating group (2 dep values)
        if r == 2:
            q_cells.append([a, b, f"d{r}"])  # duplicate row — still clean
    q_cells.append(["", f"b{seed}nul", ""])  # NULL-like empty strings
    query = Table(-1, q_cells, name=f"fd query {seed}")
    det_cols, dep_col = [0, 1], 2

    tables: list[Table] = []
    # clean FD tables: only clean composite keys
    tables.append(Table(0, [[a, b, f"p{seed}"] for a, b in keys[2:]],
                        name="clean wide"))
    tables.append(Table(1, [[keys[3][0], keys[3][1], "q"],
                            [keys[4][0], keys[4][1], "q"]], name="clean two"))
    # violators: hold a violating composite key (+ clean ones for support)
    tables.append(Table(2, [[keys[0][0], keys[0][1], "v"],
                            [keys[2][0], keys[2][1], "v"]], name="violator a"))
    tables.append(Table(3, [[keys[1][0], keys[1][1], "w"]], name="violator b"))
    # near-miss: the violating VALUES appear, the composite key never does
    tables.append(Table(4, [[keys[0][0], f"zz{seed}"],
                            [f"yy{seed}", keys[0][1]],
                            [keys[5][0], keys[5][1]]], name="near miss"))
    # permuted columns: key values live in (2, 1) — the injective mapping
    tables.append(Table(5, [["pad", keys[5][1], keys[5][0]]], name="permuted"))
    # the empty-string determinant key, matchable
    tables.append(Table(6, [["", f"b{seed}nul", "k"]], name="empty det"))
    tables.append(Table(7, [], name="zero rows"))
    # seeded noise: single determinant-column values (posting candidates
    # whose composite keys never match)
    for _ in range(8):
        tid = len(tables)
        r = int(rng.integers(n_keys))
        cells = [[keys[r][0], f"n{tid}x{j}{seed}"]
                 for j in range(int(rng.integers(1, 4)))]
        tables.append(Table(tid, cells))
    return Corpus(tables), query, det_cols, dep_col


# ---------------------------------------------------------------------------
# Oracles: brute-force join + groupby, pure python and pandas.
# ---------------------------------------------------------------------------

def _row_matches(key: tuple, row: list) -> bool:
    """Injective column-mapping match (independent of the engine's
    ``_verify_pair``): some assignment of DISTINCT row columns equals the
    key tuple position-wise."""
    if len(row) < len(key):
        return False
    per_col = [[c for c, v in enumerate(row) if v == qv] for qv in key]
    if any(not cols for cols in per_col):
        return False
    for assign in itertools.product(*per_col):
        if len(set(assign)) == len(assign):
            return True
    return False


def fd_oracle_python(corpus, query, det_cols, dep_col, min_support):
    """{table_id: (support, holds, violations)} by scanning every row."""
    dep_of_key: dict[tuple, set] = {}
    for row in query.cells:
        k = tuple(row[c] for c in det_cols)
        dep_of_key.setdefault(k, set()).add(row[dep_col])
    out = {}
    for t in corpus.tables:
        matched = {
            k for k in dep_of_key
            if any(_row_matches(k, row) for row in t.cells)
        }
        if len(matched) < min_support:
            continue
        viol = sum(1 for k in matched if len(dep_of_key[k]) > 1)
        out[t.table_id] = (len(matched), viol == 0, viol)
    return out


def fd_oracle_pandas(corpus, query, det_cols, dep_col, min_support):
    """The same facts via a MATERIALIZED pandas join + groupby: Q ⋈ T under
    every injective column mapping, concatenated, then nunique(dep) per
    determinant group — the computation ``discover_fds`` exists to avoid."""
    width = len(det_cols)
    dcols = [f"d{i}" for i in range(width)]
    qdf = pd.DataFrame({
        dcols[i]: [row[c] for row in query.cells]
        for i, c in enumerate(det_cols)
    })
    qdf["dep"] = [row[dep_col] for row in query.cells]
    out = {}
    for t in corpus.tables:
        if t.n_cols < width or t.n_rows == 0:
            continue
        tdf = pd.DataFrame(t.cells, columns=[f"c{j}" for j in range(t.n_cols)])
        frames = []
        for mapping in itertools.permutations(range(t.n_cols), width):
            m = qdf.merge(
                tdf, left_on=dcols,
                right_on=[f"c{j}" for j in mapping], how="inner",
            )
            if len(m):
                frames.append(m[dcols + ["dep"]])
        if not frames:
            continue
        j = pd.concat(frames).drop_duplicates()
        support = int(j[dcols].drop_duplicates().shape[0])
        if support < min_support:
            continue
        viol = int((j.groupby(dcols)["dep"].nunique() > 1).sum())
        out[t.table_id] = (support, viol == 0, viol)
    return out


def _facts(fds):
    return {c.table_id: (c.support, c.holds, c.violations) for c in fds}


def _entry_key(fds):
    return [dataclasses.astuple(c) for c in fds]


# ---------------------------------------------------------------------------
# Engine vs oracle, every width
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", ALL_BITS)
@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("min_support", (1, 2))
def test_matches_oracle_at_every_width(bits, seed, min_support):
    corpus, query, det_cols, dep_col = planted_fd_lake(seed)
    index = build_index(corpus, cfg=xash.XashConfig(bits=bits))[0]
    fds, stats = fd.discover_fds(
        index, query, det_cols, dep_col, min_support=min_support
    )
    oracle = fd_oracle_python(corpus, query, det_cols, dep_col, min_support)
    facts = _facts(fds)
    assert facts == oracle
    # zero false negatives, stated explicitly: every oracle table (and in
    # particular every FD-PRESERVING one) is reported with its exact facts
    for tid, truth in oracle.items():
        assert facts[tid] == truth
    # the counters tell a coherent prune story
    assert stats.fd_candidates >= stats.fd_validated >= len(fds)
    assert (stats.fd_bytes_verified > 0) == (stats.fd_validated > 0)


def test_count_prune_is_real_and_exact():
    """min_support=2 must prune candidates BEFORE validation (fewer tables
    re-gathered than at min_support=1) without changing any reported fact
    the oracle confirms at that threshold."""
    corpus, query, det_cols, dep_col = planted_fd_lake(0)
    index = build_index(corpus, cfg=xash.XashConfig(bits=128))[0]
    _, st1 = fd.discover_fds(index, query, det_cols, dep_col, min_support=1)
    fds2, st2 = fd.discover_fds(index, query, det_cols, dep_col, min_support=2)
    assert st2.fd_candidates == st1.fd_candidates
    assert st2.fd_validated < st1.fd_validated
    assert st2.fd_bytes_verified < st1.fd_bytes_verified
    assert _facts(fds2) == fd_oracle_python(corpus, query, det_cols, dep_col, 2)


def test_no_matches_yields_empty():
    corpus, _q, det_cols, dep_col = planted_fd_lake(0)
    index = build_index(corpus, cfg=xash.XashConfig(bits=128))[0]
    stranger = Table(-1, [["no-such-a", "no-such-b", "dep"]])
    fds, stats = fd.discover_fds(index, stranger, det_cols, dep_col)
    assert fds == [] and stats.fd_candidates == stats.fd_validated == 0


def test_trivial_fd_rejected():
    corpus, query, det_cols, _dep = planted_fd_lake(0)
    index = build_index(corpus, cfg=xash.XashConfig(bits=128))[0]
    with pytest.raises(ValueError, match="trivial"):
        fd.discover_fds(index, query, det_cols, det_cols[0])


@pytest.mark.skipif(not HAVE_PANDAS, reason="pandas not installed")
@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("min_support", (1, 2))
def test_oracles_agree(seed, min_support):
    """The pure-python scan and the pandas materialized join+groupby are the
    same ground truth — so either one anchors the engine tests."""
    corpus, query, det_cols, dep_col = planted_fd_lake(seed)
    assert fd_oracle_python(
        corpus, query, det_cols, dep_col, min_support
    ) == fd_oracle_pandas(corpus, query, det_cols, dep_col, min_support)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_property_random_lakes_match_oracle(seed):
    """Hypothesis-widened seed net at 128 bits (the FP-heaviest width:
    most survivors reach validation, the hardest case for exactness)."""
    corpus, query, det_cols, dep_col = planted_fd_lake(seed)
    index = build_index(corpus, cfg=xash.XashConfig(bits=128))[0]
    for min_support in (1, 2):
        fds, _ = fd.discover_fds(
            index, query, det_cols, dep_col, min_support=min_support
        )
        assert _facts(fds) == fd_oracle_python(
            corpus, query, det_cols, dep_col, min_support
        )


# ---------------------------------------------------------------------------
# Routed lake: bit-identical at {1,2,4,8} shards × every width
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", ALL_BITS)
@pytest.mark.parametrize("n_shards", SHARD_COUNTS)
def test_routed_bit_identical(bits, n_shards):
    corpus, query, det_cols, dep_col = planted_fd_lake(1)
    cfg = xash.XashConfig(bits=bits)
    global_idx = build_index(corpus, cfg=cfg)[0]
    routed_idx, _ = build_routed_index(corpus, cfg=cfg, n_shards=n_shards)
    ref, _ = fd.discover_fds(global_idx, query, det_cols, dep_col)
    got, stats = fd.discover_fds(routed_idx, query, det_cols, dep_col)
    assert _entry_key(got) == _entry_key(ref)  # bit-identical sequence
    if n_shards > 1:
        # the routed validation re-gathers from owning shards — same bytes
        assert stats.fd_bytes_verified > 0


# ---------------------------------------------------------------------------
# Epoch pinning, session threading, signals
# ---------------------------------------------------------------------------

def test_stale_plancounts_raises():
    """A §5.4 mutation between the filter launch and validation must raise:
    the re-gather would read rows the filter never probed."""
    corpus, query, det_cols, dep_col = planted_fd_lake(0)
    index = build_index(corpus, cfg=xash.XashConfig(bits=128))[0]
    [pc] = batched.plan_and_count(index, [(query, det_cols)])
    index.insert_table([["mutant", "row"]])
    with pytest.raises(ValueError, match="stale"):
        fd.fds_from_counts(index, pc, dep_col)


def test_session_threads_config_and_absorbs_stats():
    corpus, query, det_cols, dep_col = planted_fd_lake(2)
    index = build_index(corpus, cfg=xash.XashConfig(bits=128))[0]
    session = MateSession(index)
    fds, stats = session.discover_fds(query, det_cols, dep_col, min_support=1)
    assert _facts(fds) == fd_oracle_python(corpus, query, det_cols, dep_col, 1)
    assert session.stats.requests == 1
    assert session.stats.fd_candidates == stats.fd_candidates > 0
    assert session.stats.fd_validated == stats.fd_validated > 0
    assert session.stats.fd_bytes_verified == stats.fd_bytes_verified > 0


def test_signals_only_reorder_never_change_facts():
    corpus, query, det_cols, dep_col = planted_fd_lake(0)
    index = build_index(corpus, cfg=xash.XashConfig(bits=128))[0]
    plain, _ = fd.discover_fds(index, query, det_cols, dep_col)
    session = MateSession(index, DiscoveryConfig(signals=fd.DEFAULT_SIGNALS))
    scored, _ = session.discover_fds(query, det_cols, dep_col)
    assert _facts(scored) == _facts(plain)
    assert all(c.score is not None for c in scored)
    assert all(c.score is None for c in plain)
    # the declared order: descending ensemble score
    svals = [c.score for c in scored]
    assert svals == sorted(svals, reverse=True)


@pytest.mark.parametrize("bad", [
    [("joinability", 1.0)],            # list: unhashable for a frozen config
    (("bogus", 1.0),),                 # unknown signal name
    (("joinability", 0.0),),           # non-positive weight
    (("joinability",),),               # malformed pair
])
def test_config_rejects_malformed_signals(bad):
    with pytest.raises(ValueError):
        DiscoveryConfig(signals=bad)
