"""Pallas kernel tests: interpret-mode vs pure-jnp oracle, shape sweeps."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import xash
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def rand_rows(n, c, max_len):
    lens = RNG.integers(0, max_len, size=(n, c))
    out = np.zeros((n, c, max_len), dtype=np.uint8)
    for i in range(n):
        for j in range(c):
            out[i, j, : lens[i, j]] = RNG.integers(1, 38, size=lens[i, j])
    return out


@pytest.mark.parametrize("n,c,max_len", [
    (4, 1, 16), (128, 3, 48), (200, 7, 48), (257, 2, 32), (64, 12, 24),
])
def test_superkey_kernel_matches_ref(n, c, max_len):
    cfg = xash.XashConfig(max_len=max_len)
    enc = rand_rows(n, c, max_len)
    got = np.asarray(ops.superkey(enc, cfg))
    want = np.asarray(ref.xash_superkey_ref(jnp.asarray(enc), cfg))
    assert got.shape == (n, cfg.lanes)
    assert np.array_equal(got, want)


@pytest.mark.parametrize("bits", [128, 256, 512])
def test_superkey_kernel_hash_sizes(bits):
    cfg = xash.XashConfig(bits=bits, max_len=32)
    enc = rand_rows(100, 4, 32)
    got = np.asarray(ops.superkey(enc, cfg))
    want = np.asarray(ref.xash_superkey_ref(jnp.asarray(enc), cfg))
    assert np.array_equal(got, want)


def test_xash_values_kernel():
    cfg = xash.DEFAULT_CONFIG
    enc = rand_rows(300, 1, cfg.max_len)[:, 0, :]
    got = np.asarray(ops.xash_values(enc, cfg))
    want = np.asarray(ref.xash_ref(jnp.asarray(enc), cfg))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("n,q", [(10, 3), (1024, 256), (1000, 37), (2049, 300)])
def test_filter_match_kernel(n, q):
    cfg = xash.DEFAULT_CONFIG
    row_sk = np.asarray(
        ref.xash_superkey_ref(jnp.asarray(rand_rows(n, 5, 32)), cfg)
    )
    q_sk = np.asarray(ref.xash_superkey_ref(jnp.asarray(rand_rows(q, 2, 32)), cfg))
    got = np.asarray(ops.filter_match(row_sk, q_sk))
    want = np.asarray(ref.filter_match_ref(jnp.asarray(row_sk), jnp.asarray(q_sk)))
    assert got.shape == (n, q)
    assert np.array_equal(got, want)


@pytest.mark.parametrize("n,q", [(10, 3), (1024, 256), (777, 100)])
def test_filter_count_kernel(n, q):
    cfg = xash.DEFAULT_CONFIG
    row_sk = np.asarray(
        ref.xash_superkey_ref(jnp.asarray(rand_rows(n, 5, 32)), cfg)
    )
    q_sk = np.asarray(ref.xash_superkey_ref(jnp.asarray(rand_rows(q, 2, 32)), cfg))
    got = np.asarray(ops.filter_count(row_sk, q_sk))
    want = np.asarray(ref.filter_count_ref(jnp.asarray(row_sk), jnp.asarray(q_sk)))
    assert np.array_equal(got, want)


def test_filter_count_zero_query_edge():
    cfg = xash.DEFAULT_CONFIG
    row_sk = np.asarray(
        ref.xash_superkey_ref(jnp.asarray(rand_rows(300, 5, 32)), cfg)
    )
    q0 = np.zeros((3, cfg.lanes), dtype=np.uint32)
    got = np.asarray(ops.filter_count(row_sk, q0))
    want = np.asarray(ref.filter_count_ref(jnp.asarray(row_sk), jnp.asarray(q0)))
    assert np.array_equal(got, want)


@pytest.mark.parametrize("bits", [128, 256, 512])
def test_filter_count_all_zero_queries_across_widths(bits):
    """All-zero (empty-string key) query superkeys subsume EVERY row —
    including the rows the wrapper pads in — at any lane count."""
    cfg = xash.XashConfig(bits=bits, max_len=32)
    # 333 rows forces row padding to the 1024 block; 5 queries pads q to 256
    row_sk = np.asarray(ref.xash_superkey_ref(jnp.asarray(rand_rows(333, 4, 32)), cfg))
    q_sk = np.array(ref.xash_superkey_ref(jnp.asarray(rand_rows(5, 2, 32)), cfg))
    q_sk[2] = 0  # zero query mixed among real ones
    got = np.asarray(ops.filter_count(row_sk, q_sk))
    want = np.asarray(ref.filter_count_ref(jnp.asarray(row_sk), jnp.asarray(q_sk)))
    assert np.array_equal(got, want)
    assert got[2] == 333  # vacuous truth: zero query matches every real row


@pytest.mark.parametrize("bits", [128, 256, 512])
@pytest.mark.parametrize("n,q", [(100, 7), (1030, 70)])
def test_filter_count_agrees_with_match_sum(bits, n, q):
    """filter_count == filter_match(...).sum(axis=0) on padded blocks at
    every width (the fused count must equal the materialised reduction)."""
    cfg = xash.XashConfig(bits=bits, max_len=32)
    row_sk = np.asarray(ref.xash_superkey_ref(jnp.asarray(rand_rows(n, 5, 32)), cfg))
    q_sk = np.asarray(ref.xash_superkey_ref(jnp.asarray(rand_rows(q, 2, 32)), cfg))
    counts = np.asarray(ops.filter_count(row_sk, q_sk))
    match = np.asarray(ops.filter_match(row_sk, q_sk))
    assert counts.shape == (q,) and match.shape == (n, q)
    assert np.array_equal(counts, match.sum(axis=0, dtype=np.int32))


@pytest.mark.parametrize("bits", [128, 256, 512])
def test_filter_hits_table_counts_matches_oracle(bits, monkeypatch):
    """Device-side rule-1/2 reduction == host oracle at every width and on
    every dispatch path (numpy / XLA / interpret-mode Pallas), on shapes
    that force pow2 padding of rows, queries and table segments."""
    cfg = xash.XashConfig(bits=bits, max_len=32)
    rng = np.random.default_rng(bits)
    n, q, n_tables = 700, 23, 19
    row_sk = np.asarray(ref.xash_superkey_ref(jnp.asarray(rand_rows(n, 5, 32)), cfg))
    q_sk = np.asarray(ref.xash_superkey_ref(jnp.asarray(rand_rows(q, 2, 32)), cfg))
    elig = rng.random((n, q)) < 0.6
    seg = np.sort(rng.integers(0, n_tables, size=n)).astype(np.int32)
    want_hits = ops.subsume_np(row_sk, q_sk) & elig
    want_counts = np.bincount(
        seg, weights=want_hits.sum(axis=1), minlength=n_tables
    ).astype(np.int32)
    for backend in ("numpy", "xla", "pallas"):
        monkeypatch.setenv("MATE_FILTER_BACKEND", backend)
        hits, counts = ops.filter_hits_table_counts(
            row_sk, q_sk, elig, seg, n_tables
        )
        assert np.array_equal(np.asarray(hits), want_hits), (bits, backend)
        assert np.array_equal(counts, want_counts), (bits, backend)
    monkeypatch.delenv("MATE_FILTER_BACKEND")
    hits, counts = ops.filter_hits_table_counts(
        row_sk, q_sk, elig, seg, n_tables, use_device=False
    )
    assert np.array_equal(np.asarray(hits), want_hits)
    assert np.array_equal(counts, want_counts)


@pytest.mark.parametrize("s,d,dv,window,dtype", [
    (256, 64, 64, 0, jnp.float32),
    (256, 64, 64, 64, jnp.float32),
    (384, 128, 64, 0, jnp.bfloat16),  # MLA-style dv != d, unaligned S
])
def test_flash_attention_kernel(s, d, dv, window, dtype):
    import jax

    rng = jax.random.PRNGKey(0)
    B, H = 2, 2
    q = jax.random.normal(rng, (B, s, H, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (B, s, H, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (B, s, H, dv), dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    sc = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) / np.sqrt(d)
    diff = jnp.arange(s)[:, None] - jnp.arange(s)[None, :]
    ok = diff >= 0
    if window:
        ok = ok & (diff < window)
    sc = jnp.where(ok[None, None], sc, -1e30)
    ref = jnp.einsum(
        "bhst,bthd->bshd", jax.nn.softmax(sc, -1).astype(dtype), v
    )
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    assert float(
        jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))
    ) < tol


def test_filter_block_shape_sweep():
    cfg = xash.DEFAULT_CONFIG
    row_sk = np.asarray(
        ref.xash_superkey_ref(jnp.asarray(rand_rows(512, 4, 32)), cfg)
    )
    q_sk = np.asarray(ref.xash_superkey_ref(jnp.asarray(rand_rows(64, 2, 32)), cfg))
    want = np.asarray(ref.filter_match_ref(jnp.asarray(row_sk), jnp.asarray(q_sk)))
    for bn, bq in [(128, 64), (256, 128), (512, 64)]:
        got = np.asarray(ops.filter_match(row_sk, q_sk, block_n=bn, block_q=bq))
        assert np.array_equal(got, want), (bn, bq)
