import os
import sys

# tests run on the default single CPU device (the dry-run manages its own
# device count in subprocesses; never set xla_force_host_platform_device_count
# here — smoke tests and benches must see 1 device).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
