import os
import sys

# tests run on the default single CPU device (the dry-run manages its own
# device count in subprocesses; never set xla_force_host_platform_device_count
# here — smoke tests and benches must see 1 device).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---------------------------------------------------------------------------
# Shared seeded lake factories.
#
# Three test modules (and bench_ranking, see benchmarks/common.py for the
# planted-quality variant) used to copy-paste these builders; a factory call
# with explicit parameters keeps each module's lake byte-identical to what
# its fixture used to build inline while making "same lake, different module"
# a visible fact instead of a coincidence of duplicated literals.
# ---------------------------------------------------------------------------

from repro.core import xash  # noqa: E402  (path bootstrap above)
from repro.core.index import MateIndex, build_index  # noqa: E402
from repro.data import synthetic  # noqa: E402

ALL_BITS = (128, 256, 512)


def ground_truth_lake(
    n_tables: int = 60,
    corpus_seed: int = 5,
    n_rows: int = 25,
    key_width: int = 2,
    query_seed: int = 7,
):
    """Seeded corpus + one query with injected ground-truth joinability.

    Returns (corpus, query, q_cols, expected) — ``expected`` maps injected
    table id → minimum joinability (``synthetic.make_query_with_ground_truth``
    rebuilds the corpus arenas after cell surgery, hence the re-bind).
    """
    corpus = synthetic.make_corpus(
        synthetic.SyntheticSpec(n_tables=n_tables, seed=corpus_seed)
    )
    query, q_cols, expected, corpus = synthetic.make_query_with_ground_truth(
        corpus, n_rows=n_rows, key_width=key_width, seed=query_seed
    )
    return corpus, query, q_cols, expected


def mixed_query_lake(
    n_tables: int = 120,
    corpus_seed: int = 7,
    n_queries: int = 4,
    n_rows: int = 20,
    key_width: int = 2,
    query_seed: int = 11,
):
    """Seeded corpus + FP-heavy mixed queries (the paper's sensor regime:
    key columns drawn from different tables).  Returns (corpus, queries)."""
    corpus = synthetic.make_corpus(
        synthetic.SyntheticSpec(n_tables=n_tables, seed=corpus_seed)
    )
    queries = synthetic.make_mixed_queries(
        corpus, n_queries, n_rows, key_width, seed=query_seed
    )
    return corpus, queries


def indexes_at_widths(corpus, widths=ALL_BITS, built: bool = True):
    """One index per superkey width.  ``built=True`` runs the full offline
    phase (``build_index``: eager profiles + build stats); ``built=False``
    wraps ``MateIndex`` directly (lazy profiles), preserving the historical
    behaviour of modules that never touch the profile store."""
    if built:
        return {
            bits: build_index(corpus, cfg=xash.XashConfig(bits=bits))[0]
            for bits in widths
        }
    return {
        bits: MateIndex(corpus, cfg=xash.XashConfig(bits=bits))
        for bits in widths
    }
