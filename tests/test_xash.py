"""XASH unit + property tests (paper §5)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # property tests skip; unit tests still run
    HAVE_HYPOTHESIS = False

    def _skip_decorator(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    given = settings = _skip_decorator

    class _StrategyStub:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

from repro.core import encoding, xash

CFG = xash.DEFAULT_CONFIG
CFG256 = xash.XashConfig(bits=256)
CFG512 = xash.XashConfig(bits=512)
ALL_WIDTHS = [
    pytest.param(CFG, id="128"),
    pytest.param(CFG256, id="256"),
    pytest.param(CFG512, id="512"),
]

value_strat = st.text(
    alphabet=st.characters(min_codepoint=32, max_codepoint=126),
    min_size=0,
    max_size=encoding.MAX_LEN,
)


def test_config_derivations_match_paper():
    # 128-bit: c=3 (Eq. 6), 111-bit char region, 17-bit length segment,
    # 6 ones for 700M uniques (Eq. 5, §5.3.1)
    assert CFG.c == 3
    assert CFG.char_region == 111
    assert CFG.len_segment == 17
    assert CFG.ones == 6
    assert CFG.n_char_bits == 5
    assert CFG512.c == 13  # argmax 37c < 512


def test_popcount_bounded():
    vals = ["massachusetts institute of technology", "ab", "0123456789", "x"]
    for v in vals:
        h = xash.xash_oracle(v, CFG)
        assert bin(h).count("1") <= CFG.ones


@settings(max_examples=200, deadline=None)
@given(value_strat)
def test_jax_matches_oracle(value):
    enc = encoding.encode_values([value], CFG.max_len)
    got = np.asarray(xash.xash(enc, CFG))[0]
    want = xash.int_to_lanes(xash.xash_oracle(value, CFG), CFG)
    assert np.array_equal(got, want), value


@pytest.mark.parametrize("cfg", ALL_WIDTHS)
@settings(max_examples=50, deadline=None)
@given(value_strat)
def test_jax_matches_oracle_all_widths(cfg, value):
    """Oracle-vs-vectorised agreement is width-independent (4/8/16 lanes)."""
    enc = encoding.encode_values([value], cfg.max_len)
    got = np.asarray(xash.xash(enc, cfg))[0]
    want = xash.int_to_lanes(xash.xash_oracle(value, cfg), cfg)
    assert np.array_equal(got, want), (cfg.bits, value)


@pytest.mark.parametrize("cfg", ALL_WIDTHS)
@settings(max_examples=50, deadline=None)
@given(st.data())
def test_lane_packing_roundtrip(cfg, data):
    """int_to_lanes/lanes_to_int are exact inverses for any bits-wide int."""
    h = data.draw(st.integers(0, (1 << cfg.bits) - 1))
    lanes = xash.int_to_lanes(h, cfg)
    assert lanes.shape == (cfg.lanes,) and lanes.dtype == np.uint32
    assert xash.lanes_to_int(lanes) == h


@pytest.mark.parametrize("cfg", ALL_WIDTHS)
@settings(max_examples=30, deadline=None)
@given(value_strat)
def test_oracle_roundtrip_through_lanes(cfg, value):
    """An oracle hash survives the uint32 lane packing at every width."""
    h = xash.xash_oracle(value, cfg)
    assert 0 <= h < (1 << cfg.bits)
    assert xash.lanes_to_int(xash.int_to_lanes(h, cfg)) == h


@pytest.mark.parametrize("cfg", ALL_WIDTHS)
def test_config_width_derivations(cfg):
    """Eqs. 5-6 at every width: segment split covers all bits, lanes align."""
    assert cfg.bits == cfg.lanes * 32
    assert cfg.char_region == encoding.ALPHABET_SIZE * cfg.c
    assert cfg.char_region + cfg.len_segment == cfg.bits
    # c maximal with 37*c < bits (Eq. 6)
    assert cfg.char_region < cfg.bits <= encoding.ALPHABET_SIZE * (cfg.c + 1)
    assert cfg.ones >= 2  # at least one char bit + the length bit


def test_rotation_distinguishes_anagrams():
    # same chars, same length → same bits WITHOUT location encoding; the
    # paper's location feature must separate them (§5.3.3 'loop' vs 'pool')
    assert xash.xash_oracle("loop", CFG) != xash.xash_oracle("pool", CFG)
    # length feature: same chars, different lengths
    assert xash.xash_oracle("aa", CFG) != xash.xash_oracle("aaa", CFG)


def test_empty_and_whitespace():
    assert xash.xash_oracle("", CFG) == 0
    assert xash.xash_oracle(" ", CFG) != 0


def test_determinism_across_calls():
    enc = encoding.encode_values(["hello world"] * 3, CFG.max_len)
    h = np.asarray(xash.xash(enc, CFG))
    assert np.array_equal(h[0], h[1]) and np.array_equal(h[1], h[2])


@settings(max_examples=100, deadline=None)
@given(st.lists(value_strat, min_size=1, max_size=8), st.data())
def test_no_false_negatives_lemma(row_values, data):
    """§6.3 Lemma: a key drawn from the row's own values is ALWAYS subsumed
    by the row super key — the filter never loses a joinable row."""
    enc = encoding.encode_values(row_values, CFG.max_len)[None]
    sk = np.asarray(xash.superkey(enc, CFG))[0]
    k = data.draw(st.integers(1, len(row_values)))
    idx = data.draw(
        st.lists(
            st.integers(0, len(row_values) - 1), min_size=k, max_size=k, unique=True
        )
    )
    q = 0
    for i in idx:
        q |= xash.xash_oracle(row_values[i], CFG)
    q_lanes = xash.int_to_lanes(q, CFG)
    assert np.all((q_lanes & ~sk) == 0)


def test_encoding_roundtrip():
    v = "hello world 42"
    assert encoding.decode_value(encoding.encode_value(v)) == v
    # non-alphabet chars map to space
    assert encoding.decode_value(encoding.encode_value("a-b")) == "a b"


@settings(max_examples=60, deadline=None)
@given(value_strat, st.integers(0, 7))
def test_ablation_flags_oracle_jax_parity(value, flags):
    """Fig-6 component switches: JAX impl must track the oracle exactly."""
    cfg = xash.XashConfig(
        use_location=bool(flags & 1),
        use_length=bool(flags & 2),
        use_rotation=bool(flags & 4),
    )
    enc = encoding.encode_values([value], cfg.max_len)
    got = np.asarray(xash.xash(enc, cfg))[0]
    want = xash.int_to_lanes(xash.xash_oracle(value, cfg), cfg)
    assert np.array_equal(got, want), (value, flags)
