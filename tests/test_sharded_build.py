"""Sharded offline index build: device-count equivalence matrix + merge
property tests.

The contract (ISSUE 5): ``build_index`` / ``MateSession.build(mesh=...)``
produce artifacts BYTE-IDENTICAL to the single-host ``MateIndex(...)``
constructor — ``value_lanes``, posting lists, CSR offsets, super keys — at
every device count in {1, 2, 4, 8} and every width in {128, 256, 512}, with
identical ``discover``/``discover_many`` top-k downstream.  The host-sharded
path (``n_shards`` without a mesh) exercises the same merge machinery on a
single device, so the property tests run in every CI leg; the mesh matrix
runs under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
``sharded-build`` CI leg) and skips where fewer devices are visible.
"""

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # property tests skip; the matrix still runs
    HAVE_HYPOTHESIS = False

    def _skip_decorator(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    given = settings = _skip_decorator

    class _StrategyStub:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

from repro.core import discovery, xash
from repro.core.corpus import Corpus, Table
from repro.core.index import (
    MateIndex,
    _csr_ptr,
    _hash_unique_values,
    _shard_postings,
    build_index,
    index_artifacts_equal,
    merge_shard_postings,
)
from repro.core.session import DiscoveryConfig, MateSession
from repro.data import synthetic
from repro.launch import mesh as meshlib

N_DEVICES = len(jax.devices())
DEVICE_COUNTS = (1, 2, 4, 8)
WIDTHS = (128, 256, 512)

needs_8_devices = pytest.mark.skipif(
    N_DEVICES < max(DEVICE_COUNTS),
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
    "(the sharded-build CI leg)",
)


@pytest.fixture(scope="module")
def lake():
    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=60, seed=1))
    query, q_cols, _expected, corpus = synthetic.make_query_with_ground_truth(
        corpus
    )
    return corpus, query, q_cols


@pytest.fixture(scope="module")
def single_host(lake):
    """Reference single-host indexes, one per width."""
    corpus, _q, _qc = lake
    return {
        bits: MateIndex(
            corpus, cfg=xash.XashConfig(bits=bits), use_corpus_char_freq=True
        )
        for bits in WIDTHS
    }


def assert_indexes_byte_identical(got: MateIndex, ref: MateIndex):
    """Every offline artifact byte-identical (the shared
    ``index_artifacts_equal`` contract), plus the config and the
    candidate-CSR offsets the online engine derives from them."""
    assert got.cfg == ref.cfg
    assert index_artifacts_equal(got, ref)
    # CSR layout the online engine consumes (gather_candidates offsets)
    values = [ref.corpus.unique_values[i] for i in sorted(ref.postings)][:24]
    blk_got, blk_ref = got.gather_candidates(values), ref.gather_candidates(values)
    assert np.array_equal(blk_got.table_ptr, blk_ref.table_ptr)
    assert np.array_equal(blk_got.table_ids, blk_ref.table_ids)
    assert np.array_equal(blk_got.rows, blk_ref.rows)
    assert np.array_equal(blk_got.value_idx, blk_ref.value_idx)


# ---------------------------------------------------------------------------
# Device-count equivalence matrix (the acceptance criterion)
# ---------------------------------------------------------------------------


@needs_8_devices
@pytest.mark.parametrize("bits", WIDTHS)
@pytest.mark.parametrize("n_devices", DEVICE_COUNTS)
def test_mesh_build_matrix_byte_identical(lake, single_host, n_devices, bits):
    corpus, _q, _qc = lake
    mesh = meshlib.make_mesh((n_devices,), ("data",))
    idx, stats = build_index(
        corpus, cfg=xash.XashConfig(bits=bits), use_corpus_char_freq=True,
        mesh=mesh,
    )
    assert_indexes_byte_identical(idx, single_host[bits])
    assert stats.n_shards == n_devices
    assert stats.values_total == len(corpus.unique_values)
    assert stats.bytes_hashed == corpus.unique_enc.size
    assert sum(stats.shard_values) == stats.values_total
    assert sum(stats.shard_rows) == corpus.total_rows
    # one device falls back to the single-host pass (no mesh accounting)
    assert (stats.mesh_shape is None) == (n_devices == 1)
    assert stats.sharded == (n_devices > 1)


@needs_8_devices
@pytest.mark.parametrize("bits", WIDTHS)
def test_mesh_built_session_discovery_identical(lake, single_host, bits):
    """Downstream top-k parity: a sharded-built session's discover AND
    discover_many match the single-host index bit-for-bit."""
    corpus, query, q_cols = lake
    mesh = meshlib.make_mesh((max(DEVICE_COUNTS),), ("data",))
    session = MateSession.build(corpus, DiscoveryConfig(bits=bits), mesh=mesh)
    assert session.build_stats is not None and session.build_stats.sharded
    ref, _ = discovery.discover(single_host[bits], query, q_cols, k=10)
    got, _ = session.discover(query, q_cols, k=10)
    key = lambda es: [(e.table_id, e.joinability, e.mapping) for e in es]
    assert key(got) == key(ref)
    queries = [(query, q_cols)] + synthetic.make_mixed_queries(
        corpus, 2, 10, 2, seed=11
    )
    out = session.discover_many(queries, k=[10, 4, 4])
    for (q, qc), k_i, (entries, _st) in zip(queries, [10, 4, 4], out):
        ref_i, _ = discovery.discover(single_host[bits], q, qc, k=k_i)
        assert key(entries) == key(ref_i)


@needs_8_devices
def test_session_build_mesh_matches_session_build_host(lake):
    """MateSession.build with and without a mesh agree artifact-for-artifact
    (the session surface, not just the raw builder)."""
    corpus, _q, _qc = lake
    mesh = meshlib.make_mesh((4,), ("data",))
    s_mesh = MateSession.build(corpus, DiscoveryConfig(bits=256), mesh=mesh)
    s_host = MateSession.build(corpus, DiscoveryConfig(bits=256))
    assert_indexes_byte_identical(s_mesh.index, s_host.index)
    assert s_host.build_stats is not None and not s_host.build_stats.sharded


# ---------------------------------------------------------------------------
# Host-sharded merge (runs on ONE device in every CI leg)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_shards", [1, 2, 3, 5, 8])
def test_host_sharded_build_byte_identical(lake, single_host, n_shards):
    corpus, _q, _qc = lake
    idx, stats = build_index(
        corpus, cfg=xash.XashConfig(bits=128), use_corpus_char_freq=True,
        n_shards=n_shards,
    )
    assert_indexes_byte_identical(idx, single_host[128])
    assert stats.n_shards == n_shards and stats.mesh_shape is None


def test_merge_matches_single_host_csr(lake):
    """merge_shard_postings over contiguous row shards == the one-shard CSR
    (payload AND ptr), for uneven shard splits."""
    corpus, _q, _qc = lake
    n_values = len(corpus.unique_values)
    payload_ref, counts_ref = _shard_postings(
        corpus.cell_value_ids, 0, corpus.total_rows, n_values
    )
    ptr_ref = _csr_ptr(counts_ref)
    bounds = [0, 7, 7, 100, corpus.total_rows]  # uneven + one empty shard
    parts = [
        _shard_postings(corpus.cell_value_ids, lo, hi, n_values)
        for lo, hi in zip(bounds, bounds[1:])
    ]
    payload, ptr = merge_shard_postings(
        [p for p, _ in parts], [c for _, c in parts], n_values
    )
    assert np.array_equal(ptr, ptr_ref)
    assert np.array_equal(payload, payload_ref)


def test_mesh_n_shards_conflict_raises(lake):
    corpus, _q, _qc = lake
    mesh = meshlib.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="n_shards"):
        build_index(corpus, mesh=mesh, n_shards=3)


def test_sharded_build_baseline_hash(lake, single_host):
    """Non-xash hashes (host-side Python) shard over the same bounds and
    merge identically — the fallback path under any mesh."""
    corpus, _q, _qc = lake
    ref = MateIndex(corpus, cfg=xash.XashConfig(bits=128), hash_name="murmur")
    idx, stats = build_index(
        corpus, cfg=xash.XashConfig(bits=128), hash_name="murmur", n_shards=3
    )
    assert np.array_equal(idx.value_lanes, ref.value_lanes)
    assert np.array_equal(idx.superkeys, ref.superkeys)
    assert len(stats.shard_hash_seconds) == 3


# ---------------------------------------------------------------------------
# §5.4 mutations compose with a sharded-built index
# ---------------------------------------------------------------------------


def _assert_same_index_state(idx: MateIndex, rebuilt: MateIndex):
    assert np.array_equal(idx.superkeys, rebuilt.superkeys)
    for value in rebuilt.corpus.value_of:
        got = sorted(map(tuple, idx.fetch_postings(value).tolist()))
        want = sorted(map(tuple, rebuilt.fetch_postings(value).tolist()))
        assert got == want, value


def test_mutations_on_sharded_built_index():
    """insert_table / update_cell on a sharded-built index behave exactly
    like on a from-scratch rebuild (test_index.py's rebuild-consistency
    contract).  Fresh corpus: §5.4 updates mutate it in place."""
    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=60, seed=1))
    query, q_cols, _expected, corpus = synthetic.make_query_with_ground_truth(
        corpus
    )
    idx, _ = build_index(
        corpus, cfg=xash.XashConfig(bits=128), use_corpus_char_freq=True,
        n_shards=4,
    )
    key_cells = [
        [query.cells[r][c] for c in q_cols] for r in range(query.n_rows)
    ]
    new_cells = [kc + ["sharded-extra"] for kc in key_cells]
    tid = idx.insert_table(new_cells)
    idx.update_cell(tid, 0, len(new_cells[0]) - 1, "mutated")
    mutated = [list(r) for r in new_cells]
    mutated[0][-1] = "mutated"
    rebuilt = MateIndex(
        Corpus([*corpus.tables[:-1], Table(tid, mutated)]),
        cfg=idx.cfg,
    )
    _assert_same_index_state(idx, rebuilt)
    # and the engines still agree post-mutation
    seq, _ = discovery.discover(idx, query, q_cols, k=8)
    ses = MateSession(idx, DiscoveryConfig())
    got, _ = ses.discover(query, q_cols, k=8)
    assert [(e.table_id, e.joinability, e.mapping) for e in got] == [
        (e.table_id, e.joinability, e.mapping) for e in seq
    ]
    assert tid in [e.table_id for e in got]


# ---------------------------------------------------------------------------
# Property tests: hypothesis corpora (skewed / duplicate / empty columns)
# ---------------------------------------------------------------------------

# small value pool → heavy duplication across tables (skewed posting lists);
# includes the empty string (hashes to zero lanes) and multi-char values
_POOL = ["", "a", "aa", "b", "zz9", "same", "same", "x y", "0", "long value 42"]

if HAVE_HYPOTHESIS:
    cell_strat = st.sampled_from(_POOL)
    table_strat = st.integers(min_value=1, max_value=3).flatmap(
        lambda n_cols: st.lists(
            st.lists(cell_strat, min_size=n_cols, max_size=n_cols),
            min_size=0,
            max_size=6,
        )
    )
    corpus_strat = st.lists(table_strat, min_size=1, max_size=4)
else:  # pragma: no cover — given/settings degrade to skip markers above
    cell_strat = corpus_strat = None


def _corpus_from(tables_cells) -> Corpus:
    return Corpus(
        [Table(i, cells) for i, cells in enumerate(tables_cells)]
    )


@settings(max_examples=40, deadline=None)
@given(tables_cells=corpus_strat, n_shards=st.integers(min_value=1, max_value=6))
def test_property_shard_merge_matches_single_host(tables_cells, n_shards):
    """Hypothesis corpora (duplicate values, empty strings/columns, ragged
    widths, zero-row tables): shard-merge == single-host
    ``_hash_unique_values`` + postings at any shard count."""
    corpus = _corpus_from(tables_cells)
    cfg = xash.XashConfig(bits=128)
    ref = MateIndex(corpus, cfg=cfg)
    idx, _stats = build_index(corpus, cfg=cfg, n_shards=n_shards)
    want = _hash_unique_values(
        corpus.unique_values, corpus.unique_enc, ref.cfg, "xash",
        corpus.avg_row_width(),
    )
    assert np.array_equal(idx.value_lanes, want)
    assert_indexes_byte_identical(idx, ref)


@settings(max_examples=25, deadline=None)
@given(
    tables_cells=corpus_strat,
    extra=st.lists(
        st.lists(cell_strat, min_size=2, max_size=2), min_size=1, max_size=4
    ),
)
def test_property_add_rows_then_rebuild_consistency(tables_cells, extra):
    """§5.4 on sharded-built indexes: adding a table and then comparing with
    a from-scratch rebuild holds for generated corpora too."""
    corpus = _corpus_from(tables_cells)
    idx, _ = build_index(corpus, cfg=xash.XashConfig(bits=128), n_shards=3)
    tid = idx.insert_table(extra)
    rebuilt = MateIndex(
        Corpus([*corpus.tables[:-1], Table(tid, extra)]), cfg=idx.cfg
    )
    _assert_same_index_state(idx, rebuilt)
