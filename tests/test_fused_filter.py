"""Fused filter+segment-count kernel: bit-identical counts vs the composed
oracles, CSR edge shapes, and engine top-k identity on the counts-only path.

The fused kernel (``filter_kernel.filter_table_counts``) must reproduce the
composed pipeline (subsumption matrix ∧ eligibility → row sum → segment sum)
EXACTLY at every hash width — counts are integral, so equality is exact.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import discovery, xash
from repro.core.batched import discover_batched, discover_many
from repro.core.session import DiscoveryConfig
from repro.core.index import MateIndex
from repro.data import synthetic
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


def _rand_sks(n, lanes, dense_frac=0.1):
    """Random superkeys with a dense (all-ones) head so some rows subsume."""
    sk = RNG.integers(0, 2**32, size=(n, lanes), dtype=np.uint32)
    sk[: max(1, int(n * dense_frac))] = 0xFFFFFFFF
    return sk


def _oracle_counts(row_sk, q_sk, elig, seg, n_tables):
    hits = ops.subsume_np(row_sk, q_sk) & elig
    return np.bincount(
        seg, weights=hits.sum(axis=1), minlength=n_tables
    ).astype(np.int32)


@pytest.mark.parametrize("bits", [128, 256, 512])
@pytest.mark.parametrize("n,q,n_tables", [
    (700, 23, 19),    # non-pow2 everything
    (1030, 70, 13),   # row count crossing the 1024 block boundary
    (257, 5, 1),      # single-table CSR block
    (64, 3, 5),       # tiny block below every bucket minimum
])
def test_fused_counts_match_composed_oracles(bits, n, q, n_tables):
    """Fused kernel == numpy oracle == XLA `_per_table_counts` composition,
    bit-identically, at 4/8/16 lanes on non-pow2 CSR shapes."""
    lanes = xash.XashConfig(bits=bits).lanes
    row_sk = _rand_sks(n, lanes)
    q_sk = RNG.integers(0, 2**32, size=(q, lanes), dtype=np.uint32)
    q_sk[0] = 0  # zero (empty-key) query subsumes everything
    elig = RNG.random((n, q)) < 0.6
    seg = np.sort(RNG.integers(0, n_tables, size=n)).astype(np.int32)
    want = _oracle_counts(row_sk, q_sk, elig, seg, n_tables)
    got = ops.filter_table_counts(row_sk, q_sk, elig, seg, n_tables)
    assert np.array_equal(got, want), (bits, n, q, n_tables)
    # composed XLA reduction the kernel replaces (jit'd _per_table_counts)
    hits = jnp.asarray(ops.subsume_np(row_sk, q_sk) & elig)
    composed = np.asarray(
        ops._per_table_counts(hits, jnp.asarray(seg), n_tables)
    )
    assert np.array_equal(got, composed)


@pytest.mark.parametrize("bits", [128, 256, 512])
def test_fused_dispatch_returns_counts_only(bits):
    """`filter_hits_table_counts(backend='fused')` returns hits=None (the
    matrix was never produced) and oracle-identical counts at every width."""
    lanes = xash.XashConfig(bits=bits).lanes
    n, q, n_tables = 420, 17, 7
    row_sk = _rand_sks(n, lanes)
    q_sk = RNG.integers(0, 2**32, size=(q, lanes), dtype=np.uint32)
    elig = RNG.random((n, q)) < 0.5
    seg = np.sort(RNG.integers(0, n_tables, size=n)).astype(np.int32)
    hits, counts = ops.filter_hits_table_counts(
        row_sk, q_sk, elig, seg, n_tables, backend="fused"
    )
    assert hits is None
    assert np.array_equal(counts, _oracle_counts(row_sk, q_sk, elig, seg, n_tables))


def test_fused_env_backend_dispatch(monkeypatch):
    """MATE_FILTER_BACKEND=fused routes the default dispatch to the fused
    kernel (the CI `pallas-interpret-fused` leg's contract)."""
    monkeypatch.setenv("MATE_FILTER_BACKEND", "fused")
    assert ops.fused_filter_default()
    n, q, n_tables = 300, 9, 4
    row_sk = _rand_sks(n, 4)
    q_sk = RNG.integers(0, 2**32, size=(q, 4), dtype=np.uint32)
    elig = np.ones((n, q), dtype=bool)
    seg = np.sort(RNG.integers(0, n_tables, size=n)).astype(np.int32)
    hits, counts = ops.filter_hits_table_counts(row_sk, q_sk, elig, seg, n_tables)
    assert hits is None
    assert np.array_equal(counts, _oracle_counts(row_sk, q_sk, elig, seg, n_tables))


def test_fused_zero_query_and_empty_blocks():
    """Zero queries / zero rows / zero tables short-circuit; an all-false
    eligibility (fully pruned batch) yields all-zero counts."""
    row_sk = _rand_sks(100, 4)
    q_sk = np.zeros((0, 4), dtype=np.uint32)
    assert np.array_equal(
        ops.filter_table_counts(row_sk, q_sk, np.zeros((100, 0), bool),
                                np.zeros(100, np.int32), 5),
        np.zeros(5, np.int32),
    )
    assert ops.filter_table_counts(
        np.zeros((0, 4), np.uint32), _rand_sks(3, 4), np.zeros((0, 3), bool),
        np.zeros(0, np.int32), 5,
    ).tolist() == [0] * 5
    assert ops.filter_table_counts(
        row_sk, _rand_sks(3, 4), np.zeros((100, 3), bool),
        np.zeros(100, np.int32), 0,
    ).shape == (0,)
    # all-pruned: every (row, key) pair ineligible
    counts = ops.filter_table_counts(
        row_sk, np.zeros((3, 4), np.uint32), np.zeros((100, 3), bool),
        np.sort(RNG.integers(0, 5, 100)).astype(np.int32), 5,
    )
    assert np.array_equal(counts, np.zeros(5, np.int32))


def test_fused_counts_large_table_counts():
    """Regression: when the VMEM budget shrinks block_n (tb > 1024), the
    block size must still divide the padded row count — a non-divisor grid
    silently drops trailing rows.  Also pins the >cap composed fallback."""
    from repro.kernels import filter_kernel

    n, q, n_tables = 8192, 64, 1100  # tb=1152 → budget block_n < 1024
    row_sk = _rand_sks(n, 4)
    q_sk = RNG.integers(0, 2**32, size=(q, 4), dtype=np.uint32)
    elig = RNG.random((n, q)) < 0.5
    seg = np.sort(RNG.integers(0, n_tables, size=n)).astype(np.int32)
    want = _oracle_counts(row_sk, q_sk, elig, seg, n_tables)
    got = ops.filter_table_counts(row_sk, q_sk, elig, seg, n_tables)
    assert np.array_equal(got, want)
    # block helper: always a power of two in [128, 1024], within budget
    for tb in (128, 1024, 1152, 4096, 8192):
        b = filter_kernel.fused_block_n(tb)
        assert b & (b - 1) == 0 and 128 <= b <= 1024
        assert b == 128 or b * tb <= filter_kernel.FUSED_ONEHOT_BUDGET
    # above the cap the dispatch must fall back (hits non-None, same counts)
    big = filter_kernel.FUSED_MAX_TABLES + 1
    seg_big = np.sort(RNG.integers(0, big, size=300)).astype(np.int32)
    hits, counts = ops.filter_hits_table_counts(
        row_sk[:300], q_sk[:5], elig[:300, :5], seg_big, big, backend="fused"
    )
    assert hits is not None
    assert np.array_equal(
        counts, _oracle_counts(row_sk[:300], q_sk[:5], elig[:300, :5], seg_big, big)
    )


def test_fused_saturated_rows_ignore_padded_queries():
    """Regression: a saturated (all-ones) row super key subsumes the all-ones
    PADDED query columns too — without an eligibility mask (elig=None) those
    phantom columns must still contribute nothing, in both modes."""
    n, q, n_tables = 10, 5, 2  # q pads to 64: 59 phantom columns
    row_sk = RNG.integers(0, 2**32, size=(n, 4), dtype=np.uint32)
    row_sk[0] = 0xFFFFFFFF  # saturated row
    q_sk = RNG.integers(0, 2**32, size=(q, 4), dtype=np.uint32)
    seg = np.sort(RNG.integers(0, n_tables, size=n)).astype(np.int32)
    match = ops.subsume_np(row_sk, q_sk)
    want_sum = np.bincount(seg, weights=match.sum(1), minlength=n_tables)
    got_sum = ops.filter_table_counts(row_sk, q_sk, None, seg, n_tables)
    assert np.array_equal(got_sum, want_sum.astype(np.int32))
    want_any = np.bincount(seg, weights=match.any(1), minlength=n_tables)
    got_any = ops.filter_table_counts(
        row_sk, q_sk, None, seg, n_tables, mode="any"
    )
    assert np.array_equal(got_any, want_any.astype(np.int32))


def test_fused_false_pins_composed_path(lake, monkeypatch):
    """Regression: an explicit composed backend must stick even when the
    env/TPU default dispatch is fused — the composed path materialises the
    matrix (matrix_bytes > 0) and reports zero fused launches.  (The legacy
    fused=False spelling of this pin is covered in test_session.)"""
    corpus, index, query, q_cols = lake
    monkeypatch.setenv("MATE_FILTER_BACKEND", "fused")
    seq, _ = discovery.discover(index, query, q_cols, k=10)
    bat, st = discover_batched(index, query, q_cols, k=10, backend="pallas")
    assert [(e.table_id, e.joinability) for e in bat] == [
        (e.table_id, e.joinability) for e in seq
    ]
    assert st.filter_fused_launches == 0
    assert st.filter_matrix_bytes > 0


def test_fused_table_cap_fallback_accounting(lake, monkeypatch):
    """Regression: when ops falls back to the composed path above the table
    cap, engine stats must NOT claim the counts-only contract."""
    corpus, index, query, q_cols = lake
    monkeypatch.setattr(ops, "_FUSED_MAX_TABLES", 4)  # force the fallback
    seq, _ = discovery.discover(index, query, q_cols, k=10)
    bat, st = discover_batched(index, query, q_cols, k=10, backend="fused")
    assert [(e.table_id, e.joinability) for e in bat] == [
        (e.table_id, e.joinability) for e in seq
    ]
    assert st.filter_fused_launches == 0
    assert st.filter_matrix_bytes > 0


def test_fused_mode_any_matches_distributed_semantics():
    """mode='any' (rows with ≥1 hit per table) == the distributed filter's
    per-table reduction, including -1 padding rows and elig=None."""
    n, q, n_tables = 500, 11, 9
    row_sk = _rand_sks(n, 4)
    q_sk = RNG.integers(0, 2**32, size=(q, 4), dtype=np.uint32)
    seg = RNG.integers(0, n_tables, size=n).astype(np.int32)
    seg[-7:] = -1  # padding rows must scatter nowhere
    got = ops.filter_table_counts(row_sk, q_sk, None, seg, n_tables, mode="any")
    match = ops.subsume_np(row_sk, q_sk) & (seg >= 0)[:, None]
    want = np.bincount(
        seg[seg >= 0], weights=match.any(axis=1)[seg >= 0], minlength=n_tables
    ).astype(np.int32)
    assert np.array_equal(got, want)


@pytest.fixture(scope="module")
def lake():
    spec = synthetic.SyntheticSpec(n_tables=150, seed=0)
    corpus = synthetic.make_corpus(spec)
    query, q_cols, expected, corpus = synthetic.make_query_with_ground_truth(corpus)
    index = MateIndex(corpus)
    return corpus, index, query, q_cols


def test_fused_engine_topk_bit_identical(lake):
    """Engine acceptance: the fused counts-only path returns the same top-k
    (ids, scores, mappings) as scalar Algorithm 1, with ZERO match-matrix
    bytes and small batch sizes exercising multi-batch fused launches."""
    corpus, index, query, q_cols = lake
    seq, _ = discovery.discover(index, query, q_cols, k=10)
    want = [(e.table_id, e.joinability, e.mapping) for e in seq]
    for batch_tables in (7, 64, 256):
        bat, st = discover_batched(
            index, query, q_cols, k=10, batch_tables=batch_tables, backend="fused"
        )
        assert [(e.table_id, e.joinability, e.mapping) for e in bat] == want
        assert st.filter_matrix_bytes == 0
        assert st.filter_fused_launches > 0
        assert st.readback_frac == 0.0  # no matrix → frac defined as 0


def test_fused_discover_many_and_engine(lake):
    """Group (discover_many) and serving (DiscoveryEngine) fused paths are
    bit-identical to per-query discovery with counts-only group launches."""
    from repro.serve.engine import DiscoveryEngine

    corpus, index, query, q_cols = lake
    queries = [(query, q_cols)] + synthetic.make_mixed_queries(
        corpus, 2, 12, 2, seed=21
    )
    out = discover_many(index, queries, k=[10, 3, 5], backend="fused")
    for (q, qc), k_i, (entries, st) in zip(queries, [10, 3, 5], out):
        seq, _ = discovery.discover(index, q, qc, k=k_i)
        assert [(e.table_id, e.joinability, e.mapping) for e in seq] == [
            (e.table_id, e.joinability, e.mapping) for e in entries
        ]
        assert st.filter_matrix_bytes == 0
        assert st.filter_fused_launches == 1
    engine = DiscoveryEngine(
        index, batch=2, config=DiscoveryConfig(backend="fused")
    )
    reqs = [engine.submit(q, qc, k=5) for q, qc in queries]
    engine.flush()
    for (q, qc), r in zip(queries, reqs):
        seq, _ = discovery.discover(index, q, qc, k=5)
        # the session defaults to rank='quality' (ISSUE 9) which reorders
        # the heap without changing membership — compare the SET here; the
        # exact-order fused contract is pinned above at rank='count'
        assert sorted((e.table_id, e.joinability) for e in r.results) == sorted(
            (e.table_id, e.joinability) for e in seq
        )
        assert r.stats.filter_matrix_bytes == 0


@pytest.mark.parametrize("bits", [128, 512])
def test_fused_engine_topk_across_widths(lake, bits):
    """Width sweep on the fused path: a 512-bit (16-lane) index runs the same
    fused kernel and still matches the scalar scan exactly."""
    corpus, _index, query, q_cols = lake
    index = MateIndex(corpus, cfg=xash.XashConfig(bits=bits))
    seq, _ = discovery.discover(index, query, q_cols, k=10)
    bat, st = discover_batched(index, query, q_cols, k=10, backend="fused")
    assert [(e.table_id, e.joinability, e.mapping) for e in bat] == [
        (e.table_id, e.joinability, e.mapping) for e in seq
    ]
    assert st.filter_matrix_bytes == 0


def test_fused_distributed_filter_matches_broadcast():
    """impl='fused' sharded filter == the broadcast baseline (table and key
    counts), through shard_map + the interpret-mode Pallas launch."""
    import jax

    from repro.core import distributed

    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=60, seed=1))
    idx = MateIndex(corpus)
    queries = synthetic.make_mixed_queries(corpus, 1, 10, 2, seed=2)
    q, q_cols = queries[0]
    _keys, sk_of_key = discovery.build_query_superkeys(idx, q, q_cols)
    qsk = np.stack(list(sk_of_key.values()))
    row_tables = np.asarray(
        corpus.table_of_row(np.arange(corpus.total_rows)), dtype=np.int32
    )
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    sk, rt = distributed.shard_corpus_rows(
        idx.superkeys, row_tables, mesh, ("data",)
    )
    fn = distributed.make_distributed_filter(
        mesh, len(corpus.tables), ("data",), backend="fused"
    )
    tc, kc = fn(sk, rt, qsk)
    tc_ref, kc_ref = distributed.filter_counts_local(
        idx.superkeys, row_tables, qsk, len(corpus.tables)
    )
    assert np.array_equal(np.asarray(tc), np.asarray(tc_ref))
    assert np.array_equal(np.asarray(kc), np.asarray(kc_ref))


def test_fused_counts_from_real_superkeys():
    """End-to-end hash path: XASH superkeys (not random bits) through the
    fused kernel vs the materialised filter_match reduction."""
    cfg = xash.DEFAULT_CONFIG
    enc_r = RNG.integers(0, 38, size=(600, 5, 32)).astype(np.uint8)
    enc_q = RNG.integers(0, 38, size=(31, 2, 32)).astype(np.uint8)
    row_sk = np.asarray(ref.xash_superkey_ref(jnp.asarray(enc_r), cfg))
    q_sk = np.asarray(ref.xash_superkey_ref(jnp.asarray(enc_q), cfg))
    elig = RNG.random((600, 31)) < 0.8
    seg = np.sort(RNG.integers(0, 11, 600)).astype(np.int32)
    got = ops.filter_table_counts(row_sk, q_sk, elig, seg, 11)
    match = np.asarray(ops.filter_match(row_sk, q_sk)) & elig
    want = np.bincount(seg, weights=match.sum(1), minlength=11).astype(np.int32)
    assert np.array_equal(got, want)
