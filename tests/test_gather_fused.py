"""Gather-fused filter kernel: one launch from posting lists to counts.

The gather-fused path (``backend='fused-gather'``) must be BIT-IDENTICAL to
the host-gather composed path (``MateIndex.superkey_of_rows`` →
``ops.filter_table_counts``) at every hash width — per-table counts AND the
downstream top-k — while never gathering candidate superkeys on the host.
This suite pins that equivalence over the CSR edge shapes the serving tier
produces (empty posting lists, one-table blocks, all-tables-deleted,
zero-query plans) and across §5.4 mutations, where the device-resident
superkey store must refresh on every mutation-epoch bump.
"""

import numpy as np
import pytest

import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # property tests skip; unit tests still run
    HAVE_HYPOTHESIS = False

    def _skip_decorator(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    given = settings = _skip_decorator

    class _StrategyStub:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

from repro.core import discovery, xash
from repro.core.batched import discover_batched, discover_many, plan_and_count, score_from_counts
from repro.core.index import MateIndex
from repro.core.session import DiscoveryConfig, MateSession
from repro.data import synthetic
from repro.kernels import ops

RNG = np.random.default_rng(17)
ALL_BITS = (128, 256, 512)


def _oracle_counts(row_sk, q_sk, elig, seg, n_tables):
    hits = ops.subsume_np(row_sk, q_sk)
    if elig is not None:
        hits = hits & elig
    return np.bincount(
        np.asarray(seg)[np.asarray(seg) >= 0],
        weights=hits.sum(axis=1)[np.asarray(seg) >= 0],
        minlength=n_tables,
    ).astype(np.int32)


def _rand_case(lanes, n, q, n_tables, n_store=4096, seed=0):
    rng = np.random.default_rng(seed)
    store = rng.integers(0, 2**32, size=(n_store, lanes), dtype=np.uint32)
    rows = rng.integers(0, n_store, size=n).astype(np.int64)
    q_sk = rng.integers(0, 2**32, size=(q, lanes), dtype=np.uint32)
    # plant subsuming pairs so counts aren't trivially zero
    for k in range(0, q, 3):
        q_sk[k] = store[rows[k % max(n, 1)]] & rng.integers(
            0, 2**32, size=lanes, dtype=np.uint32
        )
    elig = rng.random((n, q)) < 0.7
    seg = np.sort(rng.integers(0, n_tables, size=n)).astype(np.int32)
    return store, rows, q_sk, elig, seg


# ---------------------------------------------------------------------------
# Kernel/ops-level bit-identity vs the host-gather composed launch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", ALL_BITS)
@pytest.mark.parametrize("n,q,n_tables", [
    (700, 23, 19),    # non-pow2 everything
    (1030, 70, 13),   # row count crossing the 1024 block boundary
    (257, 5, 1),      # single-table CSR block
    (64, 3, 5),       # tiny block below every bucket minimum
])
def test_gather_counts_match_host_gather(bits, n, q, n_tables):
    lanes = xash.XashConfig(bits=bits).lanes
    store, rows, q_sk, elig, seg = _rand_case(lanes, n, q, n_tables, seed=bits + n)
    composed = ops.filter_table_counts(store[rows], q_sk, elig, seg, n_tables)
    gathered = ops.gather_filter_table_counts(
        jnp.asarray(store), rows, q_sk, elig, seg, n_tables
    )
    assert np.array_equal(gathered, composed), (bits, n, q, n_tables)
    assert np.array_equal(
        gathered, _oracle_counts(store[rows], q_sk, elig, seg, n_tables)
    )


@pytest.mark.parametrize("bits", ALL_BITS)
def test_gather_dispatch_counts_only_no_host_superkeys(bits):
    """The fused-gather dispatch accepts row_sk=None — the host never gathers
    — and returns hits=None with composed-identical counts."""
    lanes = xash.XashConfig(bits=bits).lanes
    store, rows, q_sk, elig, seg = _rand_case(lanes, 420, 17, 7, seed=bits)
    hits, counts = ops.filter_hits_table_counts(
        None, q_sk, elig, seg, 7, backend="fused-gather",
        store=jnp.asarray(store), rows=rows,
    )
    assert hits is None
    want = ops.filter_table_counts(store[rows], q_sk, elig, seg, 7)
    assert np.array_equal(counts, want)


def test_gather_lane_prefix_degrade_over_full_width_store():
    """The serving tier's degrade path probes a lane PREFIX of the query
    keys against the full-width device store — counts must equal the
    composed launch over prefix-sliced host-gathered superkeys."""
    store, rows, q_sk16, elig, seg = _rand_case(16, 900, 31, 11, seed=3)
    for probe_lanes in (4, 8, 16):
        q_sk = q_sk16[:, :probe_lanes]
        composed = ops.filter_table_counts(
            store[rows][:, :probe_lanes], q_sk, elig, seg, 11
        )
        gathered = ops.gather_filter_table_counts(
            jnp.asarray(store), rows, q_sk, elig, seg, 11
        )
        assert np.array_equal(gathered, composed), probe_lanes


def test_gather_zero_shapes_short_circuit():
    store = jnp.asarray(RNG.integers(0, 2**32, size=(64, 4), dtype=np.uint32))
    zq = np.zeros((0, 4), dtype=np.uint32)
    assert ops.gather_filter_table_counts(
        store, np.zeros(0, np.int64), zq, None, np.zeros(0, np.int32), 5
    ).tolist() == [0] * 5
    assert ops.gather_filter_table_counts(
        store, np.arange(10), zq, None, np.zeros(10, np.int32), 5
    ).tolist() == [0] * 5
    assert ops.gather_filter_table_counts(
        store, np.arange(10), RNG.integers(0, 2**32, size=(3, 4), dtype=np.uint32),
        None, np.zeros(10, np.int32), 0,
    ).shape == (0,)


def test_gather_table_cap_raises_on_direct_call():
    store = jnp.asarray(RNG.integers(0, 2**32, size=(64, 4), dtype=np.uint32))
    big = ops._FUSED_MAX_TABLES + 1
    with pytest.raises(ValueError, match="at most"):
        ops.gather_filter_table_counts(
            store, np.arange(10), RNG.integers(0, 2**32, size=(3, 4), dtype=np.uint32),
            None, np.zeros(10, np.int32), big,
        )


# ---------------------------------------------------------------------------
# Engine-level: CSR edge shapes, bit-identical top-k, accounting
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lake():
    spec = synthetic.SyntheticSpec(n_tables=150, seed=0)
    corpus = synthetic.make_corpus(spec)
    query, q_cols, _expected, corpus = synthetic.make_query_with_ground_truth(corpus)
    return corpus, query, q_cols


@pytest.mark.parametrize("bits", ALL_BITS)
def test_gather_engine_topk_bit_identical(lake, bits):
    """discover_batched(backend='fused-gather') == scalar Algorithm 1 at
    every width, with zero matrix bytes and positive gather savings."""
    corpus, query, q_cols = lake
    index = MateIndex(corpus, cfg=xash.XashConfig(bits=bits))
    seq, _ = discovery.discover(index, query, q_cols, k=10)
    for batch_tables in (7, 256):
        bat, st = discover_batched(
            index, query, q_cols, k=10, batch_tables=batch_tables,
            backend="fused-gather",
        )
        assert [(e.table_id, e.joinability, e.mapping) for e in bat] == [
            (e.table_id, e.joinability, e.mapping) for e in seq
        ]
        assert st.filter_matrix_bytes == 0
        assert st.filter_fused_launches > 0
        # every launch saved n × (lanes·4 − 4) bytes of host gather traffic
        assert st.gather_bytes_saved > 0


def test_gather_discover_many_and_two_phase(lake):
    """Group launch (plan_and_count → score_from_counts) on the gather path:
    bit-identical to per-query discovery; PlanCounts carries no host
    superkeys (row_sk None) and replays from the index store."""
    corpus, query, q_cols = lake
    index = MateIndex(corpus)
    queries = [(query, q_cols)] + synthetic.make_mixed_queries(
        corpus, 2, 12, 2, seed=21
    )
    out = discover_many(index, queries, k=[10, 3, 5], backend="fused-gather")
    for (q, qc), k_i, (entries, st) in zip(queries, [10, 3, 5], out):
        seq, _ = discovery.discover(index, q, qc, k=k_i)
        assert [(e.table_id, e.joinability, e.mapping) for e in seq] == [
            (e.table_id, e.joinability, e.mapping) for e in entries
        ]
        assert st.filter_matrix_bytes == 0
        assert st.filter_fused_launches == 1
        assert st.gather_bytes_saved > 0
    pcs = plan_and_count(index, queries, "fused-gather")
    for pc, ((q, qc), (want, _)) in zip(pcs, zip(queries, out)):
        assert pc.row_sk is None and pc.fused
        assert pc.gather_saved == pc.plan.block.n_items * (index.cfg.lanes * 4 - 4)
        got, st = score_from_counts(index, pc, k=10)
        ref, _ = discovery.discover(index, q, qc, k=10)
        assert [(e.table_id, e.joinability) for e in got] == [
            (e.table_id, e.joinability) for e in ref
        ]
        # cached replay: scoring again from the cacheable copy stays identical
        got2, st2 = score_from_counts(index, pc.cacheable(), k=10, from_cache=True)
        assert [(e.table_id, e.joinability) for e in got2] == [
            (e.table_id, e.joinability) for e in got
        ]
        assert st2.gather_bytes_saved == 0  # an earlier request paid the launch


def test_gather_empty_posting_lists(lake):
    """A query whose init-column values miss the index entirely: empty CSR
    block, zero launches, empty top-k — identical to the scalar engine."""
    corpus, _query, _q_cols = lake
    index = MateIndex(corpus)
    ghost = synthetic.Table(-1, [["zzznope", "zzznope2"]] * 3)
    seq, _ = discovery.discover(index, ghost, [0, 1], k=5)
    bat, st = discover_batched(index, ghost, [0, 1], k=5, backend="fused-gather")
    assert [(e.table_id, e.joinability) for e in bat] == [
        (e.table_id, e.joinability) for e in seq
    ]
    assert st.gather_bytes_saved == 0  # nothing to gather, nothing saved


def test_gather_all_candidates_one_table():
    """CSR block with a single candidate table (one-table corpus)."""
    cells = [[f"k{r}", f"v{r % 3}", "common"] for r in range(9)]
    corpus = synthetic.Corpus([synthetic.Table(0, cells)])
    index = MateIndex(corpus)
    query = synthetic.Table(-1, [[f"k{r}", f"v{r % 3}"] for r in range(5)])
    seq, _ = discovery.discover(index, query, [0, 1], k=3)
    bat, st = discover_batched(index, query, [0, 1], k=3, backend="fused-gather")
    assert [(e.table_id, e.joinability, e.mapping) for e in bat] == [
        (e.table_id, e.joinability, e.mapping) for e in seq
    ]
    assert st.filter_fused_launches == 1


def test_gather_all_tables_deleted(lake):
    """Every candidate table tombstoned: fetch_postings filters everything,
    the CSR block is empty, and the gather path returns an empty top-k."""
    corpus, query, q_cols = lake
    index = MateIndex(corpus)
    ref, _ = discover_batched(index, query, q_cols, k=5, backend="fused-gather")
    assert ref  # sanity: undeleted lake finds joinable tables
    for t in range(len(corpus.tables)):
        index.delete_table(t)
    got, st = discover_batched(index, query, q_cols, k=5, backend="fused-gather")
    assert got == []
    assert st.gather_bytes_saved == 0
    seq, _ = discovery.discover(index, query, q_cols, k=5)
    assert seq == []


def test_gather_zero_query_plan_is_safe():
    """plan_and_count([]) and a zero-row query table short-circuit."""
    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=20, seed=4))
    index = MateIndex(corpus)
    assert plan_and_count(index, [], "fused-gather") == []


# ---------------------------------------------------------------------------
# §5.4 mutations: the device store must refresh on every epoch bump
# ---------------------------------------------------------------------------

def test_device_store_refreshes_on_epoch_bump():
    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=30, seed=8))
    index = MateIndex(corpus)
    s0 = index.device_store()
    assert s0 is index.device_store()  # cached within an epoch
    assert np.array_equal(np.asarray(s0), index.superkeys)
    index.delete_table(0)  # in-place zeroing + epoch bump
    s1 = index.device_store()
    assert s1 is not s0
    assert np.array_equal(np.asarray(s1), index.superkeys)
    assert np.asarray(s1)[: int(corpus.row_base[1])].sum() == 0
    index.update_cell(1, 0, 0, "mutated-value")  # in-place row rewrite
    s2 = index.device_store()
    assert s2 is not s1
    assert np.array_equal(np.asarray(s2), index.superkeys)
    tid = index.insert_table([["a", "b"], ["c", "d"]])
    s3 = index.device_store()
    assert s3.shape[0] == index.superkeys.shape[0] > s2.shape[0]
    assert np.array_equal(np.asarray(s3), index.superkeys)
    assert tid == len(index.corpus.tables) - 1


def test_gather_bit_identical_across_mutations(lake):
    """Insert/update/delete between launches: the gather path must keep
    matching the scalar engine after every §5.4 mutation (stale device
    stores would poison the filter silently)."""
    corpus, query, q_cols = lake
    index = MateIndex(corpus)

    def check():
        seq, _ = discovery.discover(index, query, q_cols, k=8)
        bat, st = discover_batched(index, query, q_cols, k=8, backend="fused-gather")
        assert [(e.table_id, e.joinability, e.mapping) for e in bat] == [
            (e.table_id, e.joinability, e.mapping) for e in seq
        ]
        return seq

    check()
    key_cells = [[query.cells[r][c] for c in q_cols] for r in range(query.n_rows)]
    tid = index.insert_table([kc + ["extra"] for kc in key_cells])
    seq = check()
    assert tid in [e.table_id for e in seq]  # the new table is discoverable
    index.update_cell(tid, 0, len(key_cells[0]), "mutated")
    check()
    index.delete_table(int(seq[0].table_id))
    check()


def test_gather_store_budget_demotes_to_host_gather(lake, monkeypatch):
    """A store over the device budget demotes fused-gather to the host-gather
    fused launch: identical results, zero gather savings claimed."""
    corpus, query, q_cols = lake
    index = MateIndex(corpus)
    want, _ = discover_batched(index, query, q_cols, k=10, backend="fused")
    monkeypatch.setattr(ops, "GATHER_STORE_MAX_BYTES", 0)
    got, st = discover_batched(index, query, q_cols, k=10, backend="fused-gather")
    assert [(e.table_id, e.joinability, e.mapping) for e in got] == [
        (e.table_id, e.joinability, e.mapping) for e in want
    ]
    assert st.gather_bytes_saved == 0
    assert st.filter_fused_launches > 0  # demoted to fused, not to composed


def test_gather_table_cap_demotes_per_batch(lake, monkeypatch):
    """Batches above the scatter-tile table cap fall off the gather path
    (host gather + composed launch) — results stay bit-identical and the
    stats stop claiming the counts-only contract."""
    corpus, query, q_cols = lake
    index = MateIndex(corpus)
    seq, _ = discovery.discover(index, query, q_cols, k=10)
    monkeypatch.setattr(ops, "_FUSED_MAX_TABLES", 4)
    bat, st = discover_batched(index, query, q_cols, k=10, backend="fused-gather")
    assert [(e.table_id, e.joinability, e.mapping) for e in bat] == [
        (e.table_id, e.joinability, e.mapping) for e in seq
    ]
    assert st.gather_bytes_saved == 0
    assert st.filter_fused_launches == 0
    assert st.filter_matrix_bytes > 0


def test_gather_session_and_serving_inherit(lake):
    """MateSession and the serving tier's plan_and_count seam run the gather
    path unchanged (the BoundCache stores row_sk-free PlanCounts)."""
    corpus, query, q_cols = lake
    session = MateSession(
        MateIndex(corpus, cfg=xash.XashConfig(bits=256)),
        DiscoveryConfig(backend="fused-gather", k=10),
    )
    ref, _ = discovery.discover(session.index, query, q_cols, k=10)
    got, stats = session.discover(query, q_cols)
    # session default rank='quality' (ISSUE 9) reorders without changing
    # membership — the gather contract here is the SET + the byte counters
    assert sorted((e.table_id, e.joinability) for e in got) == sorted(
        (e.table_id, e.joinability) for e in ref
    )
    assert stats.gather_bytes_saved > 0
    assert session.stats.gather_bytes_saved == stats.gather_bytes_saved
    pcs = session.plan_and_count([(query, q_cols)], filter_lanes=4)
    assert pcs[0].row_sk is None
    entries, st = session.score_from_counts(pcs[0], k=10)
    assert sorted((e.table_id, e.joinability) for e in entries) == sorted(
        (e.table_id, e.joinability) for e in ref
    )
    assert st.filter_lanes == 4  # degraded launch, set-identical results


# ---------------------------------------------------------------------------
# Property suite (hypothesis-optional, like tests/test_xash.py)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(
    bits=st.sampled_from(ALL_BITS),
    n=st.integers(min_value=1, max_value=600),
    q=st.integers(min_value=1, max_value=40),
    n_tables=st.integers(min_value=1, max_value=50),
    seed=st.integers(min_value=0, max_value=2**16),
    use_elig=st.booleans(),
)
def test_gather_property_bit_identity(bits, n, q, n_tables, seed, use_elig):
    """For arbitrary CSR shapes, the gather-fused launch equals the
    host-gather composed launch bit-for-bit at 128/256/512 bits."""
    lanes = xash.XashConfig(bits=bits).lanes
    store, rows, q_sk, elig, seg = _rand_case(
        lanes, n, q, n_tables, n_store=1024, seed=seed
    )
    if not use_elig:
        elig = None
    composed = ops.filter_table_counts(store[rows], q_sk, elig, seg, n_tables)
    gathered = ops.gather_filter_table_counts(
        jnp.asarray(store), rows, q_sk, elig, seg, n_tables
    )
    assert np.array_equal(gathered, composed)
