"""Backend registry: resolution precedence (config > env > platform),
registration invariants, shard-impl mapping, and the env-var lint."""

import os
import sys

import pytest

from repro.core import distributed
from repro.kernels import ops, registry
from repro.kernels.registry import Backend, BackendSpec

ENV = registry.ENV_VAR


def test_precedence_config_beats_env(monkeypatch):
    monkeypatch.setenv(ENV, "xla")
    bk = registry.resolve_backend("numpy")
    assert bk.name == "numpy" and bk.source == "config"


def test_precedence_env_beats_platform(monkeypatch):
    monkeypatch.setenv(ENV, "pallas")
    bk = registry.resolve_backend(None)
    assert bk.name == "pallas" and bk.source == "env"


def test_precedence_platform_default(monkeypatch):
    monkeypatch.delenv(ENV, raising=False)
    bk = registry.resolve_backend(None)
    assert bk.name == registry.platform_default() and bk.source == "platform"
    assert registry.platform_default("tpu") == "fused-gather"
    assert registry.platform_default("cpu") == "auto"
    assert registry.resolve_backend(None, platform="tpu").name == "fused-gather"


def test_resolved_backend_passes_through():
    bk = Backend("fused", source="env")
    assert registry.resolve_backend(bk) is bk


def test_unknown_config_name_raises_unknown_env_degrades(monkeypatch):
    with pytest.raises(ValueError, match="unknown filter backend"):
        registry.resolve_backend("cuda")
    # a typo'd env var must NOT crash every launch — it degrades to the
    # platform default, matching the historic dispatch
    monkeypatch.setenv(ENV, "cudnn")
    bk = registry.resolve_backend(None)
    assert bk.source == "platform"


def test_backend_properties():
    assert Backend("fused").fused and Backend("fused").device
    assert not Backend("pallas").fused
    assert not Backend("numpy").device
    assert str(Backend("xla")) == "xla"
    assert set(registry.backend_names()) == {
        "fused", "fused-gather", "pallas", "xla", "numpy", "auto"
    }
    # fused-gather is a fused backend (counts-only) that ALSO gathers on
    # device; plain fused must not claim the gather capability
    gb = Backend("fused-gather")
    assert gb.fused and gb.device and gb.gather
    assert not Backend("fused").gather


def test_register_backend_rejects_duplicates():
    with pytest.raises(ValueError, match="already registered"):
        registry.register_backend(BackendSpec("fused", "dup"))


def test_fused_filter_default_follows_registry(monkeypatch):
    monkeypatch.setenv(ENV, "fused")
    assert ops.fused_filter_default()
    monkeypatch.setenv(ENV, "xla")
    assert not ops.fused_filter_default()


def test_shard_impl_mapping(monkeypatch):
    # shard-impl names pass through; registry backends map fused/composed
    assert distributed.shard_impl_for("blocked") == "blocked"
    assert distributed.shard_impl_for("broadcast") == "broadcast"
    assert distributed.shard_impl_for("fused") == "fused"
    assert distributed.shard_impl_for(Backend("fused")) == "fused"
    # gather-fused is a fused-family backend: the sharded filter runs its
    # fused (host-gather) shard impl — and the demotion is VISIBLE now
    # (debug log + stats counter; routed ShardedMateIndex keeps the
    # gather-fused launch shard-local instead)
    assert distributed.shard_impl_for(Backend("fused-gather")) == "fused"
    assert distributed.shard_impl_for(Backend("xla")) == "broadcast"
    monkeypatch.setenv(ENV, "fused")
    assert distributed.shard_impl_for(None) == "fused"
    monkeypatch.delenv(ENV)
    assert distributed.shard_impl_for(None) == (
        "fused" if registry.platform_default() == "fused" else "broadcast"
    )


def test_shard_impl_gather_demotion_is_visible(caplog):
    """shard_impl_for silently demoted fused-gather to the fused shard impl;
    now it debug-logs the demotion and bumps the passed stats counter."""
    from repro.core.discovery import DiscoveryStats

    stats = DiscoveryStats()
    with caplog.at_level("DEBUG", logger="repro.core.distributed"):
        impl = distributed.shard_impl_for(Backend("fused-gather"), stats=stats)
    assert impl == "fused"
    assert stats.shard_gather_demotions == 1
    assert any("demoting" in r.message for r in caplog.records)
    # non-gather backends: no demotion, counter untouched
    with caplog.at_level("DEBUG", logger="repro.core.distributed"):
        assert distributed.shard_impl_for(Backend("fused"), stats=stats) == "fused"
    assert stats.shard_gather_demotions == 1


def test_env_var_read_only_by_registry():
    """The CI lint's contract, enforced as a tier-1 test too: no module
    outside kernels/registry.py reads MATE_FILTER_BACKEND."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, repo)
    try:
        from tools.lint_backend_env import violations
    finally:
        sys.path.remove(repo)
    assert violations(repo) == []


def test_lint_catches_real_reads():
    """The lint must flag code-level reads while letting docstrings and
    comments document the env var."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, repo)
    try:
        from tools.lint_backend_env import reads_env_var
    finally:
        sys.path.remove(repo)
    needle = "MATE_FILTER" + "_BACKEND"
    assert reads_env_var(f'import os\nx = os.environ.get("{needle}")\n')
    assert reads_env_var(f'FLAG = "{needle}"\n')
    assert not reads_env_var(f'"""docs mention {needle} here"""\nx = 1\n')
    assert not reads_env_var(f"# comment about {needle}\nx = 1\n")
    assert not reads_env_var(
        f'def f():\n    """{needle} docs."""\n    return 0\n'
    )
