"""End-to-end behaviour tests: drivers, enrichment, pipeline parallelism,
HLO cost model."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.corpus import Corpus, Table
from repro.core.index import MateIndex
from repro.data import synthetic
from repro.data.enrichment import enrich, tokenize_records


def test_train_driver_end_to_end(tmp_path):
    from repro.launch.train import main

    losses = main(
        [
            "--arch", "qwen1.5-0.5b", "--smoke", "--steps", "8",
            "--seq-len", "32", "--global-batch", "4",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "4", "--lr", "5e-3",
        ]
    )
    assert losses[-1] < losses[0]
    # resume path: second invocation starts from the checkpoint
    losses2 = main(
        [
            "--arch", "qwen1.5-0.5b", "--smoke", "--steps", "10",
            "--seq-len", "32", "--global-batch", "4",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "4", "--lr", "5e-3",
        ]
    )
    assert len(losses2) == 2  # resumed at step 8 of 10


def test_serve_driver_end_to_end():
    from repro.launch.serve import main

    done = main(
        ["--arch", "qwen1.5-0.5b", "--smoke", "--batch", "2",
         "--max-seq", "48", "--max-new", "4", "--n-requests", "3"]
    )
    assert all(len(r.out) == 4 for r in done)


def test_discovery_driver_end_to_end(capsys):
    from repro.launch.discovery import main

    main(["--n-tables", "80", "--queries", "2", "--rows", "10"])
    out = capsys.readouterr().out
    assert "precision" in out and "distributed filter" in out


def test_discovery_driver_sharded_build_subprocess():
    """--build-mesh N: the driver forces N virtual devices, builds the
    session over the mesh (shard_map hash pass + host merge) and the
    engines stay bit-identical — subprocess because the device count must
    be set before jax initialises."""
    res = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.discovery",
            "--build-mesh", "4", "--n-tables", "60", "--queries", "1",
            "--rows", "8",
        ],
        capture_output=True, text=True, timeout=600,
        cwd=__file__.rsplit("/", 2)[0],
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert res.returncode == 0, res.stderr[-2000:]
    assert "build stats: shards=4 mesh={'data': 4}" in res.stdout, res.stdout
    # default rank is 'quality' (ISSUE 9): the driver compares engine SETS
    assert "engines_set_identical=True" in res.stdout


def test_discovery_driver_rank_flags_subprocess():
    """--rank/--no-profile-gate: quality rank reports the gate counters and
    count rank restores the exact engines_bit_identical comparison."""
    import os

    env = {**os.environ, "PYTHONPATH": "src"}
    cwd = __file__.rsplit("/", 2)[0]
    res = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.discovery",
            "--n-tables", "80", "--queries", "2", "--rows", "8",
            "--rank", "quality",
        ],
        capture_output=True, text=True, timeout=600, cwd=cwd, env=env,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    assert "engines_set_identical=True" in res.stdout, res.stdout
    assert "profile gate (on, rank=quality)" in res.stdout, res.stdout
    assert "ranking_launches=" in res.stdout

    res = subprocess.run(
        [
            sys.executable, "-m", "repro.launch.discovery",
            "--n-tables", "80", "--queries", "2", "--rows", "8",
            "--rank", "count", "--no-profile-gate",
        ],
        capture_output=True, text=True, timeout=600, cwd=cwd, env=env,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    assert "engines_bit_identical=True" in res.stdout, res.stdout
    assert "profile gate (off, rank=count)" in res.stdout, res.stdout


def test_enrichment_operator():
    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=50, seed=4))
    base_cells = [["k%da" % i, "k%db" % i, "payload"] for i in range(10)]
    # inject joinable rows with extra feature columns into a corpus table
    feature_rows = [["k%da" % i, "k%db" % i, "feat%d" % i, "extra"] for i in range(8)]
    tid = len(corpus.tables)
    corpus.tables.append(Table(tid, feature_rows))
    corpus = Corpus(corpus.tables)
    index = MateIndex(corpus)
    base = Table(-1, base_cells)
    enriched, prov = enrich(index, base, [0, 1], k=3)
    assert enriched.n_cols > base.n_cols
    assert any(p["table_id"] == tid and p["hit_rows"] == 8 for p in prov)
    toks = tokenize_records(enriched, vocab_size=1000, seq_len=32)
    assert toks.shape == (10, 32)
    assert toks.max() < 1000


def test_pipeline_parallel_subprocess():
    """GPipe loss == non-pipelined loss (8 fake devices, 2 stages)."""
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import dataclasses, jax, jax.numpy as jnp
        from repro import configs
        from repro.launch import mesh as meshlib
        from repro.models import transformer, params as P_
        from repro.train import pipeline as PP
        from repro.train.step import chunked_ce

        cfg = configs.reduce_config(configs.get_config("qwen1.5-0.5b"))
        cfg = dataclasses.replace(cfg, n_layers=4)
        specs = transformer.model_specs(cfg)
        params = P_.materialize(specs, jax.random.PRNGKey(0))
        B, S = 16, 16
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
        labels = jnp.concatenate([tokens[:, 1:], -jnp.ones((B, 1), jnp.int32)], 1)
        hidden, _ = transformer.forward_hidden(params, cfg, tokens, remat=False)
        ref = chunked_ce(hidden, params["embed"].T.astype(hidden.dtype), labels, 0, 0.0)
        mesh = meshlib.make_mesh((2, 4), ("pod", "data"))
        staged = PP.stage_view(params, 2)
        fn = PP.pipeline_loss_fn(cfg, mesh, 2, staged, batch_axes=("data",))
        with mesh:
            out = jax.jit(fn)(staged, tokens, labels)
        diff = abs(float(out) - float(ref))
        assert diff < 1e-3, diff
        print("PP_OK", diff)
        """
    )
    res = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=__file__.rsplit("/", 2)[0], timeout=600,
    )
    assert "PP_OK" in res.stdout, res.stderr[-2000:]


def test_hlo_cost_model_counts_loop_trips():
    """Corrected flops must scale with scan trip count (XLA's raw
    cost_analysis does not)."""
    from repro.launch import hlo_cost

    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    x = jnp.ones((32, 64), jnp.float32)
    w = jnp.ones((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    got = hlo_cost.analyze(compiled.as_text())["flops"]
    want = 7 * 2 * 32 * 64 * 64
    assert abs(got - want) / want < 0.05, (got, want)
