"""Inverted index: postings, super keys, §5.4 updates, distributed filter."""

import jax
import numpy as np
import pytest

from repro.core import discovery, distributed, xash
from repro.core.corpus import Corpus, Table
from repro.core.index import MateIndex
from repro.data import synthetic


def small_corpus():
    return Corpus(
        [
            Table(0, [["uk", "cambridge", "x"], ["japan", "tokyo", "y"]]),
            Table(1, [["uk", "oxford", "z"]]),
        ]
    )


def test_postings_locations():
    idx = MateIndex(small_corpus())
    pl = idx.fetch_postings("uk")
    assert sorted(map(tuple, pl.tolist())) == [(0, 0), (2, 0)]  # global rows 0,2
    assert len(idx.fetch_postings("nonexistent")) == 0


def test_superkey_is_or_of_cells():
    corpus = small_corpus()
    idx = MateIndex(corpus)
    want = 0
    for v in ["uk", "cambridge", "x"]:
        want |= xash.xash_oracle(v, idx.cfg)
    assert xash.lanes_to_int(idx.superkeys[0]) == want


def test_insert_table():
    corpus = small_corpus()
    idx = MateIndex(corpus)
    tid = idx.insert_table([["uk", "cambridge", "new"], ["france", "paris", "w"]])
    assert tid == 2
    pl = idx.fetch_postings("uk")
    assert len(pl) == 3
    # new rows discoverable
    q = Table(-1, [["uk", "cambridge"]])
    topk, _ = discovery.discover(idx, q, [0, 1], k=5)
    assert tid in [e.table_id for e in topk]


def test_delete_table():
    idx = MateIndex(small_corpus())
    idx.delete_table(0)
    pl = idx.fetch_postings("uk")
    assert [tuple(x) for x in pl.tolist()] == [(2, 0)]


def test_update_cell_rehashes():
    corpus = small_corpus()
    idx = MateIndex(corpus)
    old_sk = idx.superkeys[0].copy()
    idx.update_cell(0, 0, 1, "london")
    assert not np.array_equal(old_sk, idx.superkeys[0])
    assert len(idx.fetch_postings("cambridge")) == 0
    assert len(idx.fetch_postings("london")) == 1
    want = 0
    for v in ["uk", "london", "x"]:
        want |= xash.xash_oracle(v, idx.cfg)
    assert xash.lanes_to_int(idx.superkeys[0]) == want


def test_corpus_char_frequencies():
    corpus = small_corpus()
    freq = corpus.char_frequencies()
    assert freq.shape == (37,)
    assert abs(freq.sum() - 1.0) < 1e-9
    idx = MateIndex(corpus, use_corpus_char_freq=True)
    assert idx.cfg.char_freq is not None


def test_distributed_filter_matches_local():
    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=60, seed=1))
    idx = MateIndex(corpus)
    queries = synthetic.make_mixed_queries(corpus, 1, 10, 2, seed=2)
    q, q_cols = queries[0]
    _keys, sk_of_key = discovery.build_query_superkeys(idx, q, q_cols)
    qsk = np.stack(list(sk_of_key.values()))
    row_tables = np.asarray(
        corpus.table_of_row(np.arange(corpus.total_rows)), dtype=np.int32
    )
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    sk, rt = distributed.shard_corpus_rows(idx.superkeys, row_tables, mesh, ("data",))
    fn = distributed.make_distributed_filter(mesh, len(corpus.tables), ("data",))
    tc, kc = fn(sk, rt, qsk)
    tc_ref, kc_ref = distributed.filter_counts_local(
        idx.superkeys, row_tables, qsk, len(corpus.tables)
    )
    assert np.array_equal(np.asarray(tc), np.asarray(tc_ref))
    assert np.array_equal(np.asarray(kc), np.asarray(kc_ref))
