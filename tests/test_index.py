"""Inverted index: postings, super keys, §5.4 updates, distributed filter."""

import jax
import numpy as np
import pytest

from repro.core import discovery, distributed, xash
from repro.core.corpus import Corpus, Table
from repro.core.index import MateIndex
from repro.data import synthetic


def small_corpus():
    return Corpus(
        [
            Table(0, [["uk", "cambridge", "x"], ["japan", "tokyo", "y"]]),
            Table(1, [["uk", "oxford", "z"]]),
        ]
    )


def test_postings_locations():
    idx = MateIndex(small_corpus())
    pl = idx.fetch_postings("uk")
    assert sorted(map(tuple, pl.tolist())) == [(0, 0), (2, 0)]  # global rows 0,2
    assert len(idx.fetch_postings("nonexistent")) == 0


def test_superkey_is_or_of_cells():
    corpus = small_corpus()
    idx = MateIndex(corpus)
    want = 0
    for v in ["uk", "cambridge", "x"]:
        want |= xash.xash_oracle(v, idx.cfg)
    assert xash.lanes_to_int(idx.superkeys[0]) == want


def test_insert_table():
    corpus = small_corpus()
    idx = MateIndex(corpus)
    tid = idx.insert_table([["uk", "cambridge", "new"], ["france", "paris", "w"]])
    assert tid == 2
    pl = idx.fetch_postings("uk")
    assert len(pl) == 3
    # new rows discoverable
    q = Table(-1, [["uk", "cambridge"]])
    topk, _ = discovery.discover(idx, q, [0, 1], k=5)
    assert tid in [e.table_id for e in topk]


def test_delete_table():
    idx = MateIndex(small_corpus())
    idx.delete_table(0)
    pl = idx.fetch_postings("uk")
    assert [tuple(x) for x in pl.tolist()] == [(2, 0)]


def test_update_cell_rehashes():
    corpus = small_corpus()
    idx = MateIndex(corpus)
    old_sk = idx.superkeys[0].copy()
    idx.update_cell(0, 0, 1, "london")
    assert not np.array_equal(old_sk, idx.superkeys[0])
    assert len(idx.fetch_postings("cambridge")) == 0
    assert len(idx.fetch_postings("london")) == 1
    want = 0
    for v in ["uk", "london", "x"]:
        want |= xash.xash_oracle(v, idx.cfg)
    assert xash.lanes_to_int(idx.superkeys[0]) == want


def _assert_same_index_state(idx: MateIndex, rebuilt: MateIndex):
    """Incrementally-updated index must equal one built from scratch."""
    assert np.array_equal(idx.superkeys, rebuilt.superkeys)
    for value in rebuilt.corpus.value_of:
        got = sorted(map(tuple, idx.fetch_postings(value).tolist()))
        want = sorted(map(tuple, rebuilt.fetch_postings(value).tolist()))
        assert got == want, value


def test_insert_table_matches_rebuild():
    idx = MateIndex(small_corpus())
    new_cells = [["uk", "cambridge", "new"], ["france", "paris", "w"]]
    idx.insert_table(new_cells)
    rebuilt = MateIndex(
        Corpus(
            [
                Table(0, [["uk", "cambridge", "x"], ["japan", "tokyo", "y"]]),
                Table(1, [["uk", "oxford", "z"]]),
                Table(2, new_cells),
            ]
        )
    )
    _assert_same_index_state(idx, rebuilt)


def test_update_cell_matches_rebuild():
    idx = MateIndex(small_corpus())
    idx.update_cell(0, 0, 1, "london")
    idx.update_cell(1, 0, 2, "tokyo")  # now shares a value with table 0
    rebuilt = MateIndex(
        Corpus(
            [
                Table(0, [["uk", "london", "x"], ["japan", "tokyo", "y"]]),
                Table(1, [["uk", "oxford", "tokyo"]]),
            ]
        )
    )
    _assert_same_index_state(idx, rebuilt)


def test_delete_table_matches_rebuild():
    """Tombstoned tables vanish from discovery exactly like a rebuild
    without them (modulo the table-id shift a rebuild causes)."""
    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=80, seed=5))
    query, q_cols, expected, corpus = synthetic.make_query_with_ground_truth(corpus)
    idx = MateIndex(corpus)
    topk, _ = discovery.discover(idx, query, q_cols, k=5)
    victim = topk[0].table_id
    idx.delete_table(victim)

    kept = [t for t in corpus.tables if t.table_id != victim]
    new_id = {t.table_id: i for i, t in enumerate(kept)}
    rebuilt = MateIndex(
        Corpus([Table(new_id[t.table_id], t.cells, t.name) for t in kept])
    )
    got, _ = discovery.discover(idx, query, q_cols, k=5)
    want, _ = discovery.discover(rebuilt, query, q_cols, k=5)
    assert victim not in [e.table_id for e in got]
    assert [(new_id[e.table_id], e.joinability) for e in got] == [
        (e.table_id, e.joinability) for e in want
    ]


def test_updates_keep_engines_bit_identical():
    """After a mix of §5.4 updates, scalar and batched engines still agree."""
    from repro.core.batched import discover_batched, discover_many

    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=60, seed=9))
    query, q_cols, _, corpus = synthetic.make_query_with_ground_truth(corpus)
    idx = MateIndex(corpus)
    key_cells = [[query.cells[r][c] for c in q_cols] for r in range(query.n_rows)]
    tid = idx.insert_table([kc + ["extra"] for kc in key_cells])
    idx.update_cell(tid, 0, len(key_cells[0]), "mutated")
    idx.delete_table(0)

    seq, _ = discovery.discover(idx, query, q_cols, k=8)
    assert tid in [e.table_id for e in seq]
    for backend in ("numpy", None):
        bat, _ = discover_batched(idx, query, q_cols, k=8, backend=backend)
        assert [(e.table_id, e.joinability, e.mapping) for e in seq] == [
            (e.table_id, e.joinability, e.mapping) for e in bat
        ]
    [(many, _)] = discover_many(idx, [(query, q_cols)], k=8)
    assert [(e.table_id, e.joinability) for e in many] == [
        (e.table_id, e.joinability) for e in seq
    ]


def test_gather_candidates_matches_scalar_grouping():
    """CSR block == the scalar engine's per-value dict grouping."""
    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=40, seed=3))
    idx = MateIndex(corpus)
    queries = synthetic.make_mixed_queries(corpus, 1, 15, 2, seed=4)
    (q, q_cols) = queries[0]
    init_col = discovery.init_column_selection(q, q_cols, "cardinality", idx)
    values = list(dict.fromkeys(q.column(init_col)))

    by_table = {}
    for i, v in enumerate(values):
        for grow, _col in idx.fetch_postings(v).tolist():
            by_table.setdefault(int(idx.corpus.table_of_row(grow)), []).append(
                (int(grow), i)
            )
    order = sorted(by_table, key=lambda t: (-len(by_table[t]), t))

    block = idx.gather_candidates(values)
    assert block.table_ids.tolist() == order
    assert block.n_items == sum(len(v) for v in by_table.values())
    for t, tid in enumerate(order):
        s = block.table_slice(t)
        got = list(zip(block.rows[s].tolist(), block.value_idx[s].tolist()))
        assert sorted(got) == sorted(by_table[tid])


def test_superkey_of_keys_matches_per_value_or():
    corpus = small_corpus()
    idx = MateIndex(corpus)
    keys = [("uk", "cambridge"), ("japan", "tokyo"), ("uk", "oxford")]
    got = idx.superkey_of_keys(keys)
    for i, key in enumerate(keys):
        want = 0
        for v in key:
            want |= xash.xash_oracle(v, idx.cfg)
        assert xash.lanes_to_int(got[i]) == want


@pytest.mark.parametrize("hash_name", ["xash", "murmur"])
def test_superkey_of_keys_ragged_widths_raise(hash_name):
    """Regression: a ragged n-ary key list used to crash in the xash
    branch's reshape and silently mis-hash on the baseline OR path — both
    branches must raise the same clear ValueError."""
    idx = MateIndex(small_corpus(), hash_name=hash_name)
    with pytest.raises(ValueError, match="ragged key widths"):
        idx.superkey_of_keys([("uk", "cambridge"), ("japan",)])
    with pytest.raises(ValueError, match="key 2 has 3"):
        idx.superkey_of_keys([("uk",), ("japan",), ("uk", "oxford", "z")])
    # uniform widths (any width) still hash fine
    assert idx.superkey_of_keys([("uk",), ("japan",)]).shape == (2, idx.cfg.lanes)


def test_fetch_postings_deleted_mask_cached_on_epoch():
    """The tombstone filter uses a deleted-row mask cached on
    mutation_epoch — behavior-neutral vs the old per-fetch np.isin, and
    rebuilt exactly once per epoch even under delete-heavy fetch storms."""
    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=40, seed=11))
    idx = MateIndex(corpus)
    victims = list(range(0, 40, 3))  # delete-heavy: 14 tombstoned tables
    for t in victims:
        idx.delete_table(t)
    epoch = idx.mutation_epoch
    for value in list(idx.corpus.value_of)[:200]:
        got = idx.fetch_postings(value)
        # the replaced per-fetch semantics: isin against the tombstone set
        vid = idx.corpus.value_of.get(value)
        pl = idx.postings.get(vid, np.zeros((0, 2), np.int64))
        if len(pl):
            tids = idx.corpus.table_of_row(pl[:, 0])
            pl = pl[~np.isin(tids, list(idx._deleted_tables))]
        assert np.array_equal(got, pl), value
    # the mask was built once for the whole storm, keyed on the epoch
    assert idx._deleted_mask_epoch == epoch
    mask = idx._deleted_mask
    idx.fetch_postings(next(iter(idx.corpus.value_of)))
    assert idx._deleted_mask is mask  # no rebuild within an epoch
    idx.delete_table(39)  # epoch bump → next fetch rebuilds
    idx.fetch_postings(next(iter(idx.corpus.value_of)))
    assert idx._deleted_mask is not mask
    assert idx._deleted_mask_epoch == idx.mutation_epoch


def test_corpus_char_frequencies():
    corpus = small_corpus()
    freq = corpus.char_frequencies()
    assert freq.shape == (37,)
    assert abs(freq.sum() - 1.0) < 1e-9
    idx = MateIndex(corpus, use_corpus_char_freq=True)
    assert idx.cfg.char_freq is not None


def test_distributed_filter_matches_local():
    corpus = synthetic.make_corpus(synthetic.SyntheticSpec(n_tables=60, seed=1))
    idx = MateIndex(corpus)
    queries = synthetic.make_mixed_queries(corpus, 1, 10, 2, seed=2)
    q, q_cols = queries[0]
    _keys, sk_of_key = discovery.build_query_superkeys(idx, q, q_cols)
    qsk = np.stack(list(sk_of_key.values()))
    row_tables = np.asarray(
        corpus.table_of_row(np.arange(corpus.total_rows)), dtype=np.int32
    )
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    sk, rt = distributed.shard_corpus_rows(idx.superkeys, row_tables, mesh, ("data",))
    fn = distributed.make_distributed_filter(mesh, len(corpus.tables), ("data",))
    tc, kc = fn(sk, rt, qsk)
    tc_ref, kc_ref = distributed.filter_counts_local(
        idx.superkeys, row_tables, qsk, len(corpus.tables)
    )
    assert np.array_equal(np.asarray(tc), np.asarray(tc_ref))
    assert np.array_equal(np.asarray(kc), np.asarray(kc_ref))
