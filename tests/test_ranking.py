"""Ranked-discovery subsystem acceptance (ISSUE 9).

Pinned contracts:
  * profile build determinism — the build-time column profiles are
    byte-identical between the single-host pass, any host shard count, and
    the routed lake's per-shard stores (concatenated in shard order);
  * the profile gate is PURE PRUNING — with the gate on, the verified
    top-k SET is identical to the ungated run at every hash width, on
    deterministic lakes, crafted prunable tables, and (under hypothesis)
    randomly seeded lakes;
  * the scoring head's jitted launch matches its numpy oracle;
  * rank='quality' only REORDERS/annotates the count-ranked set — never
    changes membership — on the single-host and the routed index, and the
    serving tier inherits both knobs (cache hits replay quality entries
    exactly; fingerprints split by rank/gate so modes cannot cross-serve);
  * §5.4 mutations invalidate the profile store epoch-for-epoch (per shard
    on the routed lake), like the device superkey store;
  * stats plumbing is field-driven: ``DiscoveryStats.merge`` and
    ``SessionStats.absorb`` enumerate dataclass fields, so a newly added
    counter can never be silently dropped from aggregation.
"""

import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # property tests skip; unit tests still run
    HAVE_HYPOTHESIS = False

    def _skip_decorator(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    given = settings = _skip_decorator

    class _StrategyStub:
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategyStub()

from repro.core import profiles, ranking, xash
from repro.core.batched import discover_batched, discover_many
from repro.core.corpus import Corpus, Table
from repro.core.discovery import DiscoveryStats
from repro.core.index import build_index
from repro.core.routing import build_routed_index
from repro.core.session import (
    _ABSORBED,
    _NOT_AGGREGATED,
    DiscoveryConfig,
    MateSession,
    SessionStats,
)
from repro.data import synthetic
from repro.serve.cache import query_fingerprint
from repro.serve.engine import DiscoveryEngine

from conftest import ALL_BITS, ground_truth_lake, indexes_at_widths


@pytest.fixture(scope="module")
def lake():
    return ground_truth_lake(
        n_tables=60, corpus_seed=5, n_rows=25, key_width=2, query_seed=7
    )


@pytest.fixture(scope="module")
def built(lake):
    corpus, _q, _qc, _e = lake
    return indexes_at_widths(corpus)


def _key(entries):
    return [(e.table_id, e.joinability, e.mapping) for e in entries]


def _ids(entries):
    return {e.table_id for e in entries}


# ---------------------------------------------------------------------------
# Profile build: determinism + layout
# ---------------------------------------------------------------------------

def test_profile_build_deterministic_across_shard_counts(lake):
    """Single-host == any host shard count, byte for byte (the build_index
    sharded profile pass concatenates per-table-range parts)."""
    corpus, _q, _qc, _e = lake
    stores = {
        n: build_index(corpus, n_shards=n)[0].profiles() for n in (1, 2, 4)
    }
    assert profiles.profiles_equal(stores[1], stores[2])
    assert profiles.profiles_equal(stores[1], stores[4])


def test_profile_build_eager_matches_lazy_rebuild(lake):
    """build_index populates the store eagerly; a lazy rebuild from the
    same arenas (the post-mutation path) is byte-identical."""
    corpus, _q, _qc, _e = lake
    idx, stats = build_index(corpus)
    eager = idx.profiles()
    assert stats.profile_seconds >= 0 and stats.profile_bytes == eager.nbytes
    lazy = profiles.build_profiles(idx.corpus, idx.value_lanes, epoch=0)
    assert profiles.profiles_equal(eager, lazy)


def test_routed_per_shard_profiles_concat_to_single_host(lake):
    """The routed lake's shard-local stores, concatenated in shard order,
    are byte-identical to the single-host store — same determinism contract
    as the routed postings/superkeys."""
    corpus, _q, _qc, _e = lake
    single = build_index(corpus)[0].profiles()
    routed, rstats = build_routed_index(corpus, n_shards=3)
    parts = [routed._shard_profiles(s) for s in routed.shards]
    assert [p.epoch for p in parts] == [0, 0, 0]
    assert rstats.profile_bytes == sum(p.nbytes for p in parts)
    merged = profiles.merge_profiles(parts)
    assert profiles.profiles_equal(single, merged)


def test_profile_store_layout(lake):
    corpus, _q, _qc, _e = lake
    store = build_index(corpus)[0].profiles()
    nt = len(corpus.tables)
    assert store.n_tables == nt
    assert store.mask.shape == (nt, profiles.MASK_WORDS)
    assert store.sketch.shape == (nt, profiles.SKETCH_K)
    assert store.col_ptr[-1] == int(corpus.n_cols.sum())
    np.testing.assert_array_equal(store.n_cols, corpus.n_cols)
    np.testing.assert_array_equal(store.n_rows, np.diff(corpus.row_base))
    # cardinality is bounded by rows; every non-empty table has card >= 1
    assert (store.card_max <= store.n_rows).all()
    assert (store.card_max[store.n_rows > 0] >= 1).all()


# ---------------------------------------------------------------------------
# The gate is pure pruning
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", ALL_BITS)
def test_gate_is_pure_pruning_every_width(built, lake, bits):
    _corpus, query, q_cols, expected = lake
    idx = built[bits]
    base, _ = discover_batched(idx, query, q_cols, k=10)
    gated, gstats = discover_batched(
        idx, query, q_cols, k=10, profile_gate=True
    )
    assert _key(gated) == _key(base)  # count rank: order too
    assert gstats.tables_gated >= 0
    # the planted ground truth survives the gate
    assert set(expected) & _ids(gated) == set(expected) & _ids(base)


def test_gate_prunes_crafted_narrow_table(lake):
    """A planted 1-column table containing the query's init values is a
    candidate (its posting lists match) but can never host a width-2 key —
    the n_cols condition gates it deterministically."""
    corpus, query, q_cols, _e = lake
    init_vals = [row[q_cols[0]] for row in query.cells[:6]]
    tables = list(corpus.tables)
    narrow_id = len(tables)
    tables.append(Table(narrow_id, [[v] for v in init_vals]))
    corpus2 = Corpus(tables, max_len=corpus.max_len)
    idx = build_index(corpus2)[0]

    base, _ = discover_batched(idx, query, q_cols, k=10)
    gated, gstats = discover_batched(
        idx, query, q_cols, k=10, profile_gate=True
    )
    assert gstats.tables_gated >= 1
    assert gstats.gate_bytes_saved > 0
    assert _key(gated) == _key(base)
    # and the narrow table was among the gated (it cannot be in either set)
    keep = idx.gate_candidates(
        [tuple(row[c] for c in q_cols) for row in query.cells[:1]],
        np.asarray([narrow_id]),
    )
    assert not keep[0]


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_gate_purity_property(seed):
    """Hypothesis sweep: random lakes + random planted queries — the gated
    verified set always equals the ungated one (128/256/512 bits)."""
    corpus = synthetic.make_corpus(
        synthetic.SyntheticSpec(n_tables=25, seed=seed % 97)
    )
    query, q_cols, _exp, corpus = synthetic.make_query_with_ground_truth(
        corpus, n_rows=12, key_width=2, seed=seed
    )
    for bits in ALL_BITS:
        idx = build_index(corpus, cfg=xash.XashConfig(bits=bits))[0]
        base, _ = discover_batched(idx, query, q_cols, k=8)
        gated, _ = discover_batched(
            idx, query, q_cols, k=8, profile_gate=True
        )
        assert _key(gated) == _key(base)


# ---------------------------------------------------------------------------
# Scoring head: oracle parity + quality-rank set identity
# ---------------------------------------------------------------------------

def test_scoring_launch_matches_numpy_oracle():
    rng = np.random.default_rng(3)
    n, n_keys = 37, 14
    counts = rng.integers(0, 30, n).astype(np.float32)
    card = rng.integers(1, 50, n).astype(np.float32)
    rows = rng.integers(1, 60, n).astype(np.float32)
    q_sketch = rng.integers(0, 2**32, profiles.SKETCH_K, dtype=np.uint32)
    t_sketch = rng.integers(
        0, 2**32, (n, profiles.SKETCH_K), dtype=np.uint32
    )
    # force some sketch matches so the similarity term is exercised
    t_sketch[::3, :5] = q_sketch[:5]
    got = np.asarray(
        ranking._score_fn()(
            counts, np.float32(n_keys), card, rows, t_sketch, q_sketch
        )
    )
    want = ranking.score_np(
        counts, n_keys, card, rows,
        (t_sketch == q_sketch[None, :]).sum(axis=1),
    )
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)
    assert got.dtype == np.float32
    # (real profiles have card <= rows so scores land in [0, 1]; these raw
    # random inputs only pin launch/oracle parity, not the range)
    assert (got >= 0).all()


@pytest.mark.parametrize("bits", ALL_BITS)
def test_quality_rank_preserves_verified_set(built, lake, bits):
    _corpus, query, q_cols, _e = lake
    idx = built[bits]
    count_rank, _ = discover_batched(idx, query, q_cols, k=10)
    quality, qstats = discover_batched(
        idx, query, q_cols, k=10, rank="quality", profile_gate=True
    )
    assert _ids(quality) == _ids(count_rank)
    assert sorted(_key(quality)) == sorted(_key(count_rank))
    assert qstats.ranking_launches >= 1
    assert all(e.quality is not None for e in quality)
    # ordered by (-quality, -joinability, table_id)
    order = [(-e.quality, -e.joinability, e.table_id) for e in quality]
    assert order == sorted(order)
    # count-rank entries carry no annotation (and the default is unchanged)
    assert all(e.quality is None for e in count_rank)


def test_quality_rank_two_phase_matches_batched(built, lake):
    """discover_many (plan_and_count + score_from_counts) produces the same
    quality-annotated entries as discover_batched — one scoring launch per
    request on the two-phase path."""
    _corpus, query, q_cols, _e = lake
    idx = built[128]
    solo, _ = discover_batched(
        idx, query, q_cols, k=10, rank="quality", profile_gate=True
    )
    many = discover_many(
        idx, [(query, q_cols)] * 2, k=10, rank="quality", profile_gate=True
    )
    for entries, mstats in many:
        assert [(e.table_id, e.quality) for e in entries] == [
            (e.table_id, e.quality) for e in solo
        ]
        assert mstats.ranking_launches == 1


def test_routed_quality_matches_single_host(lake):
    """The routed lake inherits the whole subsystem: shard-local gate +
    shard-local profile features produce the exact single-host quality
    ordering (profiles are deterministic and the count merge is exact)."""
    corpus, query, q_cols, _e = lake
    single = MateSession.build(corpus, DiscoveryConfig(k=10))
    routed = MateSession.build(
        corpus, DiscoveryConfig(k=10), distributed=True, n_shards=3
    )
    ref, st_s = single.discover(query, q_cols)
    got, st_r = routed.discover(query, q_cols)
    assert _key(got) == _key(ref)
    assert [e.quality for e in got] == [e.quality for e in ref]
    assert st_r.tables_gated == st_s.tables_gated
    assert st_r.shard_launches > 0  # the filter really ran routed


# ---------------------------------------------------------------------------
# Serving inheritance
# ---------------------------------------------------------------------------

def test_serving_inherits_rank_and_gate(built, lake):
    _corpus, query, q_cols, _e = lake
    idx = built[128]
    session = MateSession(idx, DiscoveryConfig(k=10, result_cache=8))
    eng = DiscoveryEngine(session=session, batch=1)
    cold = eng.discover(query, q_cols)
    warm = eng.discover(query, q_cols)
    assert warm.from_cache and session.stats.cache_hits == 1
    assert _key(warm.results) == _key(cold.results)
    assert [e.quality for e in warm.results] == [
        e.quality for e in cold.results
    ]
    ref, _ = discover_batched(
        idx, query, q_cols, k=10, rank="quality", profile_gate=True
    )
    assert _key(cold.results) == _key(ref)


def test_fingerprint_splits_by_rank_and_gate(lake):
    """A count-mode cache fill must never answer a quality-mode request:
    rank and gate are part of the query fingerprint."""
    _corpus, query, q_cols, _e = lake
    fps = {
        query_fingerprint(query, q_cols, rank=r, profile_gate=g)
        for r in ("count", "quality")
        for g in (False, True)
    }
    assert len(fps) == 4
    # and the default arguments reproduce the pre-ISSUE-9 fingerprint shape
    assert query_fingerprint(query, q_cols) == query_fingerprint(
        query, q_cols, rank="count", profile_gate=False
    )


def test_config_validates_rank():
    with pytest.raises(ValueError, match="rank"):
        DiscoveryConfig(rank="best")
    assert DiscoveryConfig().rank == "quality"
    assert DiscoveryConfig().profile_gate is True


# ---------------------------------------------------------------------------
# §5.4 mutations: epoch-pinned stores
# ---------------------------------------------------------------------------

def test_mutation_epoch_invalidates_profiles(lake):
    corpus, query, q_cols, _e = lake
    idx = build_index(
        Corpus([Table(t.table_id, [list(r) for r in t.cells]) for t in corpus.tables],
               max_len=corpus.max_len)
    )[0]
    s0 = idx.profiles()
    assert s0.epoch == 0 and idx.profiles() is s0  # stable while unmutated
    new_cells = [list(row[c] for c in q_cols) + ["x"] for row in query.cells]
    tid = idx.insert_table(new_cells)
    s1 = idx.profiles()
    assert s1 is not s0 and s1.epoch == idx.mutation_epoch
    assert s1.n_tables == s0.n_tables + 1
    # the inserted (joinable) table passes the gate against the query keys
    keys = list(
        dict.fromkeys(tuple(row[c] for c in q_cols) for row in query.cells)
    )
    assert idx.gate_candidates(keys, np.asarray([tid]))[0]
    # update: the store refreshes again (same discipline as device_store)
    idx.update_cell(tid, 0, 0, "zz-mutated")
    s2 = idx.profiles()
    assert s2 is not s1 and s2.epoch == idx.mutation_epoch


def test_routed_mutation_rebuilds_only_owning_shard(lake):
    corpus, _q, _qc, _e = lake
    fresh = Corpus(
        [Table(t.table_id, [list(r) for r in t.cells]) for t in corpus.tables],
        max_len=corpus.max_len,
    )
    routed, _ = build_routed_index(fresh, n_shards=2)
    before = [routed._shard_profiles(s) for s in routed.shards]
    victim = routed.shards[1].table_lo  # first table of shard 1
    routed.update_cell(victim, 0, 0, "routed-mutation")
    after = [routed._shard_profiles(s) for s in routed.shards]
    assert after[0] is before[0]  # shard 0 untouched
    assert after[1] is not before[1]
    assert after[1].epoch == routed.shards[1].mutation_epoch


# ---------------------------------------------------------------------------
# Field-driven stats plumbing
# ---------------------------------------------------------------------------

def test_discovery_stats_merge_covers_every_field():
    a, b = DiscoveryStats(), DiscoveryStats()
    for i, f in enumerate(dataclasses.fields(DiscoveryStats)):
        setattr(a, f.name, 2 * i + 1)
        setattr(b, f.name, 100 + i)
    out = a.merge(b)
    assert out is a
    for i, f in enumerate(dataclasses.fields(DiscoveryStats)):
        assert getattr(a, f.name) == (2 * i + 1) + (100 + i), f.name


def test_every_discovery_counter_is_classified_for_absorb():
    """The forgotten-field guard: every DiscoveryStats field is either
    absorbed into SessionStats or explicitly listed as not-aggregated —
    adding a counter without classifying it breaks this test."""
    names = {f.name for f in dataclasses.fields(DiscoveryStats)}
    assert set(_ABSORBED) | set(_NOT_AGGREGATED) == names
    assert not set(_ABSORBED) & set(_NOT_AGGREGATED)
    ss = SessionStats()
    for name in _ABSORBED:
        assert hasattr(ss, name), f"SessionStats lacks absorbed field {name}"


def test_absorb_raises_on_unmirrored_field(monkeypatch):
    """If a new DiscoveryStats counter is classified as absorbed but not
    mirrored on SessionStats, the very first absorb raises instead of
    silently dropping it."""
    from repro.core import session as session_mod

    monkeypatch.setattr(
        session_mod, "_ABSORBED", session_mod._ABSORBED + ("brand_new",)
    )
    ds = DiscoveryStats()
    ds.brand_new = 7  # simulate the newly added counter
    with pytest.raises(AttributeError):
        SessionStats().absorb(ds)


def test_absorb_accumulates_ranking_counters(built, lake):
    _corpus, query, q_cols, _e = lake
    session = MateSession(built[128], DiscoveryConfig(k=5))
    session.discover(query, q_cols)
    assert session.stats.ranking_launches >= 1
    assert session.stats.tables_gated >= 0
