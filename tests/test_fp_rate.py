"""FP-rate regression across superkey widths (paper Tables 1-2 ordering).

Pins the precision/bandwidth tradeoff the 512-bit path exists for: on a
seeded synthetic lake, widening the hash must strictly cut false-positive
rows, and NO width may ever reject an exact match (§6.3 lemma).
"""

import pytest

from repro.core.batched import discover_batched, filter_outcomes

from conftest import mixed_query_lake, indexes_at_widths

WIDTHS = (128, 256, 512)


@pytest.fixture(scope="module")
def fp_lake():
    """FP-heavy workload: mixed queries whose key columns come from
    different tables, so single columns hit many posting lists while full
    composite keys rarely exist (the paper's sensor-data regime).
    One index per width, shared by every test in this module."""
    corpus, queries = mixed_query_lake(
        n_tables=120, corpus_seed=7, n_queries=4, n_rows=20, key_width=2,
        query_seed=11,
    )
    assert queries
    # lazy-profile indexes (built=False): this module never ranks or gates
    indexes = indexes_at_widths(corpus, WIDTHS, built=False)
    outcomes = {}
    for bits, index in indexes.items():
        agg = {"checks": 0, "passed": 0, "tp": 0, "fp": 0, "fn": 0}
        for q, q_cols in queries:
            out = filter_outcomes(index, q, q_cols, check_false_negatives=True)
            for k in agg:
                agg[k] += out[k]
        outcomes[bits] = agg
    return queries, indexes, outcomes


def test_512bit_strictly_fewer_false_positives(fp_lake):
    _, _, outcomes = fp_lake
    agg128, agg512 = outcomes[128], outcomes[512]
    # identical probe workload at both widths
    assert agg128["checks"] == agg512["checks"] > 0
    # the ordering the paper's Tables 1-2 report: wider hash, fewer FPs
    assert agg128["fp"] > 0, "lake must exercise the FP regime"
    assert agg512["fp"] < agg128["fp"]
    # exact matches are width-invariant
    assert agg128["tp"] == agg512["tp"] > 0


def test_no_false_negatives_at_any_width(fp_lake):
    _, _, outcomes = fp_lake
    for bits in WIDTHS:
        assert outcomes[bits]["fn"] == 0, bits


def test_fp_ordering_survives_topk_engine(fp_lake):
    """The engine-level verified-FP stat shows the same ordering, and both
    widths return the same top-k (FP rate never changes results)."""
    queries, indexes, _ = fp_lake
    fp128 = fp512 = 0
    for q, q_cols in queries:
        top128, st128 = discover_batched(indexes[128], q, q_cols, k=5)
        top512, st512 = discover_batched(indexes[512], q, q_cols, k=5)
        assert [(e.table_id, e.joinability) for e in top128] == [
            (e.table_id, e.joinability) for e in top512
        ]
        fp128 += st128.verified_fp
        fp512 += st512.verified_fp
    assert fp512 <= fp128
