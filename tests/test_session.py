"""MateSession / DiscoveryConfig / async DiscoveryEngine acceptance.

The redesign's contract (ISSUE 4, amended by ISSUE 9): the session's
verified top-k SET is bit-identical to the pre-redesign entry points across
widths 128/256/512 and all backends (numpy/xla/pallas/fused) — since ISSUE 9
the session defaults to ``rank='quality'``, which REORDERS that set by the
scoring head (and the profile gate prunes candidates without changing it),
so set-level comparisons run against the count-ranked scalar engine and
exact-order comparisons against the raw engines at the session's own
rank/gate flags.  The engine's arrival-window batching honours window-full
and flush-after-deadline semantics deterministically.  The PR 4 deprecation
shims (``use_kernel=``/``fused=``/``impl=``) were REMOVED one release later
(ISSUE 5): the old kwargs now raise TypeError — pinned below.
"""

import asyncio
import dataclasses

import numpy as np
import pytest

from repro.core import discovery, xash
from repro.core.batched import discover_batched, discover_many
from repro.core.index import MateIndex
from repro.core.session import DiscoveryConfig, MateSession, VALID_BITS
from repro.data import synthetic
from repro.serve.engine import DiscoveryEngine
from repro.kernels.registry import Backend

BACKENDS = ("numpy", "xla", "pallas", "fused", "fused-gather")


@pytest.fixture(scope="module")
def lake():
    spec = synthetic.SyntheticSpec(n_tables=120, seed=0)
    corpus = synthetic.make_corpus(spec)
    query, q_cols, _expected, corpus = synthetic.make_query_with_ground_truth(corpus)
    return corpus, query, q_cols


@pytest.fixture(scope="module")
def sessions(lake):
    """One session per width (index builds are the expensive part)."""
    corpus, _q, _qc = lake
    return {
        bits: MateSession.build(corpus, DiscoveryConfig(bits=bits))
        for bits in VALID_BITS
    }


def _key(entries):
    return [(e.table_id, e.joinability, e.mapping) for e in entries]


def _same_set(a, b):
    """Rank-mode-agnostic comparison: the verified top-k SET (ids, scores,
    mappings) must match; order is the rank mode's business."""
    return sorted(_key(a)) == sorted(_key(b))


# ---------------------------------------------------------------------------
# DiscoveryConfig
# ---------------------------------------------------------------------------

def test_config_is_frozen_and_hashable():
    cfg = DiscoveryConfig(backend="fused", bits=256)
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.k = 3
    assert hash(cfg) == hash(DiscoveryConfig(backend="fused", bits=256))


@pytest.mark.parametrize("kw", [
    {"bits": 96},
    {"backend": "cuda"},
    {"fused_block_n": 100},
    {"fused_block_n": 384},
    {"prefetch_frac": 1.5},
    {"window": 0},
    {"batch_tables": 0},
    {"k": 0},
    {"flush_after": -1.0},
])
def test_config_validation(kw):
    with pytest.raises(ValueError):
        DiscoveryConfig(**kw)


def test_config_resolves_backend(monkeypatch):
    assert DiscoveryConfig(backend="numpy").resolve_backend().name == "numpy"
    monkeypatch.setenv("MATE_FILTER_BACKEND", "xla")
    # config level beats env; unset config follows env
    assert DiscoveryConfig(backend="fused").resolve_backend().name == "fused"
    assert DiscoveryConfig().resolve_backend().name == "xla"


def test_session_adopts_index_ground_truth(lake):
    corpus, _q, _qc = lake
    index = MateIndex(corpus, cfg=xash.XashConfig(bits=256))
    session = MateSession(index, DiscoveryConfig(bits=128))
    assert session.bits == 256 and session.config.bits == 256


def test_session_build_records_build_stats(sessions):
    """MateSession.build carries the offline-phase BuildStats; wrapping an
    externally built index does not invent one."""
    s = sessions[128]
    assert s.build_stats is not None
    assert s.build_stats.n_shards == 1 and not s.build_stats.sharded
    assert s.build_stats.values_total == len(s.index.corpus.unique_values)
    assert s.build_stats.bytes_hashed == s.index.corpus.unique_enc.size
    assert s.build_stats.total_seconds > 0
    assert MateSession(s.index).build_stats is None


# ---------------------------------------------------------------------------
# Acceptance: bit-identity across widths × backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", VALID_BITS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_session_discover_bit_identical(sessions, lake, bits, backend):
    """session.discover returns the scalar Algorithm 1 SET (quality rank
    reorders it) and matches the raw engine exactly at the session's own
    rank/gate flags, at every width and backend."""
    _corpus, query, q_cols = lake
    base = sessions[bits]
    session = MateSession(
        base.index, dataclasses.replace(base.config, backend=backend, k=10)
    )
    ref, _ = discovery.discover(session.index, query, q_cols, k=10)
    got, stats = session.discover(query, q_cols)
    assert _same_set(got, ref)
    old, _ = discover_batched(
        session.index, query, q_cols, k=10, backend=backend,
        rank="quality", profile_gate=True,
    )
    assert _key(got) == _key(old)
    assert all(e.quality is not None for e in got)
    if backend in ("fused", "fused-gather"):
        assert stats.filter_matrix_bytes == 0
        assert stats.filter_fused_launches > 0
    if backend == "fused-gather":
        # the host never gathered the candidate superkeys: every launch
        # saved n × (lanes·4 − 4) bytes of gather traffic
        assert stats.gather_bytes_saved > 0
    else:
        assert stats.gather_bytes_saved == 0


@pytest.mark.parametrize("backend", BACKENDS)
def test_session_discover_many_bit_identical(sessions, lake, backend):
    corpus, query, q_cols = lake
    base = sessions[128]
    session = MateSession(
        base.index, dataclasses.replace(base.config, backend=backend)
    )
    queries = [(query, q_cols)] + synthetic.make_mixed_queries(
        corpus, 2, 12, 2, seed=21
    )
    out = session.discover_many(queries, k=[10, 3, 5])
    for (q, qc), k_i, (entries, _st) in zip(queries, [10, 3, 5], out):
        ref, _ = discovery.discover(session.index, q, qc, k=k_i)
        assert _same_set(entries, ref)


def test_session_stats_accumulate(sessions, lake):
    _corpus, query, q_cols = lake
    session = MateSession(sessions[128].index, DiscoveryConfig(k=5))
    assert session.stats.requests == 0
    session.discover(query, q_cols)
    session.discover_many([(query, q_cols)] * 2)
    assert session.stats.requests == 3
    assert session.stats.filter_checks > 0
    assert 0.0 <= session.stats.precision <= 1.0


def test_ops_fused_block_n_rejects_bad_override():
    """The ops-level override check is a ValueError with the same wording as
    DiscoveryConfig.__post_init__ — it used to be a bare assert, which a
    ``python -O`` run silently skipped, letting a non-pow2 block reach the
    kernel."""
    from repro.kernels import ops

    row = np.zeros((4, 4), dtype=np.uint32)
    qk = np.zeros((2, 4), dtype=np.uint32)
    seg = np.zeros(4, dtype=np.int32)
    for bad in (100, 384, 64):
        with pytest.raises(
            ValueError,
            match=rf"fused_block_n must be a power of two >= 128, got {bad}",
        ):
            ops.filter_table_counts(row, qk, None, seg, 2, block_n=bad)
        with pytest.raises(ValueError, match="power of two >= 128"):
            ops.gather_filter_table_counts(
                jnp_store(), np.zeros(4, np.int64), qk, None, seg, 2,
                block_n=bad,
            )


def jnp_store():
    import jax.numpy as jnp

    return jnp.zeros((8, 4), dtype=jnp.uint32)


def test_ops_fused_block_n_validates_under_python_O():
    """Regression for the bare-assert bug: the check must still fire with
    assertions compiled out (``python -O``)."""
    import os
    import subprocess
    import sys

    script = (
        "import numpy as np\n"
        "from repro.kernels import ops\n"
        "row = np.zeros((4, 4), dtype=np.uint32)\n"
        "qk = np.zeros((2, 4), dtype=np.uint32)\n"
        "seg = np.zeros(4, dtype=np.int32)\n"
        "try:\n"
        "    ops.filter_table_counts(row, qk, None, seg, 2, block_n=100)\n"
        "except ValueError as e:\n"
        "    ok = 'fused_block_n must be a power of two >= 128, got 100' in str(e)\n"
        "    print('OK' if ok else 'WRONG-MESSAGE:' + str(e))\n"
        "else:\n"
        "    print('NO-ERROR')\n"
    )
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
    )
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-O", "-c", script],
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert proc.returncode == 0, proc.stderr
    assert proc.stdout.strip() == "OK", (proc.stdout, proc.stderr)


def test_session_fused_block_n_override(sessions, lake):
    """A config-pinned fused row block changes tiling only, never results."""
    _corpus, query, q_cols = lake
    base = sessions[128]
    ref, _ = base.discover(query, q_cols, k=10)
    session = MateSession(
        base.index,
        DiscoveryConfig(backend="fused", fused_block_n=128, k=10),
    )
    got, stats = session.discover(query, q_cols)
    assert _key(got) == _key(ref)
    assert stats.filter_matrix_bytes == 0


# ---------------------------------------------------------------------------
# Deprecation REMOVAL: the PR 4 shims are gone — old kwargs raise TypeError
# ---------------------------------------------------------------------------

def test_removed_use_kernel_kwarg_raises(sessions, lake):
    _corpus, query, q_cols = lake
    index = sessions[128].index
    with pytest.raises(TypeError, match="use_kernel"):
        discover_batched(index, query, q_cols, k=10, use_kernel=False)
    # the modern spelling of the old flag
    got, _ = discover_batched(index, query, q_cols, k=10, backend="numpy")
    ref, _ = discovery.discover(index, query, q_cols, k=10)
    assert _key(got) == _key(ref)


def test_removed_fused_kwarg_raises(sessions, lake):
    _corpus, query, q_cols = lake
    index = sessions[128].index
    with pytest.raises(TypeError, match="fused"):
        discover_batched(index, query, q_cols, k=10, fused=True)
    with pytest.raises(TypeError, match="fused"):
        discover_many(index, [(query, q_cols)], k=[5], fused=True)
    with pytest.raises(TypeError, match="fused"):
        DiscoveryEngine(index, batch=2, fused=True)
    with pytest.raises(TypeError, match="use_kernel"):
        DiscoveryEngine(index, batch=2, use_kernel=False)


def test_removed_distributed_impl_kwarg_raises(sessions, lake):
    from repro.core import distributed
    import jax

    corpus, _query, _q_cols = lake
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    with pytest.raises(TypeError, match="impl"):
        distributed.make_distributed_filter(
            mesh, len(corpus.tables), ("data",), impl="blocked"
        )


def test_resolve_engine_backend_shim_is_gone():
    """The legacy-flag translation layer itself was deleted with the shims —
    backend resolution is kernels.registry only."""
    from repro.core import batched

    assert not hasattr(batched, "resolve_engine_backend")
    assert not hasattr(batched, "_UNSET")


# ---------------------------------------------------------------------------
# Async engine: window / deadline semantics
# ---------------------------------------------------------------------------

def _engine(session_base, queries, window=2, flush_after=1.0):
    clock = {"t": 0.0}
    session = MateSession(
        session_base.index,
        DiscoveryConfig(window=window, flush_after=flush_after, k=5),
    )
    eng = DiscoveryEngine(session=session, clock=lambda: clock["t"])
    return eng, clock


def test_engine_window_fills_before_deadline(sessions, lake):
    corpus, query, q_cols = lake
    queries = [(query, q_cols)] + synthetic.make_mixed_queries(
        corpus, 2, 10, 2, seed=31
    )
    eng, clock = _engine(sessions[128], queries, window=2, flush_after=10.0)
    r1 = eng.submit(*queries[0])
    assert eng.pump() == []  # neither window nor deadline
    r2 = eng.submit(*queries[1])
    served = eng.pump()  # window of 2 filled — deadline irrelevant
    assert served == [r1, r2] and r1.done and r2.done


def test_engine_deadline_flushes_partial_group(sessions, lake):
    _corpus, query, q_cols = lake
    eng, clock = _engine(sessions[128], None, window=8, flush_after=1.0)
    r1 = eng.submit(query, q_cols)
    assert eng.pump() == []
    clock["t"] = 0.99
    assert eng.pump() == []  # deadline not yet reached
    clock["t"] = 1.0
    served = eng.pump()  # oldest request aged past flush_after
    assert served == [r1] and r1.done
    # future carries the payload
    entries, stats = r1.future.result(timeout=0)
    assert entries == r1.results and stats is r1.stats
    ref, _ = discovery.discover(eng.index, query, q_cols, k=5)
    assert _same_set(r1.results, ref)


def test_engine_no_deadline_only_full_windows(sessions, lake):
    _corpus, query, q_cols = lake
    eng, clock = _engine(sessions[128], None, window=4, flush_after=None)
    eng.submit(query, q_cols)
    clock["t"] = 1e9
    assert eng.pump() == []  # no deadline policy: partial group waits
    assert eng.flush()  # explicit flush always drains
    assert not eng.queue


def test_engine_deadline_serves_multiple_due_groups(sessions, lake):
    corpus, query, q_cols = lake
    qs = [(query, q_cols)] * 5
    eng, clock = _engine(sessions[128], None, window=2, flush_after=0.5)
    for q, qc in qs:
        eng.submit(q, qc)
    clock["t"] = 1.0
    served = eng.pump()  # two full windows + one deadline-due partial
    assert len(served) == 5 and all(r.done for r in served)


def test_engine_per_request_k(sessions, lake):
    _corpus, query, q_cols = lake
    eng, _clock = _engine(sessions[128], None, window=2, flush_after=None)
    r_a = eng.submit(query, q_cols, k=3)
    r_b = eng.submit(query, q_cols)  # config default k=5
    eng.pump()
    assert len(r_a.results) <= 3
    ref3, _ = discovery.discover(eng.index, query, q_cols, k=3)
    ref5, _ = discovery.discover(eng.index, query, q_cols, k=5)
    assert _same_set(r_a.results, ref3)
    assert _same_set(r_b.results, ref5)


def test_engine_next_deadline(sessions, lake):
    _corpus, query, q_cols = lake
    eng, clock = _engine(sessions[128], None, window=4, flush_after=2.0)
    assert eng.next_deadline() is None
    clock["t"] = 1.0
    eng.submit(query, q_cols)
    assert eng.next_deadline() == pytest.approx(3.0)


def test_engine_discover_async(sessions, lake):
    corpus, query, q_cols = lake
    queries = [(query, q_cols)] + synthetic.make_mixed_queries(
        corpus, 2, 10, 2, seed=33
    )
    session = MateSession(
        sessions[128].index, DiscoveryConfig(window=4, flush_after=0.02, k=5)
    )
    eng = DiscoveryEngine(session=session)

    async def run():
        return await asyncio.gather(
            *[eng.discover_async(q, qc) for q, qc in queries]
        )

    reqs = asyncio.run(run())
    assert all(r.done for r in reqs)
    for (q, qc), r in zip(queries, reqs):
        ref, _ = discovery.discover(eng.index, q, qc, k=5)
        assert sorted((e.table_id, e.joinability) for e in r.results) == sorted(
            (e.table_id, e.joinability) for e in ref
        )


def test_engine_discover_async_without_deadline_policy(sessions, lake):
    """Regression: with flush_after=None an async waiter must drain its
    group rather than spin forever waiting for a window that never fills."""
    _corpus, query, q_cols = lake
    eng = DiscoveryEngine(
        session=MateSession(sessions[128].index, DiscoveryConfig(k=5))
    )  # default config: window=8, no deadline

    async def run():
        return await asyncio.wait_for(
            eng.discover_async(query, q_cols), timeout=30.0
        )

    req = asyncio.run(run())
    assert req.done
    ref, _ = discovery.discover(eng.index, query, q_cols, k=5)
    assert _same_set(req.results, ref)


def test_engine_group_failure_rejects_every_future(sessions, lake):
    """Regression: when a group launch raises, every dequeued request's
    future must be rejected — a sibling awaiter must not hang forever."""
    _corpus, query, q_cols = lake
    eng, _clock = _engine(sessions[128], None, window=2, flush_after=None)
    good = eng.submit(query, q_cols)
    bad = eng.submit(query, [99])  # column index out of range -> IndexError
    with pytest.raises(IndexError):
        eng.pump()
    assert good.future.done() and bad.future.done()
    with pytest.raises(IndexError):
        good.future.result(timeout=0)
    assert not eng.queue  # the failed group is not silently requeued

    # flush(): a failing FIRST group must leave later groups queued with
    # pending futures, not strand them dequeued-and-unresolved
    bad2 = eng.submit(query, [99])
    pad = eng.submit(query, [99])
    later = eng.submit(query, q_cols)
    with pytest.raises(IndexError):
        eng.flush()
    assert bad2.future.done() and pad.future.done()
    assert not later.future.done() and eng.queue == [later]
    eng.flush()  # retry serves the still-queued survivor
    assert later.done and later.future.result(timeout=0)

    async def run():
        return await asyncio.wait_for(
            eng.discover_async(query, [99]), timeout=30.0
        )

    with pytest.raises(IndexError):
        asyncio.run(run())


def test_engine_session_and_index_conflict(sessions):
    with pytest.raises(TypeError):
        DiscoveryEngine(sessions[128].index, session=sessions[128])
    with pytest.raises(TypeError):
        DiscoveryEngine()


def test_engine_removed_legacy_flags_cannot_touch_session(sessions, lake):
    """The removed use_kernel=/fused= flags raise before they could ever
    touch a shared session's once-resolved backend."""
    session = MateSession(sessions[128].index, DiscoveryConfig(backend="xla"))
    with pytest.raises(TypeError, match="fused"):
        DiscoveryEngine(session=session, fused=True)
    assert session.backend.name == "xla"  # untouched


def test_enrich_accepts_session(sessions, lake):
    from repro.data.enrichment import enrich
    from repro.core.corpus import Table

    corpus, query, q_cols = lake
    session = sessions[128]
    base = Table(-1, [list(r) for r in corpus.tables[0].cells[:8]])
    served_before = session.stats.requests
    enriched_s, prov_s = enrich(session, base, key_cols=[0], k=3)
    enriched_i, prov_i = enrich(session.index, base, key_cols=[0], k=3)
    assert [r for r in enriched_s.cells] == [r for r in enriched_i.cells]
    assert prov_s == prov_i
    assert session.stats.requests == served_before + 1
