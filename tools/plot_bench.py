"""Trajectory plotter over ``benchmarks/results/BENCH_*.json`` — the small
dashboard the ROADMAP "Trajectory dashboards" item left open.

Each ``BENCH_<section>.json`` accumulates one record per bench run
({"ts", "backend", "rows"}); this tool renders the per-row trajectories so
drift is visible BEFORE it trips the >20% ``check_bench`` gate:

    PYTHONPATH=src python tools/plot_bench.py                 # all sections
    python tools/plot_bench.py --section kernels              # one section
    python tools/plot_bench.py --metric kernels:engine/mate_batched:vs_seq
    python tools/plot_bench.py --ascii                        # no matplotlib

Outputs one PNG per section under ``benchmarks/results/plots/`` (wall-clock
``us_per_call`` per row, log scale, one line per row; runs recorded under a
different backend than the latest run are marked — their points are NOT
comparable, the same rule ``check_bench`` enforces).  ``--metric`` plots a
single ``section:row:key`` derived metric instead.  ``--ascii`` prints
sparkline tables to stdout and needs no display/matplotlib at all (the
fallback when matplotlib is missing).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_RESULTS = os.path.join(REPO, "benchmarks", "results")
sys.path.insert(0, os.path.join(REPO, "src"))
sys.path.insert(0, REPO)

from tools.check_bench import parse_derived  # noqa: E402  (single parser)

SPARKS = "▁▂▃▄▅▆▇█"


def load_sections(results_dir: str) -> dict[str, list[dict]]:
    """section name -> run history (list of {"ts", "backend", "rows"})."""
    out: dict[str, list[dict]] = {}
    if not os.path.isdir(results_dir):
        return out
    for fname in sorted(os.listdir(results_dir)):
        if not (fname.startswith("BENCH_") and fname.endswith(".json")):
            continue
        section = fname[len("BENCH_"):-len(".json")]
        try:
            with open(os.path.join(results_dir, fname)) as f:
                history = json.load(f)
        except (json.JSONDecodeError, OSError):
            continue
        if isinstance(history, list) and history:
            out[section] = history
    return out


def trajectories(history: list[dict]) -> dict[str, list[tuple[int, float, str]]]:
    """row name -> [(run index, us_per_call, backend)] across the history."""
    out: dict[str, list[tuple[int, float, str]]] = {}
    for i, record in enumerate(history):
        backend = record.get("backend") or "?"
        for row in record.get("rows", []):
            out.setdefault(row["name"], []).append(
                (i, float(row.get("us_per_call", 0.0)),
                 row.get("backend", backend))
            )
    return out


def metric_trajectory(
    history: list[dict], row_name: str, key: str
) -> list[tuple[int, float, str]]:
    """[(run index, derived-key value, backend)] for one row's derived key."""
    out = []
    for i, record in enumerate(history):
        backend = record.get("backend") or "?"
        for row in record.get("rows", []):
            if row["name"] != row_name:
                continue
            val = parse_derived(row.get("derived", "")).get(key)
            if val is not None:
                out.append((i, val, row.get("backend", backend)))
    return out


def sparkline(values: list[float]) -> str:
    if not values:
        return ""
    lo, hi = min(values), max(values)
    span = (hi - lo) or 1.0
    return "".join(
        SPARKS[int((v - lo) / span * (len(SPARKS) - 1))] for v in values
    )


def render_ascii(section: str, history: list[dict]) -> None:
    trajs = trajectories(history)
    latest_backend = history[-1].get("backend") or "?"
    print(f"\n== {section} ({len(history)} runs, latest backend: "
          f"{latest_backend}) ==")
    width = max((len(n) for n in trajs), default=0)
    for name, points in sorted(trajs.items()):
        vals = [v for _, v, _ in points]
        mixed = len({b for _, _, b in points}) > 1
        last = vals[-1]
        note = "  [mixed backends]" if mixed else ""
        print(f"  {name:<{width}}  {sparkline(vals)}  last={last:,.1f}us{note}")


def render_png(
    section: str, history: list[dict], out_dir: str
) -> str | None:
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        return None
    trajs = trajectories(history)
    timed = {n: p for n, p in trajs.items() if any(v > 0 for _, v, _ in p)}
    if not timed:
        return None
    latest_backend = history[-1].get("backend") or "?"
    fig, ax = plt.subplots(figsize=(9, 5))
    for name, points in sorted(timed.items()):
        xs = [i for i, _, _ in points]
        ys = [max(v, 1e-3) for _, v, _ in points]
        (line,) = ax.plot(xs, ys, marker="o", markersize=3, linewidth=1,
                          label=name, alpha=0.8)
        # runs recorded under a foreign backend are not comparable points —
        # ring them, the same rule check_bench enforces
        off = [(i, y) for (i, _, b), y in zip(points, ys)
               if b != latest_backend]
        if off:
            ax.plot([i for i, _ in off], [y for _, y in off], "x",
                    color=line.get_color(), markersize=7)
    ax.set_yscale("log")
    ax.set_xlabel("bench run")
    ax.set_ylabel("us_per_call (log)")
    ax.set_title(f"BENCH_{section} trajectories "
                 f"(x = run under a different backend than {latest_backend!r})")
    ax.legend(fontsize=6, ncol=2, loc="upper left", framealpha=0.6)
    fig.tight_layout()
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"PLOT_{section}.png")
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return path


def render_metric_png(
    name: str, points: list[tuple[int, float, str]], out_dir: str
) -> str | None:
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        return None
    fig, ax = plt.subplots(figsize=(7, 4))
    (line,) = ax.plot([i for i, _, _ in points], [v for _, v, _ in points],
                      marker="o", linewidth=1.2)
    # same rule as the section plots: points recorded under a different
    # backend than the latest run are not comparable — ring them
    latest_backend = points[-1][2]
    off = [(i, v) for i, v, b in points if b != latest_backend]
    if off:
        ax.plot([i for i, _ in off], [v for _, v in off], "x",
                color=line.get_color(), markersize=8)
    ax.set_xlabel("bench run")
    ax.set_ylabel(name.split(":")[-1])
    ax.set_title(f"{name}"
                 + (f" (x = backend ≠ {latest_backend!r})" if off else ""))
    fig.tight_layout()
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"PLOT_{name.replace(':', '_').replace('/', '-')}.png")
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return path


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results-dir", default=DEFAULT_RESULTS)
    ap.add_argument("--out", default=None,
                    help="plot dir (default <results-dir>/plots)")
    ap.add_argument("--section", default=None, help="one section only")
    ap.add_argument("--metric", default=None,
                    help="plot one derived metric: <section>:<row>:<key>")
    ap.add_argument("--ascii", action="store_true",
                    help="sparkline tables on stdout, no matplotlib")
    args = ap.parse_args(argv)
    out_dir = args.out or os.path.join(args.results_dir, "plots")

    sections = load_sections(args.results_dir)
    if args.section:
        sections = {k: v for k, v in sections.items() if k == args.section}
    if not sections:
        print(f"no BENCH_*.json trajectories under {args.results_dir}",
              file=sys.stderr)
        return 1

    if args.metric:
        section, row, key = args.metric.split(":", 2)
        history = sections.get(section)
        if history is None:
            print(f"unknown section {section!r}", file=sys.stderr)
            return 1
        points = metric_trajectory(history, row, key)
        if not points:
            print(f"metric {args.metric!r} absent from every run", file=sys.stderr)
            return 1
        vals = [v for _, v, _ in points]
        mixed = len({b for _, _, b in points}) > 1
        print(f"{args.metric}: {sparkline(vals)} "
              f"last={points[-1][1]:g} over {len(points)} run(s)"
              + ("  [mixed backends — points are not comparable]" if mixed else ""))
        if not args.ascii:
            path = render_metric_png(args.metric, points, out_dir)
            if path:
                print(f"wrote {path}")
        return 0

    wrote = 0
    for section, history in sorted(sections.items()):
        if args.ascii:
            render_ascii(section, history)
            continue
        path = render_png(section, history, out_dir)
        if path:
            print(f"wrote {path}")
            wrote += 1
        else:
            render_ascii(section, history)  # matplotlib missing / no data
    return 0


if __name__ == "__main__":
    sys.exit(main())
