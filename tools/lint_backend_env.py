"""CI lint: ``MATE_FILTER_BACKEND`` may only be read by the backend registry.

The whole point of ``kernels/registry.py`` is that backend selection has ONE
precedence rule (explicit config > env var > platform default) evaluated in
ONE place.  Any other module touching the env var re-opens the pre-registry
scatter, so this lint fails if the variable's name occurs as a CODE string
literal (``os.environ.get("…")`` and friends) in any Python module under
``src/``, ``benchmarks/``, or ``examples/`` other than the registry itself.
Docstrings and comments may still *document* the env var — prose is not a
read — so matching is AST-based: exact string constants outside docstring
position.  (Tests may set it — they exercise the env level of the
precedence through monkeypatch; CI workflow files may set it — that is the
env level's job.)

    python tools/lint_backend_env.py          # exits non-zero on violations
"""

from __future__ import annotations

import ast
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# 'MATE_FILTER' + 'BACKEND' concatenated so this module doesn't flag itself
# when the scan roots ever grow to include tools/
NEEDLE = "MATE_FILTER" + "_BACKEND"
SCAN_ROOTS = ("src", "benchmarks", "examples")
ALLOWED = {os.path.join("src", "repro", "kernels", "registry.py")}


def _docstring_constants(tree: ast.AST) -> set[int]:
    """ids of Constant nodes sitting in docstring position."""
    out: set[int] = set()
    for node in ast.walk(tree):
        if isinstance(
            node, (ast.Module, ast.ClassDef, ast.FunctionDef, ast.AsyncFunctionDef)
        ):
            body = getattr(node, "body", [])
            if (
                body
                and isinstance(body[0], ast.Expr)
                and isinstance(body[0].value, ast.Constant)
                and isinstance(body[0].value.value, str)
            ):
                out.add(id(body[0].value))
    return out


def reads_env_var(source: str) -> bool:
    """True if the module uses the env-var name as a non-docstring string
    literal — the shape every environ read takes."""
    tree = ast.parse(source)
    docstrings = _docstring_constants(tree)
    for node in ast.walk(tree):
        if (
            isinstance(node, ast.Constant)
            and node.value == NEEDLE
            and id(node) not in docstrings
        ):
            return True
    return False


def violations(repo: str = REPO) -> list[str]:
    """Relative paths of Python modules reading the env var illegally."""
    out: list[str] = []
    for root in SCAN_ROOTS:
        base = os.path.join(repo, root)
        for dirpath, _dirnames, filenames in os.walk(base):
            for fname in filenames:
                if not fname.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fname)
                rel = os.path.relpath(path, repo)
                if rel in ALLOWED:
                    continue
                with open(path, encoding="utf-8") as f:
                    src = f.read()
                if NEEDLE in src and reads_env_var(src):
                    out.append(rel)
    return sorted(out)


def main() -> int:
    bad = violations()
    if bad:
        print(
            f"{NEEDLE} may only be read by src/repro/kernels/registry.py "
            "(route selection through kernels.registry.resolve_backend); "
            "found in:",
            file=sys.stderr,
        )
        for rel in bad:
            print(f"  {rel}", file=sys.stderr)
        return 1
    print(f"lint ok: {NEEDLE} referenced only by the registry")
    return 0


if __name__ == "__main__":
    sys.exit(main())
