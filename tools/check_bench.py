"""CI bench-regression gate: compare the latest benchmark run against the
committed baseline and exit non-zero on regression.

Usage (what .github/workflows/ci.yml runs after the bench step):

    PYTHONPATH=src python tools/check_bench.py \
        [--baseline benchmarks/baselines/BASELINE_ci.json] \
        [--results-dir benchmarks/results]

The baseline (``benchmarks/baselines/BASELINE_ci.json``, recorded on the
pinned ubuntu CI runner) names metrics as ``<section>:<row>:<key>`` —
``section`` selects ``BENCH_<section>.json``, ``row`` the emitted row name,
``key`` one ``key=value`` entry of its derived field.  Only RATIOS and exact
structural counts are gated (engine speedups, fp rates, fused matrix bytes):
absolute wall-clock µs are machine noise, ratios against a same-process
reference are not.

Per metric:
  * ``"exact": true``          — current must equal ``value`` exactly
                                 (fn counts, ordering flags, matrix bytes);
  * ``"direction": "higher"``  — fail if current < value · (1 − tolerance)
                                 (speedup ratios: lower = regression);
  * ``"direction": "lower"``   — fail if current > value · (1 + tolerance)
                                 (fp rates: higher = regression).

``tolerance`` defaults to ``default_tolerance`` (0.20 — the >20% regression
bar from ROADMAP "Trajectory dashboards") and can be overridden per metric.

The baseline's top-level ``"backend"`` names the registry-resolved filter
backend it was recorded under; rows in the latest run carry their own stamp
(``benchmarks/common.save_trajectory``) and the gate REFUSES to compare a
row recorded under a different backend — a fused-path baseline gated
against a composed-path run would measure the dispatch switch, not a
regression.
A metric whose row/key is missing from the latest run FAILS the gate: a
benchmark that silently stopped emitting is itself a regression
(benchmarks/run.py exits non-zero on section errors for the same reason).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(REPO, "benchmarks", "baselines", "BASELINE_ci.json")
DEFAULT_RESULTS = os.path.join(REPO, "benchmarks", "results")


def parse_derived(derived: str) -> dict[str, float]:
    """'a=1.5x;b=True;c=12' -> {'a': 1.5, 'b': 1.0, 'c': 12.0} (non-numeric
    entries are skipped)."""
    out: dict[str, float] = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        key, _, raw = part.partition("=")
        raw = raw.strip().rstrip("x").replace(",", "")
        if raw in ("True", "False"):
            out[key.strip()] = float(raw == "True")
            continue
        try:
            out[key.strip()] = float(raw)
        except ValueError:
            continue
    return out


def latest_rows(
    results_dir: str, section: str
) -> tuple[dict[str, dict[str, float]], dict[str, str | None]]:
    """(row name -> parsed derived dict, row name -> recorded backend) for
    the LAST run in BENCH_<section>.json."""
    path = os.path.join(results_dir, f"BENCH_{section}.json")
    if not os.path.exists(path):
        return {}, {}
    with open(path) as f:
        history = json.load(f)
    if not history:
        return {}, {}
    last = history[-1]
    run_backend = last.get("backend")
    rows = {
        row["name"]: parse_derived(row.get("derived", ""))
        for row in last["rows"]
    }
    backends = {
        row["name"]: row.get("backend", run_backend) for row in last["rows"]
    }
    return rows, backends


def check(baseline: dict, results_dir: str) -> list[str]:
    """Returns a list of failure descriptions (empty = gate passes)."""
    failures: list[str] = []
    default_tol = float(baseline.get("default_tolerance", 0.20))
    base_backend = baseline.get("backend")
    cache: dict[str, tuple[dict, dict]] = {}
    for name, spec in baseline["metrics"].items():
        section, row, key = name.split(":", 2)
        if section not in cache:
            cache[section] = latest_rows(results_dir, section)
        rows, backends = cache[section]
        cur = rows.get(row, {}).get(key)
        base = float(spec["value"])
        if cur is None:
            failures.append(f"{name}: missing from latest BENCH_{section}.json run")
            continue
        # REFUSE cross-backend comparisons: a baseline recorded on one
        # dispatch path (say fused) must not gate a run recorded on another
        # (say composed) — the ratio would measure the backend switch, not a
        # regression.  Rows are stamped by benchmarks/common.save_trajectory;
        # the baseline names its backend at the top level, and a metric whose
        # row PINS a backend in code (fused/composed kernel rows) overrides
        # it per-spec.
        row_backend = backends.get(row)
        want_backend = spec.get("backend", base_backend)
        if want_backend and row_backend and row_backend != want_backend:
            failures.append(
                f"{name}: recorded under backend {row_backend!r} but the "
                f"baseline was recorded under {want_backend!r} — refusing to "
                "compare across backends (re-record the baseline or re-run "
                "the bench under the matching MATE_FILTER_BACKEND/config)"
            )
            continue
        if spec.get("exact"):
            if cur != base:
                failures.append(f"{name}: expected exactly {base}, got {cur}")
            else:
                print(f"ok    {name}: {cur} (exact)")
            continue
        tol = float(spec.get("tolerance", default_tol))
        direction = spec["direction"]
        if direction == "higher":
            floor = base * (1.0 - tol)
            if cur < floor:
                failures.append(
                    f"{name}: {cur:.4g} < {floor:.4g} "
                    f"(baseline {base:.4g} − {tol:.0%})"
                )
            else:
                print(f"ok    {name}: {cur:.4g} (≥ {floor:.4g})")
        elif direction == "lower":
            ceil = base * (1.0 + tol)
            if cur > ceil:
                failures.append(
                    f"{name}: {cur:.4g} > {ceil:.4g} "
                    f"(baseline {base:.4g} + {tol:.0%})"
                )
            else:
                print(f"ok    {name}: {cur:.4g} (≤ {ceil:.4g})")
        else:
            failures.append(f"{name}: bad direction {direction!r} in baseline")
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--results-dir", default=DEFAULT_RESULTS)
    args = ap.parse_args(argv)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = check(baseline, args.results_dir)
    if failures:
        print(f"\nBENCH GATE FAILED ({len(failures)} metric(s)):", file=sys.stderr)
        for f_ in failures:
            print(f"  FAIL  {f_}", file=sys.stderr)
        return 1
    print(f"\nbench gate passed: {len(baseline['metrics'])} metric(s) ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
