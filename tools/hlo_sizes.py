"""Dump the largest tensor shapes in a dry-run cell's compiled HLO."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.launch import dryrun as D

def biggest(arch, shape, multi_pod=False, variant=None, sets=(), top=12):
    v = D.Variant.parse(variant or "probe", list(sets))
    import dataclasses, jax, jax.numpy as jnp
    # replicate lower_cell but keep the compiled text
    rec_text = {}
    orig = D.parse_collectives
    def capture(text):
        rec_text['t'] = text
        return orig(text)
    D.parse_collectives = capture
    rec = D.lower_cell(arch, shape, multi_pod, v)
    D.parse_collectives = orig
    sizes = {}
    for m in re.finditer(r'(\w+)\[([\d,]+)\]', rec_text['t']):
        dt, dims = m.group(1), m.group(2)
        bs = {'f32':4,'bf16':2,'s32':4,'u32':4,'pred':1,'f16':2,'s8':1,'u8':1,'f64':8}.get(dt)
        if not bs: continue
        n = 1
        for d in dims.split(','): n *= int(d)
        sizes[f'{dt}[{dims}]'] = n*bs
    for k, v2 in sorted(sizes.items(), key=lambda x: -x[1])[:top]:
        print(f'{v2/1e9:8.2f} GB  {k}')
    return rec

if __name__ == '__main__':
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument('arch'); ap.add_argument('shape')
    ap.add_argument('--multi-pod', action='store_true')
    ap.add_argument('--set', action='append', default=[], dest='sets')
    a = ap.parse_args()
    rec = biggest(a.arch, a.shape, a.multi_pod, sets=a.sets)
    if rec.get('memory_analysis'): print('temp GB:', rec['memory_analysis']['temp_size_in_bytes']/1e9)
